#!/usr/bin/env python3
"""Flight-recorder trace gate: validate a Chrome trace-event JSON file
(as written by `--trace-out`, the server `trace` command, or the
sched_interleave bench) without needing the Rust toolchain.

Checks:
  * top level is either a bare event array or
    {"traceEvents": [...], "otherData": {...}};
  * every event's "ph" is one of B/E/C/M/X/s/f and carries pid/tid
    (metadata "M" events are exempt from ts checks);
  * per (pid, tid) track: "B"/"E" pairs balance as a stack and each
    "E" closes a "B" of the same name;
  * per (pid, tid) track: "ts" is monotone non-decreasing (flow
    events are exempt — they are emitted after the duration stream
    and point back into it);
  * flow events pair: every "s" id has exactly one "f" and vice
    versa, with f.ts >= s.ts (causality cannot run backwards);
  * the ring drop counter in otherData is reported (a dropped-events
    trace is still *valid* — the ring is bounded by design — but the
    count must be surfaced, and --max-dropped can gate it).

With --require-overlap the trace must additionally contain at least one
`preload_part` span that overlaps a compute span (`step` or
`layer_fetch`) in wall time — the observable form of the paper's
I/O-under-compute pipeline (PERF.md §Observability).

With --require-flows the trace must carry the causal span-context
chain: at least one `request` root span, at least one attributed flash
I/O span (`io_batch`/`ondemand_read` with `args.req != 0`), and every
attributed flash I/O span must be reachable from a request root by
walking flow edges (s -> f, endpoints bound to slices by exact begin
timestamp on the endpoint's track) plus same-track slice nesting.
Unattributed I/O (args.req == 0 — warmup, bench traffic without
request ids) is exempt.

Usage: check_trace.py TRACE.json [--require-overlap] [--require-flows]
                      [--max-dropped N]
       check_trace.py --self-test

Exit codes: 0 = valid, 1 = invalid trace, 2 = unreadable/malformed input.
"""

import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "pallas_lint")
)
from jsonutil import load_trace_events as load_events  # noqa: E402

PHASES = {"B", "E", "C", "M", "X", "s", "f"}
COMPUTE_NAMES = {"step", "layer_fetch"}
IO_NAMES = {"io_batch", "ondemand_read"}


def fail(msg):
    print(f"check-trace: FAIL — {msg}")
    return 1


def bind_endpoint(by_track, track, ts):
    """Bind one flow endpoint to a slice index: exact begin-timestamp
    match on the endpoint's track (first in file order on ties — the
    emitter's contract), falling back to the innermost slice containing
    ts. Returns a slice index or None."""
    slices = by_track.get(track, [])
    for idx, sl in slices:
        if sl["t0"] == ts:
            return idx
    best = None
    for idx, sl in slices:
        if sl["t1"] is None:
            continue
        if sl["t0"] <= ts <= sl["t1"]:
            if best is None or sl["t0"] >= by_track_t0(best, slices):
                best = idx
    return best


def by_track_t0(idx, slices):
    for i, sl in slices:
        if i == idx:
            return sl["t0"]
    return -1


def validate(path, require_overlap=False, require_flows=False,
             max_dropped=None):
    """Validate one trace file. Returns an exit code."""
    try:
        events, other = load_events(path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check-trace: cannot read {path}: {e}")
        return 2

    stacks = {}   # (pid, tid) -> [(name, ts, slice_idx)]
    last_ts = {}  # (pid, tid) -> ts
    # closed slices, file order: {track, name, t0, t1, args, parent}
    slices = []
    flow_s = {}   # id -> (track, ts)
    flow_f = {}
    counters = 0

    for i, e in enumerate(events):
        if not isinstance(e, dict):
            return fail(f"event #{i} is not an object")
        ph = e.get("ph")
        if ph not in PHASES:
            return fail(f"event #{i}: ph {ph!r} not in {sorted(PHASES)}")
        if "pid" not in e or "tid" not in e:
            return fail(f"event #{i} ({ph}): missing pid/tid")
        if ph == "M":
            continue
        track = (e["pid"], e["tid"])
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            return fail(f"event #{i} ({ph}): bad ts {ts!r}")
        if ph in ("s", "f"):
            # flow endpoints point back into the duration stream; they
            # are exempt from per-track monotonicity but must pair up
            fid = e.get("id")
            if fid is None:
                return fail(f"event #{i} ({ph}): flow event without id")
            side = flow_s if ph == "s" else flow_f
            if fid in side:
                return fail(f"event #{i} ({ph}): duplicate flow id {fid!r}")
            side[fid] = (track, ts)
            continue
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            return fail(
                f"event #{i} ({ph} {e.get('name')!r}): ts {ts} goes "
                f"backwards on track {track} (previous {prev})")
        last_ts[track] = ts

        name = e.get("name")
        if ph == "B":
            stack = stacks.setdefault(track, [])
            parent = stack[-1][2] if stack else None
            slices.append({
                "track": track, "name": name, "t0": ts, "t1": None,
                "args": e.get("args") or {}, "parent": parent,
            })
            stack.append((name, ts, len(slices) - 1))
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                return fail(
                    f"event #{i}: E {name!r} on track {track} without "
                    "an open B")
            open_name, t0, idx = stack.pop()
            if name is not None and name != open_name:
                return fail(
                    f"event #{i}: E {name!r} closes B {open_name!r} on "
                    f"track {track}")
            slices[idx]["t1"] = ts
        elif ph == "X":
            dur = e.get("dur", 0)
            if not isinstance(dur, (int, float)) or dur < 0:
                return fail(f"event #{i} (X): bad dur {dur!r}")
            stack = stacks.get(track) or []
            slices.append({
                "track": track, "name": name, "t0": ts, "t1": ts + dur,
                "args": e.get("args") or {},
                "parent": stack[-1][2] if stack else None,
            })
        elif ph == "C":
            counters += 1

    for track, stack in stacks.items():
        if stack:
            names = [n for n, _, _ in stack]
            return fail(f"unclosed B events on track {track}: {names}")

    # flow pairing: one s + one f per id, causally ordered
    for fid, (_, ts_s) in flow_s.items():
        if fid not in flow_f:
            return fail(f"flow id {fid!r} has an 's' but no 'f'")
        if flow_f[fid][1] < ts_s:
            return fail(
                f"flow id {fid!r}: f.ts {flow_f[fid][1]} before s.ts "
                f"{ts_s} — causality runs backwards")
    for fid in flow_f:
        if fid not in flow_s:
            return fail(f"flow id {fid!r} has an 'f' but no 's'")

    dropped = other.get("dropped", 0)
    if not isinstance(dropped, (int, float)) or dropped < 0:
        return fail(f"otherData.dropped must be a non-negative number, "
                    f"got {dropped!r}")
    print(f"check-trace: {path}: {len(events)} events, {len(slices)} "
          f"spans, {len(flow_s)} flow edges, {counters} counter "
          f"samples, {int(dropped)} dropped")
    if dropped:
        print(f"check-trace: note — the ring dropped {int(dropped)} "
              "events (bounded buffer); raise the capacity or shorten "
              "the capture for a gapless trace")
    if max_dropped is not None and dropped > max_dropped:
        return fail(f"{int(dropped)} dropped events exceeds the "
                    f"--max-dropped {max_dropped} gate")

    spans = [(sl["name"], sl["t0"], sl["t1"]) for sl in slices
             if sl["t1"] is not None]

    if require_overlap:
        preloads = [sp for sp in spans if sp[0] == "preload_part"]
        computes = [sp for sp in spans if sp[0] in COMPUTE_NAMES]
        if not preloads:
            return fail("no preload_part spans (is the loader traced?)")
        if not computes:
            return fail("no step/layer_fetch spans (is the engine "
                        "traced?)")
        hit = any(p[1] < c[2] and c[1] < p[2]
                  for p in preloads for c in computes)
        if not hit:
            return fail(
                f"no preload_part span overlaps a compute span "
                f"({len(preloads)} preload, {len(computes)} compute) — "
                "I/O is not riding under compute")
        print(f"check-trace: overlap ok ({len(preloads)} preload_part, "
              f"{len(computes)} compute spans)")

    if require_flows:
        return check_flows(slices, flow_s, flow_f)

    return 0


def check_flows(slices, flow_s, flow_f):
    """Every attributed flash I/O slice must be reachable from a
    `request` root over flow edges + same-track nesting. Returns an
    exit code."""
    by_track = {}
    for idx, sl in enumerate(slices):
        by_track.setdefault(sl["track"], []).append((idx, sl))

    roots = [i for i, sl in enumerate(slices) if sl["name"] == "request"]
    if not roots:
        return fail("--require-flows: no request root spans in the "
                    "trace (is the scheduler emitting retirement "
                    "roots?)")
    targets = [
        i for i, sl in enumerate(slices)
        if sl["name"] in IO_NAMES and sl["args"].get("req", 0) != 0
    ]
    if not targets:
        return fail("--require-flows: no attributed flash I/O spans "
                    "(io_batch/ondemand_read with args.req != 0) — the "
                    "span-context chain is not reaching the read queue")

    # adjacency: flow edges (s -> f) + nesting (parent -> child)
    adj = {}
    for fid, (track_s, ts_s) in flow_s.items():
        a = bind_endpoint(by_track, track_s, ts_s)
        track_f, ts_f = flow_f[fid]
        b = bind_endpoint(by_track, track_f, ts_f)
        if a is None or b is None:
            return fail(
                f"--require-flows: flow id {fid!r} endpoint binds to no "
                f"slice (s@{track_s}:{ts_s} -> f@{track_f}:{ts_f})")
        adj.setdefault(a, []).append(b)
    for idx, sl in enumerate(slices):
        if sl["parent"] is not None:
            adj.setdefault(sl["parent"], []).append(idx)

    seen = set(roots)
    frontier = list(roots)
    while frontier:
        n = frontier.pop()
        for m in adj.get(n, ()):
            if m not in seen:
                seen.add(m)
                frontier.append(m)

    orphans = [i for i in targets if i not in seen]
    if orphans:
        detail = ", ".join(
            f"{slices[i]['name']}@{slices[i]['track']}:{slices[i]['t0']}"
            for i in orphans[:8])
        return fail(
            f"--require-flows: {len(orphans)}/{len(targets)} attributed "
            f"flash I/O spans unreachable from any request root "
            f"({detail}) — a span lost its causal parent")
    print(f"check-trace: flows ok ({len(roots)} request roots, "
          f"{len(targets)} attributed I/O spans all reachable, "
          f"{len(flow_s)} edges)")
    return 0


def self_test():
    """Validate the committed fixtures: the valid ones must pass (with
    their gate flags), the invalid ones must be rejected."""
    fixtures = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "fixtures")
    cases = [
        # (name, require_overlap, require_flows, want)
        ("trace_valid.json", True, False, 0),
        ("trace_invalid_unbalanced.json", False, False, 1),
        ("trace_invalid_ts.json", False, False, 1),
        ("trace_valid_flows.json", False, True, 0),
        ("trace_invalid_flow_unreachable.json", False, True, 1),
        ("trace_invalid_flow_pairing.json", False, False, 1),
    ]
    rc = 0
    for name, overlap, flows, want in cases:
        path = os.path.join(fixtures, name)
        got = validate(path, require_overlap=overlap,
                       require_flows=flows)
        if got != want:
            print(f"check-trace: SELF-TEST FAIL — {name}: exit {got}, "
                  f"wanted {want}")
            rc = 1
        else:
            print(f"check-trace: self-test {name}: ok (exit {got})")
    if rc == 0:
        print("check-trace: self-test ok")
    return rc


def main(argv):
    argv = list(argv[1:])
    if "--self-test" in argv:
        return self_test()
    require_overlap = "--require-overlap" in argv
    require_flows = "--require-flows" in argv
    argv = [a for a in argv
            if a not in ("--require-overlap", "--require-flows")]
    max_dropped = None
    if "--max-dropped" in argv:
        i = argv.index("--max-dropped")
        try:
            max_dropped = float(argv[i + 1])
        except (IndexError, ValueError):
            print("check-trace: --max-dropped expects a number")
            return 2
        del argv[i:i + 2]
    if len(argv) != 1:
        print(__doc__.strip())
        return 2
    return validate(argv[0], require_overlap=require_overlap,
                    require_flows=require_flows, max_dropped=max_dropped)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
