#!/usr/bin/env python3
"""Flight-recorder trace gate: validate a Chrome trace-event JSON file
(as written by `--trace-out`, the server `trace` command, or the
sched_interleave bench) without needing the Rust toolchain.

Checks:
  * top level is either a bare event array or
    {"traceEvents": [...], "otherData": {...}};
  * every event's "ph" is one of B/E/C/M/X and carries pid/tid
    (metadata "M" events are exempt from ts checks);
  * per (pid, tid) track: "B"/"E" pairs balance as a stack and each
    "E" closes a "B" of the same name;
  * per (pid, tid) track: "ts" is monotone non-decreasing;
  * the ring drop counter in otherData is reported (a dropped-events
    trace is still *valid* — the ring is bounded by design — but the
    count must be surfaced, and --max-dropped can gate it).

With --require-overlap the trace must additionally contain at least one
`preload_part` span that overlaps a compute span (`step` or
`layer_fetch`) in wall time — the observable form of the paper's
I/O-under-compute pipeline (PERF.md §Observability).

Usage: check_trace.py TRACE.json [--require-overlap] [--max-dropped N]
       check_trace.py --self-test

Exit codes: 0 = valid, 1 = invalid trace, 2 = unreadable/malformed input.
"""

import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "pallas_lint")
)
from jsonutil import load_trace_events as load_events  # noqa: E402

PHASES = {"B", "E", "C", "M", "X"}
COMPUTE_NAMES = {"step", "layer_fetch"}


def fail(msg):
    print(f"check-trace: FAIL — {msg}")
    return 1


def validate(path, require_overlap=False, max_dropped=None):
    """Validate one trace file. Returns an exit code."""
    try:
        events, other = load_events(path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check-trace: cannot read {path}: {e}")
        return 2

    stacks = {}   # (pid, tid) -> [(name, ts)]
    last_ts = {}  # (pid, tid) -> ts
    spans = []    # (name, t0, t1) closed durations, all tracks
    counters = 0

    for i, e in enumerate(events):
        if not isinstance(e, dict):
            return fail(f"event #{i} is not an object")
        ph = e.get("ph")
        if ph not in PHASES:
            return fail(f"event #{i}: ph {ph!r} not in {sorted(PHASES)}")
        if "pid" not in e or "tid" not in e:
            return fail(f"event #{i} ({ph}): missing pid/tid")
        if ph == "M":
            continue
        track = (e["pid"], e["tid"])
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            return fail(f"event #{i} ({ph}): bad ts {ts!r}")
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            return fail(
                f"event #{i} ({ph} {e.get('name')!r}): ts {ts} goes "
                f"backwards on track {track} (previous {prev})")
        last_ts[track] = ts

        name = e.get("name")
        if ph == "B":
            stacks.setdefault(track, []).append((name, ts))
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                return fail(
                    f"event #{i}: E {name!r} on track {track} without "
                    "an open B")
            open_name, t0 = stack.pop()
            if name is not None and name != open_name:
                return fail(
                    f"event #{i}: E {name!r} closes B {open_name!r} on "
                    f"track {track}")
            spans.append((open_name, t0, ts))
        elif ph == "X":
            dur = e.get("dur", 0)
            if not isinstance(dur, (int, float)) or dur < 0:
                return fail(f"event #{i} (X): bad dur {dur!r}")
            spans.append((name, ts, ts + dur))
        elif ph == "C":
            counters += 1

    for track, stack in stacks.items():
        if stack:
            names = [n for n, _ in stack]
            return fail(f"unclosed B events on track {track}: {names}")

    dropped = other.get("dropped", 0)
    if not isinstance(dropped, (int, float)) or dropped < 0:
        return fail(f"otherData.dropped must be a non-negative number, "
                    f"got {dropped!r}")
    print(f"check-trace: {path}: {len(events)} events, {len(spans)} "
          f"spans, {counters} counter samples, {int(dropped)} dropped")
    if dropped:
        print(f"check-trace: note — the ring dropped {int(dropped)} "
              "events (bounded buffer); raise the capacity or shorten "
              "the capture for a gapless trace")
    if max_dropped is not None and dropped > max_dropped:
        return fail(f"{int(dropped)} dropped events exceeds the "
                    f"--max-dropped {max_dropped} gate")

    if require_overlap:
        preloads = [sp for sp in spans if sp[0] == "preload_part"]
        computes = [sp for sp in spans if sp[0] in COMPUTE_NAMES]
        if not preloads:
            return fail("no preload_part spans (is the loader traced?)")
        if not computes:
            return fail("no step/layer_fetch spans (is the engine "
                        "traced?)")
        hit = any(p[1] < c[2] and c[1] < p[2]
                  for p in preloads for c in computes)
        if not hit:
            return fail(
                f"no preload_part span overlaps a compute span "
                f"({len(preloads)} preload, {len(computes)} compute) — "
                "I/O is not riding under compute")
        print(f"check-trace: overlap ok ({len(preloads)} preload_part, "
              f"{len(computes)} compute spans)")

    return 0


def self_test():
    """Validate the committed fixtures: the valid one must pass (with
    --require-overlap), the two invalid ones must be rejected."""
    fixtures = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "fixtures")
    cases = [
        ("trace_valid.json", True, 0),
        ("trace_invalid_unbalanced.json", False, 1),
        ("trace_invalid_ts.json", False, 1),
    ]
    rc = 0
    for name, overlap, want in cases:
        path = os.path.join(fixtures, name)
        got = validate(path, require_overlap=overlap)
        if got != want:
            print(f"check-trace: SELF-TEST FAIL — {name}: exit {got}, "
                  f"wanted {want}")
            rc = 1
        else:
            print(f"check-trace: self-test {name}: ok (exit {got})")
    if rc == 0:
        print("check-trace: self-test ok")
    return rc


def main(argv):
    argv = list(argv[1:])
    if "--self-test" in argv:
        return self_test()
    require_overlap = "--require-overlap" in argv
    argv = [a for a in argv if a != "--require-overlap"]
    max_dropped = None
    if "--max-dropped" in argv:
        i = argv.index("--max-dropped")
        try:
            max_dropped = float(argv[i + 1])
        except (IndexError, ValueError):
            print("check-trace: --max-dropped expects a number")
            return 2
        del argv[i:i + 2]
    if len(argv) != 1:
        print(__doc__.strip())
        return 2
    return validate(argv[0], require_overlap=require_overlap,
                    max_dropped=max_dropped)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
