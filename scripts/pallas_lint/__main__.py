"""Entry point: `python3 scripts/pallas_lint [...]` (directory
execution) and `python3 -m pallas_lint [...]` both work — directory
execution runs this file as a bare script, so fall back to absolute
imports there."""

import sys

if __package__ in (None, ""):
    import os

    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    from pallas_lint.cli import main
else:
    from .cli import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
