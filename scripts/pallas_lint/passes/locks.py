"""Pass: lock discipline.

The crate's deadlock-freedom argument (PR 1/3/7 desk-checks) is a set of
file-local disciplines this pass mechanizes:

  nested-lock        a second `.lock()` / RwLock `.read()`/`.write()`
                     acquired while a cache guard is live in the same fn
  nested-lock-call   a call, while a cache guard is live, to a same-crate
                     fn whose body itself acquires a lock (call graph one
                     level deep; ambiguous / common names are skipped)
  banned-cache-dep   `SharedCache` referenced from a banned module
                     (flash/, pipeline/ — workers and the loader reap
                     path must never touch the cache mutex)
  trace-under-guard  `push_batch(` / `.flush()` reachable while a cache
                     guard is live (trace producers must drop the guard
                     before publishing; TraceHandle::push_batch takes the
                     ring lock)

"Cache guard" detection leans on the one asymmetry in the codebase:
`SharedCache::lock()` returns the `MutexGuard` directly (so callsites
read `let g = self.cache.lock();`), while every raw `std::sync::Mutex`
callsite must unwrap poisoning (`.lock().unwrap()`).  A binding ending
in `.lock();` with no `.unwrap()` is therefore a cache guard; its
liveness runs to the end of the enclosing brace block or an explicit
`drop(name)`.
"""

import re
from typing import List

from ..findings import Finding, Project
from ..rustlex import match_brace

NAME = "locks"

GUARD_RE = re.compile(r"\blet\s+(?:mut\s+)?(\w+)\s*=\s*[^;{}]*?\.lock\(\)\s*;")
ACQUIRE_RE = re.compile(r"\.lock\(|\.write\(\s*\)|\.read\(\s*\)")
TRACE_RE = re.compile(r"\bpush_batch\s*\(|\.flush\s*\(")
CALL_RE = re.compile(r"(?<![\w:])([a-z_][a-z0-9_]*)\s*\(")

# Method/fn names too generic to resolve through the one-level call
# graph without type information.
COMMON_NAMES = frozenset(
    "new default insert get remove push pop len clear run main clone "
    "drop write read lock send recv next iter map filter fold count "
    "from into build open close flush wait notify_all notify_one".split()
)


def _direct_acquirers(project: Project) -> dict:
    """fn name -> (file, line) for unambiguous same-crate fns whose body
    directly acquires a lock."""
    seen: dict = {}
    dup = set()
    for sf in project.rust_files():
        for fn in sf.fns:
            if fn.name in COMMON_NAMES or fn.body_start < 0:
                continue
            if fn.name in seen or fn.name in dup:
                dup.add(fn.name)
                seen.pop(fn.name, None)
                continue
            if ACQUIRE_RE.search(fn.body(sf.lx)):
                seen[fn.name] = (sf.relpath, fn.line)
    return seen


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("locks")
    ban_modules = cfg.get("ban_modules", [])
    acquirers = _direct_acquirers(project)

    for sf in project.rust_files():
        rel = sf.relpath
        for mod in ban_modules:
            if rel.startswith(mod.rstrip("/") + "/") or rel == mod:
                for m in re.finditer(r"\bSharedCache\b", sf.lx.code):
                    out.append(
                        Finding(
                            NAME,
                            "banned-cache-dep",
                            rel,
                            sf.lx.line_of(m.start()),
                            f"`SharedCache` referenced under banned module "
                            f"{mod} (workers/loader must never touch the "
                            "cache mutex)",
                        )
                    )
        for fn in sf.fns:
            if fn.body_start < 0:
                continue
            out.extend(_check_fn(project, sf, fn, acquirers))
    return out


def _check_fn(project, sf, fn, acquirers) -> List[Finding]:
    out: List[Finding] = []
    code = sf.lx.code
    for gm in GUARD_RE.finditer(code, fn.body_start, fn.body_end):
        name = gm.group(1)
        live_start = gm.end()
        live_end = _liveness_end(code, gm.start(), fn.body_end, name)
        span = code[live_start:live_end]

        for am in ACQUIRE_RE.finditer(span):
            off = live_start + am.start()
            out.append(
                Finding(
                    NAME,
                    "nested-lock",
                    sf.relpath,
                    sf.lx.line_of(off),
                    f"lock acquired while cache guard `{name}` "
                    f"(bound at line {sf.lx.line_of(gm.start())}) is live",
                    fn=fn.name,
                )
            )
        for tm in TRACE_RE.finditer(span):
            off = live_start + tm.start()
            out.append(
                Finding(
                    NAME,
                    "trace-under-guard",
                    sf.relpath,
                    sf.lx.line_of(off),
                    f"trace publish while cache guard `{name}` is live — "
                    "drop the guard before push_batch/flush (ring lock "
                    "nests under the cache mutex otherwise)",
                    fn=fn.name,
                )
            )
        for cm in CALL_RE.finditer(span):
            callee = cm.group(1)
            if callee == fn.name or callee not in acquirers:
                continue
            # skip macro invocations: `name!(`
            off = live_start + cm.start()
            cfile, cline = acquirers[callee]
            out.append(
                Finding(
                    NAME,
                    "nested-lock-call",
                    sf.relpath,
                    sf.lx.line_of(off),
                    f"call to `{callee}` ({cfile}:{cline}, acquires a "
                    f"lock) while cache guard `{name}` is live",
                    fn=fn.name,
                )
            )
    return out


def _liveness_end(code: str, bind_start: int, fn_body_end: int, name: str):
    """Guard lives from its binding to the close of the innermost
    enclosing brace block, or an earlier explicit `drop(name)`."""
    # innermost enclosing `{`: walk back counting closes
    depth = 0
    open_idx = -1
    i = bind_start - 1
    while i >= 0:
        ch = code[i]
        if ch == "}":
            depth += 1
        elif ch == "{":
            if depth == 0:
                open_idx = i
                break
            depth -= 1
        i -= 1
    if open_idx < 0:
        end = fn_body_end
    else:
        close = match_brace(code, open_idx)
        end = close if close > 0 else fn_body_end
    end = min(end, fn_body_end)
    dm = re.search(r"\bdrop\s*\(\s*" + re.escape(name) + r"\s*\)",
                   code[bind_start:end])
    if dm:
        end = bind_start + dm.start()
    return end
