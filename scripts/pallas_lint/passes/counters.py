"""Pass: counter registry.

Every counter minted in `DecodeMetrics` / `SchedStats` / `IoSnapshot`
exists to be read somewhere — the server `stats` endpoint, a bench JSON
writer, or the check_perf.py trajectory gate.  PRs 6 and 7 showed the
failure mode: a counter lands in the struct and the server, but never
reaches figures.rs or WATCHED, so the perf gate is blind to it.

  counter-unsurfaced   registry field not emitted by the server stats
                       JSON (after normalization + aliases)
  counter-unbenched    registry field reaches neither a bench writer nor
                       check_perf's WATCHED list
  watched-unemitted    a WATCHED / gated key in check_perf.py that no
                       bench writer emits (gate watches a ghost)
  watched-unminted     a WATCHED key that maps to no registry field
                       (typo in the gate)
  stale-field-access   `recv.field` in a bench file where `recv` is a
                       registry struct (per lint.toml receivers) and
                       `field` is not a field or method of that struct —
                       the toolchain-free stand-in for type-checking
                       counter renames at their emission sites
  counter-unexposed    registry field never reaches the Prometheus
                       exposition (lint.toml exposition_files) — the
                       `metrics` endpoint silently under-reports; only
                       checked when exposition_files is configured

Key normalization: strip an `h_` prefix, a trailing `_ns`/`_us`/`_ms`
unit, and `_pNN` percentile segments; IoSnapshot fields also try an
`io_` prefix.  Residual renames are declared in lint.toml aliases.
"""

import ast
import re
from typing import Dict, List, Set

from ..findings import Finding, Project

NAME = "counters"

UNIT_RE = re.compile(r"_(ns|us|ms)$")
PCT_RE = re.compile(r"_p\d+")


def normalize(key: str) -> str:
    k = key
    if k.startswith("h_"):
        k = k[2:]
    k = PCT_RE.sub("", k)
    k = UNIT_RE.sub("", k)
    return k


def _variants(field: str, io_prefixed: bool) -> Set[str]:
    v = {normalize(field)}
    if io_prefixed:
        n = normalize(field)
        v.add("io_" + n)
        if n.startswith("io_"):
            v.add(n[3:])
    return v


def emitted_keys(sf) -> Dict[str, int]:
    """JSON keys from the `("key", value)` obj-tuple idiom: a string
    literal whose previous non-space code char is `(` and next is `,`.
    Returns key -> first line."""
    out: Dict[str, int] = {}
    code = sf.lx.code
    for start, end, line, value in sf.lx.strings:
        if not re.fullmatch(r"[a-z][a-z0-9_]*", value):
            continue
        i = start - 1
        while i >= 0 and code[i].isspace():
            i -= 1
        if i < 0 or code[i] != "(":
            continue
        j = end
        while j < len(code) and code[j].isspace():
            j += 1
        if j >= len(code) or code[j] != ",":
            continue
        out.setdefault(value, line)
    return out


def parse_watched(py_text: str, path: str) -> List[str]:
    """WATCHED plus the hard-gated keys out of check_perf.py, via the
    Python ast — no regexes over Python source."""
    tree = ast.parse(py_text, filename=path)
    watched: List[str] = []
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Assign)
            and any(
                isinstance(t, ast.Name) and t.id == "WATCHED"
                for t in node.targets
            )
            and isinstance(node.value, ast.List)
        ):
            for elt in node.value.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    watched.append(elt.value)
    return watched


def parse_gated(py_text: str, path: str) -> List[str]:
    """Keys check_perf.py indexes out of bench dicts (`prev["key"]` /
    `curr["key"]`) — these hard-gate or feed diffs, so they must exist
    in some bench writer."""
    tree = ast.parse(py_text, filename=path)
    keys: Set[str] = set()
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and node.value.id in ("prev", "curr")
            and isinstance(node.slice, ast.Constant)
            and isinstance(node.slice.value, str)
        ):
            keys.add(node.slice.value)
    return sorted(keys)


def _struct_members(project: Project, struct_name: str, relpath: str):
    """(fields, methods) of a struct, from its declaring file."""
    sf = project.files.get(relpath)
    if sf is None:
        return None, None
    fields = None
    for st in sf.structs:
        if st.name == struct_name:
            fields = st.fields
            break
    methods = {
        fn.name for fn in sf.fns if fn.impl_of == struct_name
    }
    return fields, methods


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("counters")
    if not cfg:
        # no [counters] section: nothing is registered, so there is
        # nothing to cross-check (and no perf gate to look for)
        return out
    aliases: Dict[str, List[str]] = {}
    for ent in cfg.get("aliases", []):
        field_name, _, key = ent.partition("=")
        aliases.setdefault(field_name.strip(), []).append(key.strip())
    skip_fields = set(cfg.get("skip_fields", []))

    # --- registry: struct name -> (file, fields, io_prefixed)
    registry = []
    for ent in cfg.get("registry", []):
        relpath, _, sname = ent.partition(":")
        io_prefixed = sname.startswith("io:")
        sname = sname[3:] if io_prefixed else sname
        fields, _methods = _struct_members(project, sname, relpath)
        if fields is None:
            out.append(
                Finding(
                    NAME, "registry-missing", relpath, 0,
                    f"registry struct `{sname}` not found in {relpath} "
                    "(lint.toml [counters].registry is stale)",
                )
            )
            continue
        registry.append((relpath, sname, fields, io_prefixed))

    # --- emitted key sets
    server_keys: Dict[str, int] = {}
    for relpath in cfg.get("server_files", []):
        sf = project.files.get(relpath)
        if sf is None:
            out.append(Finding(NAME, "registry-missing", relpath, 0,
                               "server file missing from lint tree"))
            continue
        server_keys.update(emitted_keys(sf))

    # --- exposition key set (Prometheus `metrics` endpoint); the check
    # only arms when lint.toml names exposition files, so trees without
    # a metrics endpoint stay green
    expo_aliases: Dict[str, List[str]] = {}
    for ent in cfg.get("exposition_aliases", []):
        field_name, _, key = ent.partition("=")
        expo_aliases.setdefault(field_name.strip(), []).append(key.strip())
    expo_files = cfg.get("exposition_files", [])
    expo_norm: Set[str] = set()
    for relpath in expo_files:
        sf = project.files.get(relpath)
        if sf is None:
            out.append(Finding(NAME, "registry-missing", relpath, 0,
                               "exposition file missing from lint tree"))
            continue
        expo_norm |= {normalize(k) for k in emitted_keys(sf)}

    bench_markers = cfg.get(
        "bench_markers", ["rust/benches/", "bench/figures.rs"]
    )
    bench_keys: Dict[str, int] = {}
    for relpath, sf in sorted(project.files.items()):
        if any(mk in relpath for mk in bench_markers):
            bench_keys.update(emitted_keys(sf))

    perf_rel = cfg.get("perf_gate", "scripts/check_perf.py")
    perf_text = project.read_text(perf_rel)
    watched: List[str] = []
    gated: List[str] = []
    if perf_text is None:
        out.append(Finding(NAME, "registry-missing", perf_rel, 0,
                           "perf gate script missing"))
    else:
        watched = parse_watched(perf_text, perf_rel)
        gated = parse_gated(perf_text, perf_rel)

    server_norm = {normalize(k) for k in server_keys}
    bench_norm = {normalize(k) for k in bench_keys}
    watched_norm = {normalize(k) for k in watched}

    # --- R1/R2: every registry field surfaces
    for relpath, sname, fields, io_prefixed in registry:
        sf = project.files[relpath]
        decl_line = next(
            (st.line for st in sf.structs if st.name == sname), 0
        )
        for field_name in fields:
            if field_name in skip_fields or f"{sname}.{field_name}" in skip_fields:
                continue
            variants = _variants(field_name, io_prefixed)
            for alias in aliases.get(field_name, []):
                variants.add(normalize(alias))
            if not variants & server_norm:
                out.append(
                    Finding(
                        NAME, "counter-unsurfaced", relpath, decl_line,
                        f"{sname}.{field_name} is minted but the server "
                        "stats JSON never emits it (or an alias of it)",
                    )
                )
            if not variants & (bench_norm | watched_norm):
                out.append(
                    Finding(
                        NAME, "counter-unbenched", relpath, decl_line,
                        f"{sname}.{field_name} reaches neither a bench "
                        "JSON writer nor check_perf.py WATCHED — the perf "
                        "trajectory is blind to it",
                    )
                )
            if expo_files:
                evariants = set(variants)
                for alias in expo_aliases.get(field_name, []):
                    evariants.add(normalize(alias))
                if not evariants & expo_norm:
                    out.append(
                        Finding(
                            NAME, "counter-unexposed", relpath, decl_line,
                            f"{sname}.{field_name} never reaches the "
                            "Prometheus exposition (exposition_files) — "
                            "the metrics endpoint under-reports the "
                            "registry",
                        )
                    )

    # --- R3/R5: the gate's keys are real
    all_fields_norm: Set[str] = set()
    for _rel, _sname, fields, io_prefixed in registry:
        for field_name in fields:
            all_fields_norm |= _variants(field_name, io_prefixed)
            for alias in aliases.get(field_name, []):
                all_fields_norm.add(normalize(alias))
    for key in watched + gated:
        if normalize(key) not in bench_norm:
            out.append(
                Finding(
                    NAME, "watched-unemitted", perf_rel, 0,
                    f"check_perf.py reads key {key!r} but no bench writer "
                    "emits it",
                )
            )
    derived_ok = set(cfg.get("derived_keys", []))
    for key in watched:
        if key in derived_ok:
            continue
        if normalize(key) not in all_fields_norm:
            out.append(
                Finding(
                    NAME, "watched-unminted", perf_rel, 0,
                    f"WATCHED key {key!r} maps to no registry counter "
                    "(typo, or declare it in [counters].derived_keys)",
                )
            )

    # --- R4: receiver field accesses in bench files resolve
    for ent in cfg.get("receivers", []):
        file_suffix, recv, sname, srel = ent.split(":")
        fields, methods = _struct_members(project, sname, srel)
        if fields is None:
            continue
        members = set(fields) | methods
        for relpath, sf in sorted(project.files.items()):
            if not relpath.endswith(file_suffix):
                continue
            for m in re.finditer(
                r"\b" + re.escape(recv) + r"\.([a-z_][a-z0-9_]*)", sf.lx.code
            ):
                if m.group(1) not in members:
                    out.append(
                        Finding(
                            NAME, "stale-field-access", relpath,
                            sf.lx.line_of(m.start()),
                            f"`{recv}.{m.group(1)}` does not resolve to a "
                            f"field or method of {sname} — renamed counter "
                            "with a stale emission site?",
                        )
                    )
    return out
