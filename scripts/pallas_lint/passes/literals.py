"""Pass: construction-site exhaustiveness.

Adding a field to a config struct means updating every literal that
builds one — ~19 `EngineOptions` sites across src, benches, and tests.
Without a compiler, a missed site silently ships a stale literal in a
test nobody can run.  This pass re-checks every site on every lint run:

  missing-field   a non-`..`-spread literal of a tracked struct omits a
                  declared field
  unknown-field   a literal names a field the declaration lacks
                  (renamed field with stale sites)
  struct-missing  lint.toml tracks a struct the tree no longer declares
  unmapped-flag   a CLI flag string in main.rs absent from the
                  lint.toml [cli_flags] round-trip map
  flag-bad-field  a [cli_flags] entry whose target field is not declared
                  by any tracked struct
  stale-flag-map  a [cli_flags] entry whose flag no longer appears in
                  main.rs

Literals with a depth-1 `..spread` tail are exempt by design (that is
the idiom for "defaults plus overrides").  Match patterns need no
special casing: a pattern listing fields without `..` that missed one
would not compile, so any pattern we see is either complete or spread.
"""

import re
from typing import Dict, List

from ..findings import Finding, Project
from ..items import parse_field_names
from ..rustlex import match_brace

NAME = "literals"

FLAG_RE = re.compile(
    r"\b(?:opt_or|opt_usize|opt_f64|opt|has_flag)\s*\(\s*$"
)


def _literal_sites(sf, struct_name: str):
    """(offset_of_open_brace, line) for each `Name {` that is a value
    (not the declaration, an impl header, or `for Name`)."""
    sites = []
    code = sf.lx.code
    for m in re.finditer(r"\b" + re.escape(struct_name) + r"\s*\{", code):
        before = code[: m.start()].rstrip()
        # declaration (`struct Name {`), impl header, trait-impl target,
        # or return-type position (`-> Name {` opens the fn body, not a
        # literal) — not construction sites
        if re.search(r"\b(struct|impl|for|enum|union|trait)\s*$", before):
            continue
        if before.endswith("->"):
            continue
        brace = code.index("{", m.start())
        sites.append((brace, sf.lx.line_of(m.start())))
    return sites


def _spread_at_depth1(body: str) -> bool:
    depth = 0
    i = 0
    while i < len(body):
        ch = body[i]
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "." and depth == 0 and body[i : i + 2] == "..":
            return True
        i += 1
    return False


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("literals")
    tracked: List[str] = list(cfg.get("structs", []))

    decls: Dict[str, List[str]] = {}
    decl_where: Dict[str, str] = {}
    for sf in project.rust_files():
        for st in sf.structs:
            if st.name in tracked and st.name not in decls:
                decls[st.name] = st.fields
                decl_where[st.name] = f"{sf.relpath}:{st.line}"

    for sname in tracked:
        if sname not in decls:
            out.append(
                Finding(
                    NAME, "struct-missing", "lint.toml", 0,
                    f"[literals].structs tracks `{sname}` but no "
                    "declaration was found in the lint tree",
                )
            )

    for sf in project.rust_files():
        for sname, fields in decls.items():
            declared = set(fields)
            for brace, line in _literal_sites(sf, sname):
                end = match_brace(sf.lx.code, brace)
                if end < 0:
                    continue
                body = sf.lx.code[brace + 1 : end]
                if _spread_at_depth1(body):
                    continue
                present = parse_field_names(body)
                missing = [f for f in fields if f not in present]
                extra = [f for f in present if f not in declared]
                if missing:
                    out.append(
                        Finding(
                            NAME, "missing-field", sf.relpath, line,
                            f"`{sname}` literal omits "
                            f"{', '.join(missing)} (declared at "
                            f"{decl_where[sname]}; add the field or use "
                            "`..` defaults)",
                        )
                    )
                for f in extra:
                    out.append(
                        Finding(
                            NAME, "unknown-field", sf.relpath, line,
                            f"`{sname}` literal sets `{f}` which the "
                            f"declaration at {decl_where[sname]} lacks",
                        )
                    )

    out.extend(_check_flags(project, decls))
    return out


def _check_flags(project: Project, decls) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("cli_flags")
    main_rel = cfg.get("main", "rust/src/main.rs")
    sf = project.files.get(main_rel)
    if sf is None:
        return out
    mapping: Dict[str, str] = {}
    for ent in cfg.get("map", []):
        flag, _, target = ent.partition("=")
        mapping[flag.strip()] = target.strip()

    # flags actually parsed in main.rs: string literal that is the first
    # argument of an args.opt*/has_flag call
    seen_flags: Dict[str, int] = {}
    code = sf.lx.code
    for start, _end, line, value in sf.lx.strings:
        if FLAG_RE.search(code[:start]):
            seen_flags.setdefault(value, line)

    all_fields = set()
    for fields in decls.values():
        all_fields.update(fields)

    for flag, line in sorted(seen_flags.items()):
        if flag not in mapping:
            out.append(
                Finding(
                    NAME, "unmapped-flag", main_rel, line,
                    f"CLI flag --{flag} has no [cli_flags] round-trip "
                    "entry — map it to the config field it feeds (or "
                    "`special:<why>` if it is not config-backed)",
                )
            )
    for flag, target in sorted(mapping.items()):
        if flag not in seen_flags:
            out.append(
                Finding(
                    NAME, "stale-flag-map", main_rel, 0,
                    f"[cli_flags] maps --{flag} but main.rs no longer "
                    "parses that flag",
                )
            )
            continue
        if target.startswith("special:"):
            continue
        field = target.split(".")[-1]
        if field not in all_fields:
            out.append(
                Finding(
                    NAME, "flag-bad-field", main_rel, seen_flags[flag],
                    f"--{flag} maps to `{target}` but no tracked config "
                    "struct declares that field",
                )
            )
    return out
