"""Pass: structural sanity.

The cheap checks that catch a botched merge or hand-edit before any
deeper pass wastes time on garbled input:

  unbalanced          delimiters don't balance in a file's code view
                      (strings/comments already excluded)
  missing-module-file lib.rs declares `mod x;` but neither src/x.rs nor
                      src/x/mod.rs exists
  undeclared-module   a src/ subdirectory with a mod.rs that lib.rs
                      never declares (dead tree shipping in the repo)
  dup-test-name       two `#[test]` fns with the same name in one file —
                      the second silently shadows nothing but will not
                      compile; in a toolchain-less container that means
                      it ships broken
"""

import os
import re
from collections import Counter
from typing import List

from ..findings import Finding, Project
from ..rustlex import check_balance

NAME = "structure"

MOD_RE = re.compile(r"^\s*(?:pub\s+)?mod\s+([a-z_][a-z0-9_]*)\s*;", re.M)


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []

    for sf in project.rust_files():
        for line, msg in check_balance(sf.lx):
            out.append(Finding(NAME, "unbalanced", sf.relpath, line, msg))

        tests = [fn for fn in sf.fns if fn.is_test]
        counts = Counter(fn.name for fn in tests)
        flagged = set()
        for fn in tests:
            if counts[fn.name] > 1 and fn.name not in flagged:
                flagged.add(fn.name)
                lines = [str(f.line) for f in tests if f.name == fn.name]
                out.append(
                    Finding(
                        NAME, "dup-test-name", sf.relpath, fn.line,
                        f"#[test] fn `{fn.name}` defined "
                        f"{counts[fn.name]}x in this file "
                        f"(lines {', '.join(lines)}) — will not compile",
                        fn=fn.name,
                    )
                )

    out.extend(_check_lib_wiring(project))
    return out


def _check_lib_wiring(project: Project) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("structure")
    lib_rel = cfg.get("lib", "rust/src/lib.rs")
    src_rel = os.path.dirname(lib_rel)
    sf = project.files.get(lib_rel)
    if sf is None:
        return out

    declared = {}
    for m in MOD_RE.finditer(sf.lx.code):
        declared[m.group(1)] = sf.lx.line_of(m.start())

    src_abs = os.path.join(project.root, src_rel)
    for name, line in sorted(declared.items()):
        file_form = os.path.join(src_abs, name + ".rs")
        dir_form = os.path.join(src_abs, name, "mod.rs")
        if not (os.path.exists(file_form) or os.path.exists(dir_form)):
            out.append(
                Finding(
                    NAME, "missing-module-file", lib_rel, line,
                    f"lib.rs declares `mod {name};` but neither "
                    f"{src_rel}/{name}.rs nor {src_rel}/{name}/mod.rs "
                    "exists",
                )
            )

    if os.path.isdir(src_abs):
        for entry in sorted(os.listdir(src_abs)):
            sub = os.path.join(src_abs, entry)
            if os.path.isdir(sub) and os.path.exists(
                os.path.join(sub, "mod.rs")
            ):
                if entry not in declared:
                    out.append(
                        Finding(
                            NAME, "undeclared-module",
                            f"{src_rel}/{entry}/mod.rs", 1,
                            f"module directory `{entry}/` has a mod.rs "
                            "but lib.rs never declares it — dead tree",
                        )
                    )
    return out
