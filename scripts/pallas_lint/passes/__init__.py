"""Pass registry.

A pass is a module exposing ``NAME`` (str) and ``run(project) ->
List[Finding]``.  Adding a pass = write the module, import it here,
append to PASSES, document the invariant in LINT.md.  Every finding
carries the pass name so allowlist entries bind to it.
"""

from . import counters, hotpath, literals, locks, structure

PASSES = [structure, locks, counters, literals, hotpath]

BY_NAME = {p.NAME: p for p in PASSES}
