"""Pass: hot-path hygiene.

Functions on the per-token decode path are annotated
`// pallas-lint: hot-path` (comment directly above the fn, attributes in
between are fine).  Inside an annotated fn:

  hot-unwrap      `.unwrap()` / `.expect(` / `panic!` / `unreachable!` /
                  `todo!` — a panic on the decode path kills the whole
                  engine mid-wave.  Built-in idiom allowance: an unwrap
                  directly chained onto `.lock()` or `.wait(..)` is the
                  std mutex/condvar poisoning idiom (poisoning only
                  happens after another thread already panicked) and is
                  not flagged.
  hot-alloc       an allocation call (`Vec::new`, `vec![`,
                  `with_capacity`, `String::new`, `format!`, `.to_vec(`,
                  `Box::new`, `.collect(`) inside a `for`/`while`/`loop`
                  body — per-iteration allocation on the per-token path
                  is the death-by-a-thousand-mallocs the slab recycler
                  exists to prevent.
  missing-annotation  a fn listed in lint.toml [hotpath].required lacks
                  the annotation — the seeded annotation set can only
                  grow, never silently disappear.
"""

import re
from typing import List

from ..findings import Finding, Project
from ..rustlex import match_brace

NAME = "hotpath"

PANIC_RE = re.compile(
    r"\.unwrap\s*\(|\.expect\s*\(|\bpanic!\s*[(\[]|\bunreachable!\s*[(\[]"
    r"|\btodo!\s*[(\[]"
)
ALLOW_CHAIN_RE = re.compile(r"(?:\.lock\s*\(\s*\)|\.wait\s*\([^()]*\))\s*$")
ALLOC_RE = re.compile(
    r"\bVec\s*::\s*new\s*\(|\bvec!\s*[\[(]|\bwith_capacity\s*\("
    r"|\bString\s*::\s*new\s*\(|\bformat!\s*\(|\.to_vec\s*\("
    r"|\bBox\s*::\s*new\s*\(|\.collect\s*[::<(]"
)
LOOP_RE = re.compile(r"\b(for|while|loop)\b")


def _loop_body_ranges(code: str, start: int, end: int):
    """Brace ranges of for/while/loop bodies inside [start, end)."""
    ranges = []
    for m in LOOP_RE.finditer(code, start, end):
        # find the body `{` at depth 0 from the keyword (loop: immediate;
        # for/while: after the header expression)
        i = m.end()
        depth = 0
        while i < end:
            ch = code[i]
            if ch in "([":
                depth += 1
            elif ch in ")]":
                depth = max(0, depth - 1)
            elif ch == "{" and depth == 0:
                close = match_brace(code, i)
                if close > 0:
                    ranges.append((i, close))
                break
            elif ch == ";" and depth == 0:
                break  # `while let` desugars never hit this; labels do
            i += 1
    return ranges


def run(project: Project) -> List[Finding]:
    out: List[Finding] = []
    cfg = project.config.section("hotpath")

    annotated = {}  # (relpath, fn_name) -> True
    for sf in project.rust_files():
        for fn in sf.fns:
            if any(a.startswith("hot-path") for a in fn.annotations):
                annotated[(sf.relpath, fn.name)] = True
                if fn.body_start < 0:
                    continue
                out.extend(_check_body(sf, fn))

    for ent in cfg.get("required", []):
        relpath, _, fn_name = ent.partition(":")
        if (relpath, fn_name) not in annotated:
            out.append(
                Finding(
                    NAME, "missing-annotation", relpath, 0,
                    f"`{fn_name}` is required to carry "
                    "`// pallas-lint: hot-path` (lint.toml "
                    "[hotpath].required) but the annotation is missing",
                    fn=fn_name,
                )
            )
    return out


def _check_body(sf, fn) -> List[Finding]:
    out: List[Finding] = []
    code = sf.lx.code
    for m in PANIC_RE.finditer(code, fn.body_start, fn.body_end):
        before = code[fn.body_start : m.start()]
        if m.group(0).startswith((".unwrap", ".expect")) and \
                ALLOW_CHAIN_RE.search(before):
            continue  # lock/condvar poisoning idiom
        what = m.group(0).rstrip("([ ")
        out.append(
            Finding(
                NAME, "hot-unwrap", sf.relpath, sf.lx.line_of(m.start()),
                f"`{what}` inside hot-path fn — a panic here kills the "
                "decode loop mid-wave; bubble an error instead",
                fn=fn.name,
            )
        )
    seen_offsets = set()  # nested loops: report each alloc site once
    for lo, hi in _loop_body_ranges(code, fn.body_start, fn.body_end):
        for m in ALLOC_RE.finditer(code, lo, hi):
            if m.start() in seen_offsets:
                continue
            seen_offsets.add(m.start())
            what = m.group(0).rstrip("([:< ")
            out.append(
                Finding(
                    NAME, "hot-alloc", sf.relpath, sf.lx.line_of(m.start()),
                    f"`{what}` allocates per loop iteration inside a "
                    "hot-path fn — hoist it or use the slab/recycler",
                    fn=fn.name,
                )
            )
    return out
