"""pallas-lint driver.

    python3 scripts/pallas_lint [--root DIR] [--config lint.toml]
                                [--json] [--self-test] [--pass NAME]

Exit codes mirror the other gates: 0 = clean, 1 = findings (or a failed
self-test case), 2 = config/usage error.  Findings print as
``file:line: [pass/code] message`` so CI logs are clickable.
"""

import json
import os
import sys

from .config import ConfigError, LintConfig, load_config
from .findings import Project, apply_suppressions
from .passes import BY_NAME, PASSES


def run_passes(project, only=None):
    findings = []
    for p in PASSES:
        if only and p.NAME not in only:
            continue
        findings.extend(p.run(project))
    findings.sort(key=lambda f: (f.file, f.line, f.passname, f.code))
    return apply_suppressions(project, findings)


def lint_tree(root, config_path, only=None):
    config = load_config(config_path)
    project = Project(root, config).load_tree()
    if not project.files:
        print(f"pallas-lint: no Rust files under {config.rust_roots} "
              f"(root {root}) — nothing to lint", file=sys.stderr)
        return None
    return run_passes(project, only=only)


def print_text(res):
    for f in res.active:
        print(f.render())
    for f in res.stale_allows:
        print(f.render())
    n_act = len(res.active) + len(res.stale_allows)
    n_sup = len(res.suppressed)
    if n_act:
        print(f"pallas-lint: FAIL — {n_act} finding(s) "
              f"({n_sup} suppressed by allowlist)")
    else:
        print(f"pallas-lint: ok ({n_sup} finding(s) suppressed by "
              "allowlist)")
    return 1 if n_act else 0


def print_json(res):
    out = {
        "ok": not res.active and not res.stale_allows,
        "findings": [f.as_json() for f in res.active],
        "stale_allows": [f.as_json() for f in res.stale_allows],
        "suppressed": [f.as_json() for f in res.suppressed],
    }
    print(json.dumps(out, indent=2, sort_keys=True))
    return 0 if out["ok"] else 1


def self_test(root, only=None):
    """Run every fixture case under scripts/fixtures/lint/: each case dir
    carries its own lint.toml plus expect.json with the exact findings
    (pass/code/file/line) the case must produce. Good cases expect []."""
    fixdir = os.path.join(root, "scripts", "fixtures", "lint")
    if not os.path.isdir(fixdir):
        print(f"pallas-lint: fixture dir {fixdir} missing", file=sys.stderr)
        return 2
    cases = sorted(
        d for d in os.listdir(fixdir)
        if os.path.isdir(os.path.join(fixdir, d))
    )
    if not cases:
        print("pallas-lint: no fixture cases", file=sys.stderr)
        return 2
    failed = 0
    for case in cases:
        cdir = os.path.join(fixdir, case)
        expect_path = os.path.join(cdir, "expect.json")
        config_path = os.path.join(cdir, "lint.toml")
        if not os.path.exists(expect_path):
            print(f"self-test: {case}: missing expect.json")
            failed += 1
            continue
        with open(expect_path) as f:
            expect = json.load(f)
        try:
            if os.path.exists(config_path):
                config = load_config(config_path)
            else:
                config = LintConfig(raw={}, rust_roots=["."])
            project = Project(cdir, config).load_tree()
            res = run_passes(project, only=only)
        except Exception as e:  # a crash on a fixture is a failure too
            print(f"self-test: {case}: CRASH {type(e).__name__}: {e}")
            failed += 1
            continue
        got = sorted(
            (f.passname, f.code, f.file, f.line)
            for f in res.active + res.stale_allows
        )
        want = sorted(
            (e["pass"], e["code"], e["file"], e["line"])
            for e in expect.get("findings", [])
        )
        want_sup = expect.get("suppressed")
        sup_ok = (
            want_sup is None or len(res.suppressed) == want_sup
        )
        if got == want and sup_ok:
            n = len(want)
            print(f"self-test: {case}: ok "
                  f"({n} expected finding(s), {len(res.suppressed)} "
                  "suppressed)")
        else:
            failed += 1
            print(f"self-test: {case}: MISMATCH")
            for t in want:
                if t not in got:
                    print(f"  missing  {t[2]}:{t[3]}: [{t[0]}/{t[1]}]")
            for t in got:
                if t not in want:
                    print(f"  unexpected  {t[2]}:{t[3]}: [{t[0]}/{t[1]}]")
            if not sup_ok:
                print(f"  suppressed: want {want_sup}, "
                      f"got {len(res.suppressed)}")
    print(f"self-test: {len(cases) - failed}/{len(cases)} cases ok")
    return 1 if failed else 0


def main(argv):
    argv = list(argv)
    root = "."
    config_path = None
    as_json = False
    do_self_test = False
    only = None
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--root":
            i += 1
            root = argv[i]
        elif a == "--config":
            i += 1
            config_path = argv[i]
        elif a == "--json":
            as_json = True
        elif a == "--self-test":
            do_self_test = True
        elif a == "--pass":
            i += 1
            if argv[i] not in BY_NAME:
                print(f"pallas-lint: unknown pass {argv[i]!r} "
                      f"(have: {', '.join(sorted(BY_NAME))})",
                      file=sys.stderr)
                return 2
            only = {argv[i]}
        elif a in ("-h", "--help"):
            print(__doc__.strip())
            return 0
        else:
            print(f"pallas-lint: unknown argument {a!r}", file=sys.stderr)
            return 2
        i += 1

    if do_self_test:
        return self_test(root, only=only)

    if config_path is None:
        config_path = os.path.join(root, "lint.toml")
    if not os.path.exists(config_path):
        print(f"pallas-lint: config {config_path} missing", file=sys.stderr)
        return 2
    try:
        res = lint_tree(root, config_path, only=only)
    except ConfigError as e:
        print(f"pallas-lint: config error: {e}", file=sys.stderr)
        return 2
    if res is None:
        return 2
    return print_json(res) if as_json else print_text(res)
