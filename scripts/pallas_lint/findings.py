"""Finding model, the per-run Project container, and suppression logic.

A finding is suppressed either by a `[[allow]]` entry in lint.toml
(pass+code+file-suffix, optional fn / detail-substring narrowing, `why`
required) or by an inline `// pallas-lint: allow(code)` comment on the
finding's line or the line above.  Allow entries that match nothing are
themselves reported (`stale-allow`) so the allowlist can only shrink as
violations get fixed, never silently rot.
"""

import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .config import LintConfig
from .items import extract_fns, extract_structs
from .rustlex import lex


@dataclass
class Finding:
    passname: str
    code: str
    file: str      # repo-relative path
    line: int
    message: str
    fn: Optional[str] = None
    suppressed_by: Optional[str] = None

    def render(self) -> str:
        loc = f"{self.file}:{self.line}"
        return f"{loc}: [{self.passname}/{self.code}] {self.message}"

    def as_json(self) -> dict:
        d = {
            "pass": self.passname,
            "code": self.code,
            "file": self.file,
            "line": self.line,
            "message": self.message,
        }
        if self.fn:
            d["fn"] = self.fn
        if self.suppressed_by:
            d["suppressed_by"] = self.suppressed_by
        return d


INLINE_ALLOW_RE = re.compile(r"//\s*pallas-lint:\s*allow\(([a-z0-9_,\s-]+)\)")


class SourceFile:
    """A lexed Rust file plus lazily-extracted items."""

    def __init__(self, relpath: str, text: str):
        self.relpath = relpath
        self.lx = lex(relpath, text)
        self._fns = None
        self._structs = None

    @property
    def fns(self):
        if self._fns is None:
            self._fns = extract_fns(self.lx)
        return self._fns

    @property
    def structs(self):
        if self._structs is None:
            self._structs = extract_structs(self.lx)
        return self._structs

    def enclosing_fn(self, offset: int):
        for fn in self.fns:
            if fn.body_start >= 0 and fn.body_start <= offset < fn.body_end:
                return fn
        return None

    def inline_allows(self, line: int) -> List[str]:
        """Codes allowed by an inline comment on `line` or the line
        directly above."""
        codes = []
        for ln in (line, line - 1):
            txt = self.lx.comment_by_line.get(ln, "")
            m = INLINE_ALLOW_RE.search(txt)
            if m:
                codes.extend(c.strip() for c in m.group(1).split(","))
        return codes


class Project:
    """Everything a pass needs: the lexed Rust tree, the config, and the
    repo root for passes that read non-Rust files (check_perf.py,
    Makefile, ci.yml)."""

    def __init__(self, root: str, config: LintConfig):
        self.root = root
        self.config = config
        self.files: Dict[str, SourceFile] = {}

    def add_file(self, relpath: str, text: str):
        self.files[relpath] = SourceFile(relpath, text)

    def load_tree(self):
        for rel_root in self.config.rust_roots:
            absroot = os.path.join(self.root, rel_root)
            if not os.path.isdir(absroot):
                continue
            for dirpath, _dirnames, filenames in os.walk(absroot):
                for name in sorted(filenames):
                    if not name.endswith(".rs"):
                        continue
                    ap = os.path.join(dirpath, name)
                    rel = os.path.relpath(ap, self.root)
                    with open(ap, encoding="utf-8") as f:
                        self.add_file(rel, f.read())
        return self

    def read_text(self, relpath: str) -> Optional[str]:
        ap = os.path.join(self.root, relpath)
        if not os.path.exists(ap):
            return None
        with open(ap, encoding="utf-8") as f:
            return f.read()

    def rust_files(self) -> List[SourceFile]:
        return [self.files[k] for k in sorted(self.files)]


@dataclass
class RunResult:
    findings: List[Finding] = field(default_factory=list)
    stale_allows: List[Finding] = field(default_factory=list)

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed_by is None]

    @property
    def suppressed(self) -> List[Finding]:
        return [f for f in self.findings if f.suppressed_by is not None]


def apply_suppressions(project: Project, findings: List[Finding]) -> RunResult:
    res = RunResult(findings=findings)
    for f in findings:
        sf = project.files.get(f.file)
        if sf is not None and f.code in sf.inline_allows(f.line):
            f.suppressed_by = f"inline allow at {f.file}:{f.line}"
            continue
        for ent in project.config.allow:
            if ent.matches(f):
                ent.used = True
                f.suppressed_by = f"{ent.origin} ({ent.why})"
                break
    for ent in project.config.allow:
        if not ent.used:
            res.stale_allows.append(
                Finding(
                    passname="allowlist",
                    code="stale-allow",
                    file="lint.toml",
                    line=0,
                    message=(
                        f"allow entry matches nothing "
                        f"(pass={ent.passname} code={ent.code} "
                        f"file={ent.file}"
                        + (f" fn={ent.fn}" if ent.fn else "")
                        + f"): {ent.why!r} — delete it"
                    ),
                )
            )
    return res
