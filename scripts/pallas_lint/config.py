"""lint.toml loading.

The container's Python is 3.10 (no tomllib), so this ships a parser for
the small TOML subset lint.toml actually uses: `[section]` /
`[section.sub]` tables, `[[array-of-tables]]`, and `key = value` where
value is a string, int, bool, or a (possibly multi-line) list of
strings.  Unknown syntax is a hard error — a silently misparsed config
is worse than no linter.
"""

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class ConfigError(Exception):
    pass


_KEY_RE = re.compile(r"^[A-Za-z0-9_-]+$")


def parse_toml(text: str, where: str = "lint.toml") -> dict:
    root: dict = {}
    current = root
    lines = text.splitlines()
    i = 0
    n = len(lines)
    while i < n:
        raw = lines[i]
        line = _strip_comment(raw).strip()
        i += 1
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise ConfigError(f"{where}:{i}: malformed table array {raw!r}")
            path = line[2:-2].strip()
            parent, leaf = _descend(root, path, where, i)
            arr = parent.setdefault(leaf, [])
            if not isinstance(arr, list):
                raise ConfigError(f"{where}:{i}: {path} is not a table array")
            current = {}
            arr.append(current)
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise ConfigError(f"{where}:{i}: malformed table header {raw!r}")
            path = line[1:-1].strip()
            parent, leaf = _descend(root, path, where, i)
            tbl = parent.setdefault(leaf, {})
            if not isinstance(tbl, dict):
                raise ConfigError(f"{where}:{i}: {path} is not a table")
            current = tbl
            continue
        if "=" not in line:
            raise ConfigError(f"{where}:{i}: expected key = value, got {raw!r}")
        key, _, rest = line.partition("=")
        key = key.strip().strip('"')
        if not _KEY_RE.match(key):
            raise ConfigError(f"{where}:{i}: bad key {key!r}")
        rest = rest.strip()
        # multi-line list: keep consuming until brackets balance
        while rest.count("[") > rest.count("]"):
            if i >= n:
                raise ConfigError(f"{where}:{i}: unterminated list for {key}")
            rest += " " + _strip_comment(lines[i]).strip()
            i += 1
        current[key] = _parse_value(rest, where, i)
    return root


def _descend(root, path, where, lineno):
    parts = [p.strip() for p in path.split(".")]
    if not all(_KEY_RE.match(p) for p in parts):
        raise ConfigError(f"{where}:{lineno}: bad table path {path!r}")
    node = root
    for p in parts[:-1]:
        node = node.setdefault(p, {})
        if not isinstance(node, dict):
            raise ConfigError(f"{where}:{lineno}: {p} is not a table")
    return node, parts[-1]


def _strip_comment(line: str) -> str:
    out = []
    in_str = False
    i = 0
    while i < len(line):
        ch = line[i]
        if ch == '"' and (i == 0 or line[i - 1] != "\\"):
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
        i += 1
    return "".join(out)


def _parse_value(v: str, where: str, lineno: int):
    v = v.strip()
    if v.startswith('"') and v.endswith('"') and len(v) >= 2:
        return v[1:-1].replace('\\"', '"').replace("\\\\", "\\")
    if v in ("true", "false"):
        return v == "true"
    if v.startswith("[") and v.endswith("]"):
        inner = v[1:-1].strip()
        if not inner:
            return []
        items = []
        for part in _split_list(inner):
            items.append(_parse_value(part, where, lineno))
        return items
    try:
        return int(v.replace("_", ""))
    except ValueError:
        raise ConfigError(f"{where}:{lineno}: unsupported value {v!r}")


def _split_list(inner: str) -> List[str]:
    parts = []
    buf = []
    in_str = False
    for i, ch in enumerate(inner):
        if ch == '"' and (i == 0 or inner[i - 1] != "\\"):
            in_str = not in_str
        if ch == "," and not in_str:
            parts.append("".join(buf).strip())
            buf = []
            continue
        buf.append(ch)
    tail = "".join(buf).strip()
    if tail:
        parts.append(tail)
    return parts


@dataclass
class AllowEntry:
    passname: str
    code: str
    file: str                 # path suffix match
    why: str
    fn: Optional[str] = None
    detail: Optional[str] = None   # substring of the finding message
    used: bool = False
    origin: str = ""          # "lint.toml#<index>" for stale reporting

    def matches(self, finding) -> bool:
        if self.passname != finding.passname or self.code != finding.code:
            return False
        if not finding.file.endswith(self.file):
            return False
        if self.fn is not None and self.fn != (finding.fn or ""):
            return False
        if self.detail is not None and self.detail not in finding.message:
            return False
        return True


@dataclass
class LintConfig:
    raw: dict = field(default_factory=dict)
    rust_roots: List[str] = field(default_factory=list)
    allow: List[AllowEntry] = field(default_factory=list)

    def section(self, name: str) -> dict:
        sec = self.raw.get(name, {})
        return sec if isinstance(sec, dict) else {}


def load_config(path: str) -> LintConfig:
    with open(path) as f:
        raw = parse_toml(f.read(), where=path)
    cfg = LintConfig(raw=raw)
    proj = raw.get("project", {})
    cfg.rust_roots = list(proj.get("rust_roots", ["rust/src"]))
    for idx, ent in enumerate(raw.get("allow", [])):
        if not isinstance(ent, dict):
            raise ConfigError(f"{path}: [[allow]] #{idx} is not a table")
        for req in ("pass", "code", "file", "why"):
            if req not in ent:
                raise ConfigError(
                    f"{path}: [[allow]] #{idx} missing required key "
                    f"{req!r} (every suppression needs a justification)"
                )
        if not str(ent["why"]).strip():
            raise ConfigError(
                f"{path}: [[allow]] #{idx} has an empty `why` — every "
                "suppression carries a one-line justification"
            )
        cfg.allow.append(
            AllowEntry(
                passname=ent["pass"],
                code=ent["code"],
                file=ent["file"],
                why=ent["why"],
                fn=ent.get("fn"),
                detail=ent.get("detail"),
                origin=f"{path}#allow[{idx}]",
            )
        )
    return cfg
