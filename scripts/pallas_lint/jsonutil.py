"""JSON-reading helpers shared by the three stdlib-Python gates
(check_perf.py, check_trace.py, pallas-lint) so every gate parses bench
points and trace files identically.

Import from the gate scripts via:

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "pallas_lint"))
    import jsonutil

(kept importable both as ``pallas_lint.jsonutil`` and as a top-level
``jsonutil`` module so the flat gate scripts need no package install).
"""

import json
import os


def read_json(path):
    """Parse one JSON file. Propagates OSError / JSONDecodeError — the
    gates decide whether malformed input is exit-2 fatal."""
    with open(path) as f:
        return json.load(f)


def load_pair(prev_path, curr_path, what, tag="check-perf"):
    """Baseline-rotation helper: returns (prev, curr) dicts, or None when
    there is no previous point yet (first run records the baseline; the
    caller treats a missing *current* point as its own error)."""
    if not os.path.exists(prev_path):
        print(f"{tag}: no previous {what} point ({prev_path}); "
              "nothing to diff — baseline recorded")
        return None
    prev = read_json(prev_path)
    curr = read_json(curr_path)
    return prev, curr


def load_trace_events(path):
    """Chrome-trace loader: returns (events, other_data). Accepts both
    the bare-array form and the object form with a `traceEvents` key.
    Raises ValueError on anything else."""
    v = read_json(path)
    if isinstance(v, list):
        return v, {}
    if isinstance(v, dict):
        events = v.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("object form needs a traceEvents array")
        other = v.get("otherData", {})
        if not isinstance(other, dict):
            raise ValueError("otherData must be an object")
        return events, other
    raise ValueError("top level must be an array or an object")


def rel_delta(prev, curr):
    """Relative change curr vs prev, or None when prev is 0/invalid —
    the shared guard all the perf diffs use before printing a %."""
    try:
        p, c = float(prev), float(curr)
    except (TypeError, ValueError):
        return None
    if p <= 0:
        return None
    return (c - p) / p
