r"""Comment/string/char-literal-aware Rust lexer.

The passes never want to see the *inside* of a comment or a string when
they scan for code patterns (`.lock()`, `EngineOptions {`, ...), but the
counter-registry pass wants exactly the opposite — the emitted JSON key
strings.  So one scan produces both views of a file:

  * ``code``     — the source with every comment and every string/char
                   literal body replaced by spaces.  Char positions and
                   line numbers are IDENTICAL to the original file, so a
                   regex hit in ``code`` maps straight back to a
                   clickable file:line.
  * ``strings``  — every string literal as (start, end, line, value).
  * ``comments`` — every comment as (start, line, text), doc comments
                   included (the hot-path annotations and inline
                   suppressions live here).

Handles: line comments, nested block comments, ``"..."`` with escapes,
``r"..."`` / ``r#"..."#`` raw strings (any hash depth), byte strings
``b"..."`` / ``br#"..."#``, char literals ``'x'`` ``'\n'`` ``'\u{..}'``
``b'x'``, and tells lifetimes/labels (``'a``, ``'outer:``) apart from
char literals.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass
class LexedFile:
    path: str
    text: str
    code: str
    # (start, end_exclusive, line, value)
    strings: List[Tuple[int, int, int, str]] = field(default_factory=list)
    # (start, line, text)
    comments: List[Tuple[int, int, str]] = field(default_factory=list)
    # line -> comment text (last comment starting on that line)
    comment_by_line: dict = field(default_factory=dict)

    def line_of(self, offset: int) -> int:
        """1-based line number of a char offset (binary search)."""
        lo, hi = 0, len(self._line_starts) - 1
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self._line_starts[mid] <= offset:
                lo = mid
            else:
                hi = mid - 1
        return lo + 1

    def finish(self):
        starts = [0]
        for i, ch in enumerate(self.text):
            if ch == "\n":
                starts.append(i + 1)
        self._line_starts = starts
        for off, line, txt in self.comments:
            self.comment_by_line[line] = txt
        return self


def _is_ident(ch: str) -> bool:
    return ch.isalnum() or ch == "_"


def _blank(span_text: str) -> str:
    return "".join(c if c == "\n" else " " for c in span_text)


def lex(path: str, text: str) -> LexedFile:
    out = LexedFile(path=path, text=text, code="")
    code = []
    i, n = 0, len(text)
    line = 1

    while i < n:
        ch = text[i]
        nxt = text[i + 1] if i + 1 < n else ""

        # ---- line comment (// /// //!)
        if ch == "/" and nxt == "/":
            j = i
            while j < n and text[j] != "\n":
                j += 1
            out.comments.append((i, line, text[i:j]))
            code.append(_blank(text[i:j]))
            i = j
            continue

        # ---- block comment, nested per Rust
        if ch == "/" and nxt == "*":
            j = i + 2
            depth = 1
            while j < n and depth > 0:
                if text[j] == "/" and j + 1 < n and text[j + 1] == "*":
                    depth += 1
                    j += 2
                elif text[j] == "*" and j + 1 < n and text[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            out.comments.append((i, line, text[i:j]))
            span = text[i:j]
            code.append(_blank(span))
            line += span.count("\n")
            i = j
            continue

        # ---- raw / byte string prefixes
        if ch in "rb" and (i == 0 or not _is_ident(text[i - 1])):
            j = i
            prefix = ""
            while j < n and text[j] in "rb" and len(prefix) < 2:
                prefix += text[j]
                j += 1
            hashes = 0
            k = j
            while k < n and text[k] == "#":
                hashes += 1
                k += 1
            if k < n and text[k] == '"' and "r" in prefix:
                # raw string: ends at " + matching hash count
                end_marker = '"' + "#" * hashes
                close = text.find(end_marker, k + 1)
                if close == -1:
                    close = max(k + 1, n - len(end_marker))
                end = close + len(end_marker)
                value = text[k + 1 : close]
                out.strings.append((i, end, line, value))
                span = text[i:end]
                code.append(_blank(span))
                line += span.count("\n")
                i = end
                continue
            if prefix == "b" and j < n and text[j] == '"':
                close, value, nl = _scan_plain_string(text, j)
                out.strings.append((i, close, line, value))
                span = text[i:close]
                code.append(_blank(span))
                line += nl
                i = close
                continue
            if prefix == "b" and j < n and text[j] == "'":
                close = _scan_char(text, j)
                code.append(_blank(text[i:close]))
                i = close
                continue
            # plain identifier starting with r/b
            code.append(text[i])
            i += 1
            continue

        # ---- plain string
        if ch == '"':
            close, value, nl = _scan_plain_string(text, i)
            out.strings.append((i, close, line, value))
            span = text[i:close]
            code.append(_blank(span))
            line += nl
            i = close
            continue

        # ---- char literal vs lifetime/label
        if ch == "'":
            if nxt == "\\":
                close = _scan_char(text, i)
                code.append(_blank(text[i:close]))
                i = close
                continue
            if i + 2 < n and text[i + 2] == "'" and nxt != "'":
                code.append(_blank(text[i : i + 3]))
                i += 3
                continue
            # lifetime or label: keep as code
            code.append(ch)
            i += 1
            continue

        code.append(ch)
        if ch == "\n":
            line += 1
        i += 1

    out.code = "".join(code)
    assert len(out.code) == len(text), f"lexer desync in {path}"
    return out.finish()


def _scan_plain_string(text: str, start: int):
    """start points at the opening quote. Returns (end_exclusive, value,
    newlines_crossed)."""
    i = start + 1
    n = len(text)
    buf = []
    nl = 0
    while i < n:
        ch = text[i]
        if ch == "\\":
            if i + 1 < n and text[i + 1] == "\n":
                nl += 1
            buf.append(text[i : i + 2])
            i += 2
            continue
        if ch == '"':
            return i + 1, "".join(buf), nl
        if ch == "\n":
            nl += 1
        buf.append(ch)
        i += 1
    return n, "".join(buf), nl


def _scan_char(text: str, start: int) -> int:
    """start points at the opening '. Returns end offset (exclusive)."""
    i = start + 1
    n = len(text)
    while i < n:
        ch = text[i]
        if ch == "\\":
            i += 2
            continue
        if ch == "'":
            return i + 1
        if ch == "\n":  # malformed; bail
            return i
        i += 1
    return n


DELIMS = {"(": ")", "[": "]", "{": "}"}
CLOSERS = {v: k for k, v in DELIMS.items()}


def check_balance(lx: LexedFile):
    """Returns a list of (line, message) delimiter problems in the file's
    code view (strings/comments already blanked). Stops at the first
    problem — everything after a mismatch is noise."""
    problems = []
    stack = []
    for i, ch in enumerate(lx.code):
        if ch in DELIMS:
            stack.append((ch, i))
        elif ch in CLOSERS:
            if not stack:
                problems.append((lx.line_of(i), f"unmatched closing '{ch}'"))
                return problems
            op, oi = stack.pop()
            if DELIMS[op] != ch:
                problems.append(
                    (
                        lx.line_of(i),
                        f"mismatched '{ch}' closes '{op}' opened at "
                        f"line {lx.line_of(oi)}",
                    )
                )
                return problems
    for op, oi in stack[:1]:
        problems.append((lx.line_of(oi), f"unclosed '{op}'"))
    return problems


def match_brace(code: str, open_idx: int) -> int:
    """Index of the brace matching code[open_idx] (which must be an
    opener). Returns -1 if unbalanced."""
    op = code[open_idx]
    close = DELIMS[op]
    depth = 0
    for i in range(open_idx, len(code)):
        ch = code[i]
        if ch == op:
            depth += 1
        elif ch == close:
            depth -= 1
            if depth == 0:
                return i
    return -1
