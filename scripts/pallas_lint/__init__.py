"""pallas-lint: a toolchain-free static invariant checker for the
ActiveFlow Rust crate.

Seven PRs of concurrency-heavy Rust shipped from containers with no Rust
toolchain; every safety argument rested on hand desk-checks of the same
few invariants (single-cache-lock family fetch, cache-free loader, counter
plumbing from DecodeMetrics to the perf gate, exhaustive config-struct
literals).  This package turns those desk-checks into a CI gate that runs
on stdlib Python only — the one correctness tool that can actually arm on
every push in this container (see LINT.md for the invariant catalogue).

Layout:
  rustlex.py    comment/string/char-literal-aware Rust lexer
  items.py      per-item (fn / struct / impl) span extractor
  config.py     lint.toml loader (mini-TOML subset) + allowlist
  findings.py   Finding model + suppression matching
  passes/       the pluggable pass battery (locks, counters, literals,
                hotpath, structure)
  cli.py        driver: discovery, pass dispatch, text/--json output,
                --self-test fixture battery
  jsonutil.py   JSON-reading helpers shared with check_perf/check_trace
"""

__version__ = "1.0"
