"""Per-item span extraction over a lexed file.

Works on the *blanked* code view from rustlex, so `fn` inside a comment
or a format string never registers.  Extraction is regex + brace-match,
not a grammar: good enough for a single crate written in house style,
and the structure pass independently verifies every file balances, so a
mis-extraction here is loud rather than silent.
"""

import re
from dataclasses import dataclass, field
from typing import List, Optional

from .rustlex import LexedFile, match_brace

FN_RE = re.compile(r"\bfn\s+([A-Za-z_]\w*)")
STRUCT_RE = re.compile(r"\bstruct\s+([A-Za-z_]\w*)")
IMPL_RE = re.compile(r"\bimpl\b[^;{]*?\{")


@dataclass
class FnItem:
    name: str
    start: int          # offset of the `fn` keyword
    body_start: int     # offset of the opening brace (-1: no body)
    body_end: int       # offset of the closing brace (exclusive, -1: none)
    line: int
    impl_of: Optional[str] = None   # enclosing `impl Type` name, if any
    annotations: List[str] = field(default_factory=list)
    is_test: bool = False

    def body(self, lx: LexedFile) -> str:
        if self.body_start < 0:
            return ""
        return lx.code[self.body_start : self.body_end]


@dataclass
class StructItem:
    name: str
    start: int
    line: int
    fields: List[str] = field(default_factory=list)


ANNOT_RE = re.compile(r"//\s*pallas-lint:\s*([a-z-]+(?:\([^)]*\))?)")


def _annotations_above(lx: LexedFile, fn_start: int) -> List[str]:
    """Collect `// pallas-lint: X` annotations from the contiguous run of
    comment/attribute/blank lines directly above the item."""
    line = lx.line_of(fn_start)
    out = []
    # walk upward through attribute lines (#[...]), comments, visibility
    # spillover; stop at the first line that is real non-attribute code.
    lines = lx.text.splitlines()
    i = line - 2  # 0-based index of the line above
    while i >= 0:
        raw = lines[i].strip()
        if raw.startswith("//"):
            m = ANNOT_RE.search(raw)
            if m:
                out.append(m.group(1))
            i -= 1
            continue
        if raw.startswith("#[") or raw == "" or raw.startswith("#!["):
            i -= 1
            continue
        break
    return out


def _is_test_fn(lx: LexedFile, fn_start: int) -> bool:
    line = lx.line_of(fn_start)
    lines = lx.text.splitlines()
    i = line - 2
    while i >= 0:
        raw = lines[i].strip()
        if raw.startswith("//") or raw == "":
            i -= 1
            continue
        if raw.startswith("#["):
            if "test" in raw:
                return True
            i -= 1
            continue
        break
    return False


def extract_fns(lx: LexedFile) -> List[FnItem]:
    out = []
    impl_spans = []  # (name, body_start, body_end)
    for m in IMPL_RE.finditer(lx.code):
        brace = lx.code.index("{", m.start())
        end = match_brace(lx.code, brace)
        if end < 0:
            continue
        header = lx.code[m.start() : brace]
        # `impl Foo`, `impl Trait for Foo`, `impl<T> Foo<T>`
        name = None
        fm = re.search(r"\bfor\s+([A-Za-z_]\w*)", header)
        if fm:
            name = fm.group(1)
        else:
            im = re.search(r"\bimpl\s*(?:<[^>]*>)?\s*([A-Za-z_]\w*)", header)
            if im:
                name = im.group(1)
        impl_spans.append((name, brace, end))

    for m in FN_RE.finditer(lx.code):
        name = m.group(1)
        # find the body: first `{` at signature depth 0 past the arg list,
        # stopping at `;` (trait method decl / extern fn)
        i = m.end()
        n = len(lx.code)
        depth = 0
        body_start = -1
        while i < n:
            ch = lx.code[i]
            if ch in "(<[":
                depth += 1
            elif ch in ")>]":
                # `->` return arrows contain `>`: only count matched pairs
                if ch == ">" and i > 0 and lx.code[i - 1] == "-":
                    i += 1
                    continue
                depth = max(0, depth - 1)
            elif ch == "{" and depth == 0:
                body_start = i
                break
            elif ch == ";" and depth == 0:
                break
            i += 1
        body_end = -1
        if body_start >= 0:
            e = match_brace(lx.code, body_start)
            if e >= 0:
                body_end = e + 1
        impl_of = None
        for iname, ib, ie in impl_spans:
            if ib < m.start() < ie:
                impl_of = iname
                break
        out.append(
            FnItem(
                name=name,
                start=m.start(),
                body_start=body_start,
                body_end=body_end,
                line=lx.line_of(m.start()),
                impl_of=impl_of,
                annotations=_annotations_above(lx, m.start()),
                is_test=_is_test_fn(lx, m.start()),
            )
        )
    return out


def extract_structs(lx: LexedFile) -> List[StructItem]:
    out = []
    for m in STRUCT_RE.finditer(lx.code):
        name = m.group(1)
        # find `{` or `;` (unit/tuple struct) at depth 0 past generics
        i = m.end()
        n = len(lx.code)
        depth = 0
        brace = -1
        while i < n:
            ch = lx.code[i]
            if ch == "<":
                depth += 1
            elif ch == ">":
                depth = max(0, depth - 1)
            elif ch == "(" and depth == 0:
                brace = -1  # tuple struct
                break
            elif ch == "{" and depth == 0:
                brace = i
                break
            elif ch == ";" and depth == 0:
                break
            i += 1
        fields = []
        if brace >= 0:
            end = match_brace(lx.code, brace)
            if end > 0:
                fields = parse_field_names(lx.code[brace + 1 : end])
        out.append(
            StructItem(
                name=name, start=m.start(), line=lx.line_of(m.start()),
                fields=fields,
            )
        )
    return out


FIELD_RE = re.compile(r"([A-Za-z_]\w*)\s*:(?!:)")
SHORTHAND_RE = re.compile(r"^\s*(?:mut\s+)?([A-Za-z_]\w*)\s*$")


def parse_field_names(body: str) -> List[str]:
    """Field names at depth 0 of a struct body (declaration or literal).
    Handles `name: value` pairs and literal shorthand (`Foo { x, y }`);
    nested braces/parens/brackets (fn types, array types, nested literals)
    are skipped.  `..spread` tails yield nothing (callers check for the
    spread themselves)."""
    out = []
    depth = 0
    i = 0
    n = len(body)
    flat = []
    while i < n:
        ch = body[i]
        # `<`/`>` are deliberately NOT depth brackets: shift expressions
        # (`256 << 20`) are everywhere in byte-size literal values and
        # would wedge the depth counter.  A comma inside a generic type
        # therefore splits a field decl in two, but the name half still
        # parses and the type tail matches nothing — harmless.
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth = max(0, depth - 1)
        flat.append(ch if depth == 0 else " ")
        i += 1
    flat_s = "".join(flat)
    for part in flat_s.split(","):
        if part.lstrip().startswith(".."):
            continue
        m = FIELD_RE.search(part)
        if m is None:
            m = SHORTHAND_RE.match(part)
        if m and m.group(1) not in ("pub", "crate"):
            out.append(m.group(1))
    return out
