fn nested(cache_handle: &SharedCache, q: &Mutex<u64>) {
    let cache = cache_handle.lock();
    let g = q.lock().unwrap();
    let _ = (cache.len(), g);
}

fn takes_inner_lock(q: &Mutex<u64>) -> u64 {
    *q.lock().unwrap()
}

fn calls_acquirer(cache_handle: &SharedCache, q: &Mutex<u64>) {
    let cache = cache_handle.lock();
    let v = takes_inner_lock(q);
    cache.store(v);
}

fn publishes_under_guard(cache_handle: &SharedCache, trace: &TraceBuf) {
    let cache = cache_handle.lock();
    trace.flush();
    drop(cache);
}
