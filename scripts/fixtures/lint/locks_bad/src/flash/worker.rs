use crate::cache::SharedCache;
