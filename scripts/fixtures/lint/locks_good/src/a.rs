fn family_fetch(cache_handle: &SharedCache) {
    let mut cache = cache_handle.lock();
    cache.insert_rows(1, 2);
    drop(cache);
    trace.push_batch(events);
}

fn std_mutex_ok(q: &Mutex<Vec<u8>>) {
    // `.lock().unwrap()` is a std mutex, not a cache guard binding
    let g = q.lock().unwrap();
    let _ = g.len();
}
