pub fn write_point(m: &Metrics) -> String {
    obj(vec![
        ("tokens", num(m.tokens as f64)),
        ("flash_bytes", num(m.flash_bytes as f64)),
        ("sched_waves", num(m.waves as f64)),
    ])
}
