// Prometheus exposition: flash_bytes is deliberately missing so the
// self-test exercises counter-unexposed; waves reaches it only through
// the sched_waves exposition alias.
pub fn render(m: &Metrics) -> String {
    let mut out = String::new();
    counter(&mut out, ("tokens", m.tokens));
    counter(&mut out, ("sched_waves", m.waves));
    out
}
