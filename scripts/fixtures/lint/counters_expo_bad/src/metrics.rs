pub struct Metrics {
    pub tokens: u64,
    pub flash_bytes: u64,
    pub waves: u64,
}
