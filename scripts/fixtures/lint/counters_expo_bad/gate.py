WATCHED = ["tokens", "flash_bytes", "sched_waves"]
