pub struct Opts {
    pub alpha: u64,
    pub beta: u64,
}

pub fn build() -> Opts {
    // deliberately short for the fixture
    // pallas-lint: allow(missing-field)
    Opts { alpha: 1 }
}
