// pallas-lint: hot-path
fn step(rows: &[u64]) -> u64 {
    let head = rows.first().unwrap();
    let mut total = 0;
    for r in rows {
        let copy: Vec<u64> = Vec::new();
        total += r + copy.len() as u64 + head;
    }
    total
}

fn fetch() {}
