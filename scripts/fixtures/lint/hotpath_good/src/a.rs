// pallas-lint: hot-path
fn step(q: &Mutex<u64>, cv: &Condvar) -> u64 {
    // poisoning idiom: unwrap directly chained on lock()/wait() is fine
    let mut g = q.lock().unwrap();
    g = cv.wait(g).unwrap();
    // allocation OUTSIDE any loop body is fine
    let scratch: Vec<u64> = Vec::new();
    for v in scratch.iter() {
        let _ = v + *g;
    }
    *g
}
