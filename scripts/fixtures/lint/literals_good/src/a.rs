pub struct Opts {
    pub sparsity: f64,
    pub group: usize,
    pub cache_bytes: u64,
}

fn build() -> Opts {
    Opts {
        sparsity: 0.6,
        group: 4,
        cache_bytes: 256 << 10,
    }
}

fn build_defaulted(base: Opts) -> Opts {
    Opts {
        sparsity: 0.9,
        ..base
    }
}
