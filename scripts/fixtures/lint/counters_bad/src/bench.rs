pub fn write_point(m: &Metrics) -> String {
    obj(vec![
        ("tokens", num(m.tokens as f64)),
        ("old_tokens", num(m.old_tokens as f64)),
    ])
}
