pub fn stats(m: &Metrics) -> String {
    obj(vec![("tokens", num(m.tokens as f64))])
}
