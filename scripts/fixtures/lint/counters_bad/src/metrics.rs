pub struct Metrics {
    pub tokens: u64,
    pub orphan_counter: u64,
}
