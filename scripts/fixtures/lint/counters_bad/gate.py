WATCHED = ["tokens", "ghost_key"]
