pub mod a;
pub mod missing;
