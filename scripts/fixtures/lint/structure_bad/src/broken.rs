fn oops() {
    if true {
        let x = 1;
}
