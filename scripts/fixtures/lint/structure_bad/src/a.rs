#[test]
fn same_name() {}

#[test]
fn same_name() {}
