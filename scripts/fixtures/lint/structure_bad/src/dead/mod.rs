pub fn nobody_declares_me() {}
