pub struct Opts {
    pub sparsity: f64,
    pub group: usize,
    pub cache_bytes: u64,
}

fn short() -> Opts {
    Opts {
        sparsity: 0.6,
        group: 4,
    }
}

fn stale_rename() -> Opts {
    Opts {
        sparsity: 0.6,
        group: 4,
        cache_bytes: 1,
        io_depth: 2,
    }
}
