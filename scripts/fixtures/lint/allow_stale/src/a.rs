pub fn clean() -> u64 {
    7
}
