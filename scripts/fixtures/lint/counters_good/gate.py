WATCHED = ["flash_bytes", "itl_p50_us", "tokens_per_sec"]
