pub fn write_point(m: &Metrics) -> String {
    obj(vec![
        ("tokens", num(m.tokens as f64)),
        ("tokens_per_sec", num(m.tokens_per_sec())),
        ("flash_bytes", num(m.flash_bytes as f64)),
        ("itl_p50_us", num(m.h_itl_us.p50())),
    ])
}
