pub fn stats(m: &Metrics) -> String {
    obj(vec![
        ("tokens", num(m.tokens as f64)),
        ("flash_bytes", num(m.flash_bytes as f64)),
        ("itl_p99_us", num(m.h_itl_us.p99())),
    ])
}
