pub struct Metrics {
    pub tokens: u64,
    pub flash_bytes: u64,
    pub h_itl_us: Histo,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64
    }
}
