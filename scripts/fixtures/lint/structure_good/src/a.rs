pub fn braces_in_strings() -> &'static str {
    // the lexer must not count delimiters inside strings or comments: }}
    let s = "}{)(";
    let c = '{';
    let r = r#"{{{"#;
    let _ = (s, c, r);
    "ok"
}

#[test]
fn first_test() {}

#[test]
fn second_test() {}
