pub fn exists() {}
