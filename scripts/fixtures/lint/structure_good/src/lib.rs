pub mod a;
pub mod b;
