#!/usr/bin/env python3
"""Perf-trajectory gate: diff two BENCH_decode.json points and fail on a
>5% tokens/sec regression (ROADMAP item; see PERF.md methodology).

Usage: check_perf.py PREV.json CURR.json [--threshold 0.05]

Exit codes: 0 = ok (or no previous point to compare), 1 = regression,
2 = malformed input.
"""

import json
import os
import sys

THRESHOLD = 0.05

# Secondary counters worth flagging (informational, never fatal): these
# move with workload changes, so only tokens/sec gates the build.
WATCHED = [
    "cache_lock_acquires",
    "flash_bytes",
    "ondemand_rows",
    "slab_bytes_peak",
]


def main(argv):
    if len(argv) < 3:
        print(__doc__.strip())
        return 2
    prev_path, curr_path = argv[1], argv[2]
    threshold = THRESHOLD
    if "--threshold" in argv:
        try:
            threshold = float(argv[argv.index("--threshold") + 1])
        except (IndexError, ValueError):
            print("check-perf: --threshold expects a number")
            return 2

    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-smoke`")
        return 2
    if not os.path.exists(prev_path):
        print(f"check-perf: no previous point ({prev_path}); nothing to "
              "diff — baseline recorded")
        return 0

    try:
        with open(prev_path) as f:
            prev = json.load(f)
        with open(curr_path) as f:
            curr = json.load(f)
        tps_prev = float(prev["tokens_per_sec"])
        tps_curr = float(curr["tokens_per_sec"])
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed bench point: {e}")
        return 2

    if tps_prev <= 0:
        print("check-perf: previous tokens_per_sec is 0 — skipping diff")
        return 0

    delta = (tps_curr - tps_prev) / tps_prev
    print(f"check-perf: tokens/sec {tps_prev:.2f} -> {tps_curr:.2f} "
          f"({delta:+.1%}, threshold -{threshold:.0%})")
    for key in WATCHED:
        if key in prev and key in curr and float(prev[key]) > 0:
            d = (float(curr[key]) - float(prev[key])) / float(prev[key])
            if abs(d) >= threshold:
                print(f"check-perf:   note: {key} {prev[key]} -> "
                      f"{curr[key]} ({d:+.1%})")

    if delta < -threshold:
        print("check-perf: FAIL — tokens/sec regressed past the "
              f"{threshold:.0%} gate")
        return 1
    print("check-perf: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
