#!/usr/bin/env python3
"""Perf-trajectory gate: diff two BENCH_decode.json points and fail on a
>5% tokens/sec regression or a >5% p99 inter-token-latency regression
(the `itl_p99_us` tail from the flight-recorder histograms — skipped
gracefully when the previous point predates it); optionally also diff
two BENCH_governor.json
points (fail on a >5% settle-time regression), two BENCH_sched.json
points (fail on a >5% aggregate interleaved tokens/sec regression), and
two BENCH_kv.json points (fail on a >5% regression of either admitted
concurrency or aggregate tokens/sec for the paged-KV mixed-length
workload), and two BENCH_kernels.json points (fail on a >5% regression
of the dequant block-kernel speedup or the bucketed-attention
host-copy reduction) (ROADMAP items; see PERF.md methodology).

Usage: check_perf.py PREV.json CURR.json [--threshold 0.05]
                     [--governor GOV_PREV.json GOV_CURR.json]
                     [--sched SCHED_PREV.json SCHED_CURR.json]
                     [--kv KV_PREV.json KV_CURR.json]
                     [--kernels KERN_PREV.json KERN_CURR.json]

Exit codes: 0 = ok (or no previous point to compare), 1 = regression,
2 = malformed input.
"""

import json
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "pallas_lint")
)
from jsonutil import load_pair  # noqa: E402  (shared gate helpers)

THRESHOLD = 0.05

# Secondary counters worth flagging (informational, never fatal): these
# move with workload changes, so only tokens/sec gates the build.
WATCHED = [
    "cache_lock_acquires",
    "flash_bytes",
    "ondemand_rows",
    "slab_bytes_peak",
    "io_batches",
    "io_wait_us",
    "io_wait_loader_us",
    "io_wait_engine_us",
    "io_buffers_recycled",
    "faults_injected",
    "retries",
    "wedged_recoveries",
    "fallback_rows",
    "degraded_fallbacks",
    "kv_blocks_peak",
    "itl_p50_us",
    "itl_p95_us",
    "ondemand_p99_us",
    "io_wait_engine_p99_us",
    "host_copy_bytes",
    "attn_bucket_cap",
    "dequant_rows_vectorized",
    "subslab_waste_bytes",
]


def check_itl_tail(prev, curr, threshold):
    """p99 inter-token-latency gate over the decode pair: the tail must
    not regress >threshold. Skips gracefully when either point predates
    the flight-recorder percentiles. Returns an exit code."""
    if "itl_p99_us" not in prev or "itl_p99_us" not in curr:
        print("check-perf: no itl_p99_us in one of the decode points — "
              "ITL tail gate skipped (pre-flight-recorder baseline)")
        return 0
    try:
        p, c = float(prev["itl_p99_us"]), float(curr["itl_p99_us"])
    except (TypeError, ValueError) as e:
        print(f"check-perf: malformed itl_p99_us: {e}")
        return 2
    if p <= 0:
        print("check-perf: previous itl_p99_us is 0 — skipping ITL diff")
        return 0
    delta = (c - p) / p
    print(f"check-perf: itl p99 {p:.0f}us -> {c:.0f}us "
          f"({delta:+.1%}, threshold +{threshold:.0%})")
    if delta > threshold:
        print("check-perf: FAIL — p99 inter-token latency regressed "
              f"past the {threshold:.0%} gate")
        return 1
    return 0


def check_governor(prev_path, curr_path, threshold):
    """Settle-time gate over BENCH_governor.json: the total wall time the
    live engine spent applying re-budget plans must not regress >5%.
    Returns an exit code (0 ok / 1 regression / 2 malformed)."""
    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-governor`"
              " (governor gate skipped)")
        return 0
    try:
        pair = load_pair(prev_path, curr_path, "governor")
        if pair is None:
            return 0
        prev, curr = pair
        settle_prev = float(prev["rebudget_settle_ms"])
        settle_curr = float(curr["rebudget_settle_ms"])
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed governor bench point: {e}")
        return 2

    if settle_prev <= 0:
        print("check-perf: previous settle time is 0 — skipping "
              "governor diff")
        return 0
    delta = (settle_curr - settle_prev) / settle_prev
    print(f"check-perf: governor settle {settle_prev:.2f}ms -> "
          f"{settle_curr:.2f}ms ({delta:+.1%}, threshold +{threshold:.0%})")
    # informational: per-phase tokens/sec swings
    for p_prev, p_curr in zip(prev.get("phases", []),
                              curr.get("phases", [])):
        tp, tc = p_prev.get("tokens_per_sec"), p_curr.get("tokens_per_sec")
        if tp and tc and float(tp) > 0:
            d = (float(tc) - float(tp)) / float(tp)
            if abs(d) >= threshold:
                print(f"check-perf:   note: phase@"
                      f"{p_prev.get('budget_bytes')} tok/s {tp} -> {tc} "
                      f"({d:+.1%})")
    if delta > threshold:
        print("check-perf: FAIL — governor settle time regressed past "
              f"the {threshold:.0%} gate")
        return 1
    return 0


def check_sched(prev_path, curr_path, threshold):
    """Aggregate-throughput gate over BENCH_sched.json: interleaved
    tokens/sec for the N-sequence workload must not regress >5%. The
    speedup-over-serial ratio is printed informationally (the bench
    itself already asserts speedup > 1)."""
    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-sched`"
              " (scheduler gate skipped)")
        return 0
    try:
        pair = load_pair(prev_path, curr_path, "sched")
        if pair is None:
            return 0
        prev, curr = pair
        tps_prev = float(prev["aggregate_tokens_per_sec"])
        tps_curr = float(curr["aggregate_tokens_per_sec"])
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed sched bench point: {e}")
        return 2

    if tps_prev <= 0:
        print("check-perf: previous sched tokens/sec is 0 — skipping diff")
        return 0
    delta = (tps_curr - tps_prev) / tps_prev
    print(f"check-perf: sched aggregate {tps_prev:.2f} -> {tps_curr:.2f} "
          f"tok/s ({delta:+.1%}, threshold -{threshold:.0%})")
    for key in ("speedup", "wave_avg_us", "io_wait_engine_us_interleaved"):
        if key in prev and key in curr and float(prev[key]) > 0:
            d = (float(curr[key]) - float(prev[key])) / float(prev[key])
            if abs(d) >= threshold:
                print(f"check-perf:   note: {key} {prev[key]} -> "
                      f"{curr[key]} ({d:+.1%})")
    if delta < -threshold:
        print("check-perf: FAIL — scheduler aggregate throughput "
              f"regressed past the {threshold:.0%} gate")
        return 1
    return 0


def check_kv(prev_path, curr_path, threshold):
    """Paged-KV gate over BENCH_kv.json: the mixed-length workload's
    admitted concurrency AND its aggregate tokens/sec must not regress
    >5% (the bench itself already asserts concurrency strictly beats the
    whole-window ceiling)."""
    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-kv`"
              " (kv gate skipped)")
        return 0
    try:
        pair = load_pair(prev_path, curr_path, "kv")
        if pair is None:
            return 0
        prev, curr = pair
        gated = [("admitted_concurrency",
                  float(prev["admitted_concurrency"]),
                  float(curr["admitted_concurrency"])),
                 ("aggregate_tokens_per_sec",
                  float(prev["aggregate_tokens_per_sec"]),
                  float(curr["aggregate_tokens_per_sec"]))]
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed kv bench point: {e}")
        return 2

    rc = 0
    for key, p, c in gated:
        if p <= 0:
            print(f"check-perf: previous kv {key} is 0 — skipping diff")
            continue
        delta = (c - p) / p
        print(f"check-perf: kv {key} {p:.2f} -> {c:.2f} "
              f"({delta:+.1%}, threshold -{threshold:.0%})")
        if delta < -threshold:
            print(f"check-perf: FAIL — paged-KV {key} regressed past "
                  f"the {threshold:.0%} gate")
            rc = 1
    for key in ("speedup_vs_whole_window", "kv_preemptions_oom"):
        if key in prev and key in curr and float(prev[key]) > 0:
            d = (float(curr[key]) - float(prev[key])) / float(prev[key])
            if abs(d) >= threshold:
                print(f"check-perf:   note: {key} {prev[key]} -> "
                      f"{curr[key]} ({d:+.1%})")
    return rc


def check_kernels(prev_path, curr_path, threshold):
    """Kernel hot-path gate over BENCH_kernels.json: the dequant
    block-kernel speedups (vs the scalar reference) and the bucketed
    attention host-copy reduction must not regress >5%. The attention
    keys are 0 when the bench ran without attn_core_<cap> artifacts —
    those diffs skip, matching the bench's self-skip."""
    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-kernels`"
              " (kernels gate skipped)")
        return 0
    try:
        pair = load_pair(prev_path, curr_path, "kernels")
        if pair is None:
            return 0
        prev, curr = pair
        gated = [(key, float(prev[key]), float(curr[key]))
                 for key in ("dequant_speedup_q8_0",
                             "dequant_speedup_q4_0",
                             "host_copy_reduction")]
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed kernels bench point: {e}")
        return 2

    rc = 0
    for key, p, c in gated:
        if p <= 0:
            print(f"check-perf: previous kernels {key} is 0 — skipping "
                  "diff")
            continue
        delta = (c - p) / p
        print(f"check-perf: kernels {key} {p:.2f}x -> {c:.2f}x "
              f"({delta:+.1%}, threshold -{threshold:.0%})")
        if delta < -threshold:
            print(f"check-perf: FAIL — kernel {key} regressed past "
                  f"the {threshold:.0%} gate")
            rc = 1
    for key in ("host_copy_bytes", "attn_bucket_cap",
                "dequant_rows_vectorized", "subslab_waste_bytes"):
        if key in prev and key in curr and float(prev[key]) > 0:
            d = (float(curr[key]) - float(prev[key])) / float(prev[key])
            if abs(d) >= threshold:
                print(f"check-perf:   note: {key} {prev[key]} -> "
                      f"{curr[key]} ({d:+.1%})")
    return rc


def main(argv):
    argv = list(argv)
    governor = None
    if "--governor" in argv:
        i = argv.index("--governor")
        try:
            governor = (argv[i + 1], argv[i + 2])
        except IndexError:
            print("check-perf: --governor expects PREV.json CURR.json")
            return 2
        del argv[i:i + 3]
    sched = None
    if "--sched" in argv:
        i = argv.index("--sched")
        try:
            sched = (argv[i + 1], argv[i + 2])
        except IndexError:
            print("check-perf: --sched expects PREV.json CURR.json")
            return 2
        del argv[i:i + 3]
    kv = None
    if "--kv" in argv:
        i = argv.index("--kv")
        try:
            kv = (argv[i + 1], argv[i + 2])
        except IndexError:
            print("check-perf: --kv expects PREV.json CURR.json")
            return 2
        del argv[i:i + 3]
    kernels = None
    if "--kernels" in argv:
        i = argv.index("--kernels")
        try:
            kernels = (argv[i + 1], argv[i + 2])
        except IndexError:
            print("check-perf: --kernels expects PREV.json CURR.json")
            return 2
        del argv[i:i + 3]
    threshold = THRESHOLD
    if "--threshold" in argv:
        try:
            threshold = float(argv[argv.index("--threshold") + 1])
        except (IndexError, ValueError):
            print("check-perf: --threshold expects a number")
            return 2

    if len(argv) < 3:
        print(__doc__.strip())
        return 2
    prev_path, curr_path = argv[1], argv[2]

    if not os.path.exists(curr_path):
        print(f"check-perf: {curr_path} missing — run `make bench-smoke`")
        return 2

    rc = 0
    try:
        pair = load_pair(prev_path, curr_path, "decode")
        if pair is not None:
            prev, curr = pair
            tps_prev = float(prev["tokens_per_sec"])
            tps_curr = float(curr["tokens_per_sec"])
            if tps_prev <= 0:
                print("check-perf: previous tokens_per_sec is 0 — "
                      "skipping diff")
            else:
                delta = (tps_curr - tps_prev) / tps_prev
                print(f"check-perf: tokens/sec {tps_prev:.2f} -> "
                      f"{tps_curr:.2f} ({delta:+.1%}, threshold "
                      f"-{threshold:.0%})")
                for key in WATCHED:
                    if key in prev and key in curr and float(prev[key]) > 0:
                        d = (float(curr[key]) - float(prev[key])) \
                            / float(prev[key])
                        if abs(d) >= threshold:
                            print(f"check-perf:   note: {key} {prev[key]} "
                                  f"-> {curr[key]} ({d:+.1%})")
                if delta < -threshold:
                    print("check-perf: FAIL — tokens/sec regressed past "
                          f"the {threshold:.0%} gate")
                    rc = 1
            rc = max(rc, check_itl_tail(prev, curr, threshold))
    except (json.JSONDecodeError, KeyError, ValueError) as e:
        print(f"check-perf: malformed bench point: {e}")
        return 2

    if governor is not None:
        grc = check_governor(governor[0], governor[1], threshold)
        rc = max(rc, grc)

    if sched is not None:
        src = check_sched(sched[0], sched[1], threshold)
        rc = max(rc, src)

    if kv is not None:
        krc = check_kv(kv[0], kv[1], threshold)
        rc = max(rc, krc)

    if kernels is not None:
        knrc = check_kernels(kernels[0], kernels[1], threshold)
        rc = max(rc, knrc)

    if rc == 0:
        print("check-perf: ok")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
