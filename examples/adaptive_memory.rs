//! Adaptive DRAM usage — the paper's headline capability: the same model
//! served under shrinking memory budgets. For each budget the §4.1 search
//! picks (sp, N, cache) and the engine actually runs with them, reporting
//! measured DRAM and speed.
//!
//! ```sh
//! cargo run --release --example adaptive_memory
//! ```

use activeflow::cache::CachePolicy;
use activeflow::config::ArtifactConfig;
use activeflow::costmodel::{self, Geometry};
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::layout::AwgfFile;
use activeflow::tokenizer;
use activeflow::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let cfg = ArtifactConfig::load(dir)?;
    let awgf = AwgfFile::open(&cfg.weights_file)?;
    let geo = Geometry::from_awgf(&awgf);
    let dev = &device::PIXEL6;
    let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    println!(
        "adaptive DRAM sweep on {} — model {} on flash, KV {}",
        dev.name,
        human_bytes(geo.model_bytes),
        human_bytes(geo.kv_bytes)
    );
    println!(
        "{:>10} {:>6} {:>3} {:>10} | {:>10} {:>8} {:>7}",
        "budget", "sp", "N", "cache", "meas-dram", "tok/s", "ppl-tag"
    );

    // weight budgets from "almost everything fits" down to "barely
    // anything does" (KV is a fixed cost on top — paper Eq 8)
    for frac in [0.9, 0.6, 0.45, 0.3, 0.15] {
        let budget = geo.kv_bytes + (geo.model_bytes as f64 * frac) as u64;
        let Some(r) = costmodel::search(dev, &geo, budget, 0.85, 1.0, &grid)
        else {
            println!("{:>10}  -> infeasible", human_bytes(budget));
            continue;
        };
        let opts = EngineOptions {
            sparsity: r.params.sp,
            group_size: r.params.n_group,
            swap_mode: SwapMode::Preload,
            cache_bytes: r.params.cache_bytes,
            cache_policy: CachePolicy::Contextual,
            device: dev,
            clock: ClockMode::Timed,
            bw_scale: 1.0,
        trigger: PreloadTrigger::FirstLayer,
        };
        let mut eng = SwapEngine::open(dir, opts)?;
        eng.generate(&prompt, 16, 0.0)?;
        let mem = eng.memory_report();
        println!(
            "{:>10} {:>6.2} {:>3} {:>10} | {:>10} {:>8.2} {:>7}",
            human_bytes(budget),
            r.params.sp,
            r.params.n_group,
            human_bytes(r.params.cache_bytes),
            human_bytes(mem.dram_total()),
            eng.metrics.tokens_per_sec(),
            eng.sparsity_tag(),
        );
        assert!(
            mem.dram_total() <= budget + geo.kv_bytes,
            "engine exceeded its budget!"
        );
    }
    println!(
        "\nsame binary, same flash file — only the budget changed. \
         (user-oblivious adaptive DRAM usage, paper §1)"
    );
    Ok(())
}
