//! Adaptive DRAM usage — the paper's headline capability, now *live*: one
//! engine served under a shrinking memory budget with **no restarts**.
//! A scripted [`PressureSchedule`] steps M_max down; at every step the
//! [`DramGovernor`] re-runs the §4.1 search online and applies
//! `(sp, N, cache)` to the running engine — the weight cache evicts down
//! to its new target, the loader gets a tighter slab ceiling, and the
//! active sparsity level switches across the compiled artifact sets
//! between requests.
//!
//! ```sh
//! cargo run --release --example adaptive_memory
//! ```

use activeflow::cache::CachePolicy;
use activeflow::config::ArtifactConfig;
use activeflow::costmodel::{self, Geometry};
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::governor::{
    DramGovernor, GovernorConfig, PressureSchedule, RebudgetTrigger,
};
use activeflow::layout::AwgfFile;
use activeflow::tokenizer;
use activeflow::util::human_bytes;

const TOKENS_PER_PHASE: u64 = 16;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let cfg = ArtifactConfig::load(dir)?;
    let awgf = AwgfFile::open(&cfg.weights_file)?;
    let geo = Geometry::from_awgf(&awgf);
    let dev = &device::PIXEL6;
    let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    // Scripted pressure trace: weight budgets from "almost everything
    // fits" down to "barely anything does" (KV is a fixed cost on top —
    // paper Eq 8), one phase of decoding between steps. The spec-string
    // round-trip is deliberate: it is the same scriptable path the
    // governor bench and a server-side schedule use.
    let spec = [0.9, 0.6, 0.45, 0.3, 0.15]
        .iter()
        .enumerate()
        .map(|(i, frac)| {
            let budget =
                geo.kv_bytes + (geo.model_bytes as f64 * frac) as u64;
            format!("{}@{}", budget, i as u64 * TOKENS_PER_PHASE)
        })
        .collect::<Vec<_>>()
        .join(",");
    let mut schedule = PressureSchedule::parse(&spec)?;

    // Open ONE engine at the first (largest) budget's configuration…
    let first_budget = schedule.steps()[0].budget;
    let r0 = costmodel::search(dev, &geo, first_budget, 0.85, 1.0, &grid)
        .expect("largest budget must be feasible");
    let mut eng = SwapEngine::open(dir, EngineOptions {
        sparsity: r0.params.sp,
        group_size: r0.params.n_group,
        swap_mode: SwapMode::Preload,
        cache_bytes: r0.params.cache_bytes,
        cache_policy: CachePolicy::Contextual,
        device: dev,
        clock: ClockMode::Timed,
        bw_scale: 1.0,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
    })?;
    // …and let the governor drive every later step on the live engine.
    // One sequence at a time here: cap the KV pool at a single seq so
    // the planner doesn't reserve phantom KV for concurrency this
    // example never uses.
    let gcfg = GovernorConfig {
        max_seqs: 1,
        ..GovernorConfig::default()
    };
    let mut gov = DramGovernor::new(&eng, gcfg, first_budget);

    println!(
        "live adaptive DRAM on {} — model {} on flash, KV {}, one engine, \
         {} scripted budget steps, zero restarts",
        dev.name,
        human_bytes(geo.model_bytes),
        human_bytes(geo.kv_bytes),
        schedule.len()
    );
    println!(
        "{:>10} {:>6} {:>3} {:>10} | {:>10} {:>10} {:>10} | {:>8} {:>7} \
         {:>9}",
        "budget", "sp", "N", "cache-tgt", "L:cache", "L:preload",
        "L:compute", "tok/s", "evict", "settle"
    );

    let mut decoded = 0u64;
    while let Some(budget) = schedule.due(decoded) {
        let d = gov.set_budget(&mut eng, budget, RebudgetTrigger::Schedule)?;
        if !d.applied && d.note == "infeasible" {
            println!("{:>10}  -> infeasible (engine keeps sp={:.2})",
                     human_bytes(budget), d.old_sp);
            decoded += TOKENS_PER_PHASE;
            continue;
        }
        let before = eng.metrics.clone();
        eng.generate(&prompt, TOKENS_PER_PHASE as usize, 0.0)?;
        decoded += TOKENS_PER_PHASE;
        let wall = (eng.metrics.wall - before.wall).as_secs_f64();
        let toks = eng.metrics.tokens - before.tokens;
        let ledger = eng.pool_ledger();
        println!(
            "{:>10} {:>6.2} {:>3} {:>10} | {:>10} {:>10} {:>10} | {:>8.2} \
             {:>7} {:>7.1}ms",
            human_bytes(budget),
            d.new_sp,
            d.new_group,
            human_bytes(d.cache_target),
            human_bytes(ledger.cache_bytes),
            human_bytes(ledger.preload_bytes),
            human_bytes(ledger.compute_bytes),
            toks as f64 / wall.max(1e-9),
            d.evicted_rows,
            d.settle.as_secs_f64() * 1e3,
        );
        assert!(
            ledger.cache_bytes <= d.cache_target,
            "cache did not shrink to its target: {} > {}",
            ledger.cache_bytes,
            d.cache_target
        );
        // end-to-end budget compliance (Eq 8 pools vs M_max): between
        // requests the preload store must be drained, and the applied
        // plan's pools — measured cache + the searched M_cl the slab cap
        // protects + fixed KV — must fit the scripted budget
        assert_eq!(
            ledger.preload_bytes, 0,
            "preload slabs must be retired between requests"
        );
        if d.applied {
            assert!(
                ledger.cache_bytes + d.m_cl + geo.kv_bytes <= budget,
                "engine exceeded its budget: cache {} + M_cl {} + kv {} > {}",
                ledger.cache_bytes,
                d.m_cl,
                geo.kv_bytes,
                budget
            );
        }
    }

    let m = &eng.metrics;
    println!(
        "\nsame engine, same flash file — only the budget moved underneath \
         it. {} re-budgets applied ({} rows evicted, {} level switches, \
         {:.1} ms total settle); decisions recorded: {}",
        m.rebudgets_applied,
        m.rebudget_rows_evicted,
        m.level_switches,
        m.rebudget_settle.as_secs_f64() * 1e3,
        gov.decisions().len(),
    );
    println!("(user-oblivious adaptive DRAM usage, paper §1 — now without \
              engine restarts)");
    Ok(())
}
