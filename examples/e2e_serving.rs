//! End-to-end serving driver (the EXPERIMENTS.md validation run): boots the
//! TCP server on the distilled tiny model, fires a batch of concurrent
//! client requests across the synthetic task domains, and reports
//! latency/throughput — proving all layers compose: rust coordinator →
//! PJRT artifacts (JAX+Pallas) → AWGF flash file → swapping pipeline.
//!
//! ```sh
//! cargo run --release --example e2e_serving
//! ```

use std::time::Instant;

use activeflow::cache::CachePolicy;
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::governor::GovernorConfig;
use activeflow::server::{client_roundtrip, serve, ServerConfig};
use activeflow::tokenizer;
use activeflow::util::json::{num, obj, s, Value};
use activeflow::util::Stats;

const ADDR: &str = "127.0.0.1:7171";
const N_CLIENTS: usize = 2;
const REQS_PER_CLIENT: usize = 3;
const TOKENS_PER_REQ: usize = 16;

fn main() -> anyhow::Result<()> {
    let cfg = ServerConfig {
        addr: ADDR.into(),
        artifact_dir: "artifacts".into(),
        opts: EngineOptions {
            sparsity: 0.6,
            group_size: 4,
            swap_mode: SwapMode::Preload,
            cache_bytes: 1024 * 1024,
            cache_policy: CachePolicy::Contextual,
            device: &device::PIXEL6,
            clock: ClockMode::Timed,
            bw_scale: 1.0,
            trigger: PreloadTrigger::FirstLayer,
            io_queue_depth: 0,
            kv_block_tokens: 16,
        },
        governor: GovernorConfig::default(),
        initial_budget: None,
        pressure_schedule: None,
        pressure_file: None,
        // continuous batching: both clients' requests decode interleaved
        max_seqs: N_CLIENTS,
        sched_queue_cap: 16,
        fault_spec: None,
        trace_out: None,
        telemetry_interval_ms: 500,
    };
    let server = std::thread::spawn(move || serve(cfg));

    // wait for the engine to come up
    let ping = obj(vec![("prompt", s("warmup ")), ("n_tokens", num(2.0))]);
    for _ in 0..120 {
        if client_roundtrip(ADDR, &ping).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(250));
    }
    println!(
        "[e2e] server up; firing {N_CLIENTS}x{REQS_PER_CLIENT} requests x \
         {TOKENS_PER_REQ} tokens"
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..N_CLIENTS {
        handles.push(std::thread::spawn(move || -> Vec<(f64, f64, String)> {
            let domains = tokenizer::DOMAIN_NAMES;
            let mut out = Vec::new();
            for r in 0..REQS_PER_CLIENT {
                let dom = domains[(c + r) % domains.len()];
                let prompt =
                    tokenizer::gen_text((c * 100 + r) as u64, 1, Some(dom));
                let req = obj(vec![
                    ("prompt", s(&prompt)),
                    ("n_tokens", num(TOKENS_PER_REQ as f64)),
                    ("temp", num(0.0)),
                ]);
                let resp = client_roundtrip(ADDR, &req).expect("roundtrip");
                let get = |k: &str| {
                    resp.get(k).and_then(Value::as_f64).unwrap_or(f64::NAN)
                };
                out.push((
                    get("queue_ms") + get("decode_ms"),
                    get("toks_per_sec"),
                    resp.get("text")
                        .and_then(Value::as_str)
                        .unwrap_or("")
                        .chars()
                        .take(40)
                        .collect(),
                ));
            }
            out
        }));
    }
    let mut lat = Vec::new();
    let mut tps = Vec::new();
    for h in handles {
        for (l, t, text) in h.join().unwrap() {
            println!("[e2e]   {l:8.1} ms e2e | {t:6.2} tok/s | \"{text}…\"");
            lat.push(l);
            tps.push(t);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_reqs = N_CLIENTS * REQS_PER_CLIENT;
    let ls = Stats::from(&lat);
    println!(
        "\n[e2e] {total_reqs} requests in {wall:.1}s ({:.2} req/s, {:.1} \
         tok/s aggregate)",
        total_reqs as f64 / wall,
        (total_reqs * TOKENS_PER_REQ) as f64 / wall
    );
    println!(
        "[e2e] e2e latency ms: p50 {:.0} p90 {:.0} p99 {:.0} (mean {:.0}, \
         queueing included)",
        ls.p50, ls.p90, ls.p99, ls.mean
    );

    let stats =
        client_roundtrip(ADDR, &obj(vec![("cmd", s("stats"))])).unwrap();
    println!("[e2e] server stats: {}", stats.to_string());
    let _ = client_roundtrip(ADDR, &obj(vec![("cmd", s("shutdown"))]));
    let _ = server.join();
    Ok(())
}
