//! Quickstart: load the tiny model and decode with active-weight swapping.
//!
//! ```sh
//! make artifacts          # once: train + distill + AOT-lower (python)
//! cargo run --release --example quickstart
//! ```

use activeflow::cache::CachePolicy;
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::tokenizer;
use activeflow::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let opts = EngineOptions {
        sparsity: 0.6,                      // skip 60% of weight channels
        group_size: 4,                      // cross-layer preload group (N)
        swap_mode: SwapMode::Preload,       // the ActiveFlow pipeline
        cache_bytes: 256 * 1024,            // contextual hot-weight cache
        cache_policy: CachePolicy::Contextual,
        device: &device::PIXEL6,            // simulated UFS 3.1 phone
        clock: ClockMode::Timed,            // flash reads really take time
        bw_scale: 1.0,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,                  // 0 = device's modeled queue depth
        kv_block_tokens: 16,                // paged KV: tokens per block
    };
    let mut engine = SwapEngine::open("artifacts".as_ref(), opts)?;
    println!(
        "model '{}' at sparsity level {} on {}",
        engine.model().name,
        engine.sparsity_tag(),
        engine.opts.device.label
    );

    let prompt = tokenizer::encode("the sparse model swaps active weights. ");
    let out = engine.generate(&prompt, 64, 0.0)?;
    println!("\nprompt> {}", tokenizer::decode(&prompt));
    println!("model>  {}", tokenizer::decode(&out));

    let mem = engine.memory_report();
    println!(
        "\n{:.2} tok/s | DRAM {} vs full weights on flash {} | cache hit \
         {:.0}% | preload precision {:.0}%",
        engine.metrics.tokens_per_sec(),
        human_bytes(mem.dram_total()),
        human_bytes(mem.flash_file_bytes),
        engine.cache_hit_rate() * 100.0,
        engine.metrics.preload_precision() * 100.0,
    );
    Ok(())
}
