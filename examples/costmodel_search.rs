//! Cost-model exploration across paper-scale geometries: what configuration
//! would ActiveFlow pick for Llama-2-7B / Llama-3-8B / Mixtral-8x7B on each
//! of the three phones, across memory budgets (the §4.1 search + Table 1
//! model at full scale — no weights needed).
//!
//! ```sh
//! cargo run --release --example costmodel_search
//! ```

use activeflow::costmodel::{self, Geometry};
use activeflow::device;
use activeflow::util::human_bytes;

fn main() {
    let geos: [(&str, Geometry); 3] = [
        ("llama-2-7b-q4", Geometry::llama7b_q4()),
        ("llama-3-8b-q4", Geometry::llama8b_q4()),
        ("mixtral-8x7b-q4", Geometry::mixtral8x7b_q4()),
    ];
    let grid = [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95];
    for (name, geo) in geos {
        println!(
            "\n=== {name} (S_m {} | S_l {} | {} layers) ===",
            human_bytes(geo.model_bytes),
            human_bytes(geo.layer_bytes),
            geo.n_layers
        );
        println!(
            "{:<10} {:>9} | {:>5} {:>3} {:>10} | {:>9} {:>9}",
            "device", "budget", "sp", "N", "cache", "tok/s", "mem"
        );
        for dev in device::ALL {
            for budget_gb in [6.0, 4.0, 2.9, 2.0, 1.3] {
                let budget = (budget_gb * (1u64 << 30) as f64) as u64;
                match costmodel::search(dev, &geo, budget, 0.85, 1.0, &grid) {
                    None => println!(
                        "{:<10} {:>8.1}G | infeasible",
                        dev.name, budget_gb
                    ),
                    Some(r) => println!(
                        "{:<10} {:>8.1}G | {:>5.2} {:>3} {:>10} | {:>9.2} {:>9}",
                        dev.name,
                        budget_gb,
                        r.params.sp,
                        r.params.n_group,
                        human_bytes(r.params.cache_bytes),
                        1.0 / r.cost.t_decode,
                        human_bytes(r.cost.mem_bytes)
                    ),
                }
            }
        }
    }
    println!(
        "\n(speed *rises* as budgets shrink — decode is weight-bandwidth \
         bound, the paper's core observation; quality falls instead, see \
         Fig 18/Fig 1.)"
    );
}
