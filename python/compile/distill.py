"""Top-K sparsity-aware self-distillation (paper §5).

Teacher = the dense checkpoint; student = the same weights pushed through the
Top-K masked forward with **straight-through-estimated** mask gradients
(Eq 10-11) and the **γ-combined KLD+CE loss** (Eq 12-13):

    L_SD = γ · D_KL(P_T || P_S) + (1-γ) · CE(y_T, y_S)

γ depends on the sparsity level (high sparsity → CE-heavy, see
DistillConfig.gamma). Distillation happens once at a high sparsity level and
the result is evaluated across the whole grid ("one-distill-all-scale",
§5.2) — the Fig 18 table comes out of ``--eval``.

Run: ``cd python && python -m compile.distill [--steps N] [--eval]``
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import TINY, SPARSITY_GRID, DistillConfig
from . import model as M
from .train import adamw_init, adamw_update


def kld(p_logits, q_logits):
    """D_KL(P || Q) per Eq 12, averaged over batch/time."""
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    logq = jax.nn.log_softmax(q_logits, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1))


def sd_loss(teacher_logits, student_logits, gamma):
    """Eq 13: γ·KLD(teacher||student) + (1-γ)·CE(teacher labels, student)."""
    y_t = jnp.argmax(teacher_logits, axis=-1)
    ce = M.xent_loss(student_logits, y_t)
    return gamma * kld(teacher_logits, student_logits) + (1 - gamma) * ce


def distill(model_cfg=TINY, dcfg: DistillConfig = None,
            out_dir="../artifacts", log=print):
    dcfg = dcfg or DistillConfig()
    from .aot import flatten_ckpt, unflatten_ckpt

    teacher = unflatten_ckpt(
        np.load(os.path.join(out_dir, "ckpt_dense.npz")), model_cfg)
    student = jax.tree.map(jnp.copy, teacher)
    opt = adamw_init(student)
    data = corpus.batches(corpus.train_corpus(seed=4242),
                          dcfg.seq_len, dcfg.batch_size, seed=dcfg.seed)
    sp = dcfg.distill_sp
    gamma = dcfg.gamma(sp)

    @jax.jit
    def step_fn(student, opt, x):
        t_logits = M.dense_forward(teacher, model_cfg, x)

        def loss_fn(s):
            s_logits = M.sparse_forward(s, model_cfg, x, sp)
            return sd_loss(t_logits, s_logits, gamma)

        loss, grads = jax.value_and_grad(loss_fn)(student)
        student, opt = adamw_update(student, grads, opt, dcfg.lr, wd=0.0)
        return student, opt, loss

    t0 = time.time()
    for step in range(dcfg.steps):
        x, _ = next(data)
        student, opt, loss = step_fn(student, opt, jnp.asarray(x))
        if step % 20 == 0 or step == dcfg.steps - 1:
            log(f"[distill] step {step:4d} sd-loss {float(loss):.4f} "
                f"(sp={sp}, gamma={gamma:.2f}, {time.time()-t0:.0f}s)")

    path = os.path.join(out_dir, "ckpt_distilled.npz")
    np.savez(path, **flatten_ckpt(student))
    log(f"[distill] wrote {path}")
    return student


def evaluate(model_cfg=TINY, out_dir="../artifacts", log=print,
             n_windows=24):
    """Fig 18: perplexity of baseline (top-k on dense ckpt) vs distilled,
    across the sparsity grid. Writes artifacts/distill_eval.json."""
    from .aot import unflatten_ckpt

    dense = unflatten_ckpt(
        np.load(os.path.join(out_dir, "ckpt_dense.npz")), model_cfg)
    dist_path = os.path.join(out_dir, "ckpt_distilled.npz")
    distilled = (unflatten_ckpt(np.load(dist_path), model_cfg)
                 if os.path.exists(dist_path) else None)
    toks = corpus.eval_corpus()[: 128 * n_windows + 1]

    rows = []
    ppl_dense = M.perplexity(dense, model_cfg, toks)
    rows.append({"sp": 0.0, "baseline": ppl_dense,
                 "distilled": ppl_dense})
    log(f"[eval] dense ppl = {ppl_dense:.3f}")
    for sp in SPARSITY_GRID:
        base = M.perplexity(dense, model_cfg, toks, sp=sp)
        dist = (M.perplexity(distilled, model_cfg, toks, sp=sp)
                if distilled is not None else float("nan"))
        rows.append({"sp": sp, "baseline": base, "distilled": dist})
        log(f"[eval] sp={sp:.1f}  baseline ppl={base:8.3f}  "
            f"distilled ppl={dist:8.3f}")
    out = {"rows": rows, "n_eval_tokens": len(toks)}
    with open(os.path.join(out_dir, "distill_eval.json"), "w") as f:
        json.dump(out, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=DistillConfig.steps)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--eval", action="store_true",
                    help="evaluate ppl across the sparsity grid (Fig 18)")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    dcfg = DistillConfig(steps=args.steps)
    if not args.skip_train:
        distill(TINY, dcfg, args.out)
    if args.eval:
        evaluate(TINY, args.out)


if __name__ == "__main__":
    main()
