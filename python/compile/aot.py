"""AOT lowering: every decode-step function → HLO *text* artifact, plus the
AWGF weight file, the runtime manifest, and golden test vectors.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the text parser reassigns
ids. See /opt/xla-example/README.md.

Outputs under --out (default ../artifacts):
    model.awgf            reordered + quantized weights (export.py)
    model_config.json     model geometry + artifact manifest + layout mirror
    goldens.json          prompt/logits/greedy-continuation test vectors
    <name>.hlo.txt        one per artifact (see `artifact_specs`)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, export
from .configs import TINY, SPARSITY_GRID, ModelConfig
from . import model as M

F32 = jnp.float32
I32 = jnp.int32

# Smallest bucketed attention window to compile (== the runtime's default
# --kv-block-tokens; buckets below one KV block can never be selected).
ATTN_BUCKET_FLOOR = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sp_tag(sp) -> str:
    return "dense" if sp is None else f"sp{int(round(sp * 100)):02d}"


def artifact_specs(cfg: ModelConfig):
    """(name, fn, arg ShapeDtypeStructs, n_outputs) for every artifact."""
    S = lambda *shape: jax.ShapeDtypeStruct(shape, F32)
    d, qd, dkv, dff, V = (cfg.d_model, cfg.q_dim, cfg.d_kv, cfg.d_ff,
                          cfg.vocab_size)
    specs = []
    for sp in [None] + SPARSITY_GRID:
        ka = cfg.k_active(sp, d) if sp else d
        ko = cfg.k_active(sp, qd) if sp else qd
        kf = cfg.k_active(sp, dff) if sp else dff
        t = sp_tag(sp)
        specs += [
            (f"qkv_{t}", M.qkv_step,
             [S(1, ka), S(ka, qd), S(ka, dkv), S(ka, dkv)], 3),
            (f"o_{t}", M.proj_step, [S(1, ko), S(ko, d)], 1),
            (f"gu_{t}", M.gu_step, [S(1, ka), S(ka, dff), S(ka, dff)], 1),
            (f"down_{t}", M.proj_step, [S(1, kf), S(kf, d)], 1),
        ]
    specs += [
        ("attn_core", functools.partial(M.attn_core_step, cfg),
         [S(1, qd), S(1, dkv), S(1, dkv), S(cfg.max_seq, dkv),
          S(cfg.max_seq, dkv), jax.ShapeDtypeStruct((), I32)], 3),
    ]
    # Length-bucketed attention windows: power-of-two caps from the
    # default KV block size up to (excl.) max_seq — the full window stays
    # plain "attn_core". The rust engine gathers only ceil-to-bucket rows
    # per step instead of the whole [max_seq, d_kv] window; artifact count
    # is bounded by log2(max_seq). Same traced function — any cap >=
    # pos+1 is bit-identical (see model.attn_core_step).
    cap = ATTN_BUCKET_FLOOR
    while cap < cfg.max_seq:
        specs.append(
            (f"attn_core_{cap}", functools.partial(M.attn_core_step, cfg),
             [S(1, qd), S(1, dkv), S(1, dkv), S(cap, dkv), S(cap, dkv),
              jax.ShapeDtypeStruct((), I32)], 3))
        cap *= 2
    specs += [
        ("logits", M.logits_step, [S(1, d), S(d, V)], 1),
        ("dense_layer", functools.partial(M.dense_layer_step, cfg),
         [S(1, d), S(d, qd), S(d, dkv), S(d, dkv), S(qd, d), S(d, dff),
          S(d, dff), S(dff, d), S(d,), S(d,), S(cfg.max_seq, dkv),
          S(cfg.max_seq, dkv), jax.ShapeDtypeStruct((), I32)], 3),
    ]
    return specs


def load_params(cfg: ModelConfig, out_dir: str):
    """Prefer the distilled checkpoint, then the dense one, else random init."""
    for name in ("ckpt_distilled.npz", "ckpt_dense.npz"):
        p = os.path.join(out_dir, name)
        if os.path.exists(p):
            print(f"[aot] loading {p}")
            return unflatten_ckpt(np.load(p), cfg), name
    print("[aot] no checkpoint found; using random init")
    return M.init_params(cfg, jax.random.PRNGKey(0)), "random"


def flatten_ckpt(params):
    flat = {"embed": params["embed"], "g_final": params["g_final"],
            "lm_head": params["lm_head"]}
    for li, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"layers.{li}.{k}"] = v
    return {k: np.asarray(v) for k, v in flat.items()}


def unflatten_ckpt(flat, cfg: ModelConfig):
    layers = []
    for li in range(cfg.n_layers):
        layers.append({
            k: jnp.asarray(flat[f"layers.{li}.{k}"])
            for k in ("wq", "wk", "wv", "wo", "wg", "wu", "wd",
                      "g_attn", "g_mlp")
        })
    return {
        "embed": jnp.asarray(flat["embed"]),
        "layers": layers,
        "g_final": jnp.asarray(flat["g_final"]),
        "lm_head": jnp.asarray(flat["lm_head"]),
    }


def make_goldens(qparams, cfg: ModelConfig):
    """Golden vectors computed with the *quantize-dequantized* weights — the
    exact f32 values the rust engine sees."""
    prompt = corpus.encode("the sparse model swaps active weights. ")
    out = {"prompt": prompt}
    for sp, key in [(0.6, "sp60"), (None, "dense")]:
        logits, gen = M.sparse_decode_reference(qparams, cfg, sp, prompt,
                                                n_gen=12)
        out[key] = {
            "logits_last_prompt": np.asarray(
                logits[len(prompt) - 1]).tolist(),
            "greedy": gen,
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quant", default="q4_0",
                    choices=["f32", "q8_0", "q4_0"])
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    cfg = TINY

    params, ckpt_src = load_params(cfg, args.out)

    # ---- weights: AWGF file + quantized view for goldens
    hdr = export.write_awgf(os.path.join(args.out, "model.awgf"), params,
                            cfg, quant=args.quant,
                            group_size=args.group_size)
    qparams = export.quantized_params(params, cfg, args.quant)
    print(f"[aot] wrote model.awgf (quant={args.quant}, "
          f"N={args.group_size}, ckpt={ckpt_src})")

    # ---- HLO artifacts
    manifest = {}
    for name, fn, specs, n_out in artifact_specs(cfg):
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest[name] = {
            "file": fname,
            "inputs": [[list(s.shape), str(s.dtype)] for s in specs],
            "n_outputs": n_out,
        }
        print(f"[aot] lowered {name} ({len(text)} chars)")

    # ---- sparsity level table (what rust needs to pick k per op)
    levels = []
    for sp in SPARSITY_GRID:
        levels.append({
            "sp": sp,
            "tag": sp_tag(sp),
            "k_attn": cfg.k_active(sp, cfg.d_model),
            "k_o": cfg.k_active(sp, cfg.q_dim),
            "k_ff": cfg.k_active(sp, cfg.d_ff),
        })

    config = {
        "model": cfg.to_dict(),
        "quant": args.quant,
        "group_size": args.group_size,
        "ckpt": ckpt_src,
        "sparsity_levels": levels,
        "artifacts": manifest,
        "weights_file": "model.awgf",
    }
    with open(os.path.join(args.out, "model_config.json"), "w") as f:
        json.dump(config, f, indent=1)

    if not args.skip_goldens:
        goldens = make_goldens(qparams, cfg)
        with open(os.path.join(args.out, "goldens.json"), "w") as f:
            json.dump(goldens, f)
        print(f"[aot] goldens: sp60 greedy={goldens['sp60']['greedy'][:8]}...")
    print("[aot] done")


if __name__ == "__main__":
    main()
