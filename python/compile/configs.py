"""Model + system configuration shared across the python compile path.

The rust side reads the JSON emitted by `aot.py` (`artifacts/model_config.json`);
keep field names in sync with `rust/src/config/`.
"""

from dataclasses import dataclass, field, asdict
from typing import List


# Sparsity grid used everywhere (paper Fig 14: 0.5..0.8; Fig 18 adds 0.9).
# sp = fraction of weight channels *skipped* per op.
SPARSITY_GRID: List[float] = [0.5, 0.6, 0.7, 0.8, 0.9]


@dataclass
class ModelConfig:
    """Geometry of the byte-level transformer (Llama-family architecture:
    RMSNorm, RoPE, GQA attention, SwiGLU FFN, untied LM head)."""

    name: str = "tiny"
    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 8
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 384
    max_seq: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    def k_active(self, sp: float, dim: int) -> int:
        """Number of active channels of an input dimension `dim` at sparsity sp."""
        k = int(round(dim * (1.0 - sp)))
        return max(1, min(dim, k))

    def param_count(self) -> int:
        d, dff = self.d_model, self.d_ff
        per_layer = (
            d * self.q_dim          # wq
            + d * self.d_kv * 2     # wk, wv
            + self.q_dim * d        # wo
            + d * dff * 2           # wg, wu
            + dff * d               # wd
            + 2 * d                 # norms
        )
        return (
            self.vocab_size * d      # embed
            + self.n_layers * per_layer
            + d                      # final norm
            + d * self.vocab_size    # lm head
        )

    def to_dict(self):
        dd = asdict(self)
        dd["d_kv"] = self.d_kv
        dd["q_dim"] = self.q_dim
        dd["param_count"] = self.param_count()
        return dd


TINY = ModelConfig()

# A deeper/wider variant exercised by shape tests only (not trained).
SMALL = ModelConfig(
    name="small", d_model=256, n_layers=12, n_heads=8, n_kv_heads=4,
    head_dim=32, d_ff=768,
)


@dataclass
class TrainConfig:
    seq_len: int = 128
    batch_size: int = 8
    steps: int = 400
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    seed: int = 0
    eval_every: int = 100
    eval_batches: int = 4


@dataclass
class DistillConfig:
    """Sparsity-aware self-distillation (paper §5)."""

    seq_len: int = 128
    batch_size: int = 8
    steps: int = 150
    lr: float = 8e-6 * 100   # paper uses 8e-6 on a 7B model; scaled for tiny
    seed: int = 1
    # distill at a single high sparsity; evaluate across the grid
    # ("one-distill-all-scale", paper §5.2)
    distill_sp: float = 0.8
    # gamma in Eq. 13 as a function of sparsity: high sparsity -> CE-heavy
    def gamma(self, sp: float) -> float:
        # gamma -> 1 (KLD) at low sparsity, -> 0 (CE) at high sparsity.
        return float(max(0.0, min(1.0, 1.6 - 1.6 * sp)))
