"""Offline analyses reproducing the paper's motivation figures:

  Fig 2 — upper-bound contextual sparsity during decoding (|W|·|x| scoring)
  Fig 3 — ReLU-style zero sparsity vs Top-K magnitude sparsity
  Fig 4 — cross-layer activation cosine similarity / top-k precision
          (per layer pair, the detailed view; the rust engine reports the
          aggregated runtime view)

Run: ``cd python && python -m compile.analysis <upper-bound|sparsity-kinds|
similarity> [--out ../artifacts]``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import TINY
from . import model as M
from .kernels import ref


def _load(out_dir):
    from .aot import load_params

    params, src = load_params(TINY, out_dir)
    return params, src


# ---------------------------------------------------------------- Fig 2


def upper_bound(out_dir, n_tokens=48, step=0.02):
    """Per decoded token: the minimal fraction of weights (ranked by
    S_ij = |W_ij|·|x_j|, applied row-wise as in our channel granularity)
    that still reproduces the dense argmax token."""
    params, _ = _load(out_dir)
    cfg = TINY
    toks = corpus.eval_corpus()[: n_tokens + 1]

    fractions = []
    for pos in range(1, n_tokens):
        prefix = jnp.asarray(toks[:pos], jnp.int32)[None]
        dense_logits = M.dense_forward(params, cfg, prefix)[0, -1]
        want = int(jnp.argmax(dense_logits))
        # bisect over the sparsity grid (coarse scan, matches the paper's
        # incremental 1% removal in spirit at channel granularity)
        found = 1.0
        for keep in np.arange(step, 1.0 + 1e-9, step):
            sp = 1.0 - float(keep)
            logits = M.sparse_forward(params, cfg, prefix, sp)[0, -1]
            if int(jnp.argmax(logits)) == want:
                found = float(keep)
                break
        fractions.append(found)
        if pos % 10 == 0:
            print(f"[fig2] token {pos}: active fraction {found:.2f}")
    out = {"fractions": fractions, "step": step}
    path = os.path.join(out_dir, "upper_bound.json")
    with open(path, "w") as f:
        json.dump(out, f)
    arr = np.asarray(fractions)
    print(f"[fig2] mean {arr.mean():.3f} max {arr.max():.3f} -> {path}")
    return out


# ---------------------------------------------------------------- Fig 3


def sparsity_kinds(out_dir):
    """ReLU-style natural zeros vs Top-K magnitude sparsity of the FFN
    intermediate activation (SwiGLU models have almost no exact zeros —
    the paper's motivation for Top-K)."""
    params, _ = _load(out_dir)
    cfg = TINY
    toks = jnp.asarray(corpus.eval_corpus()[:129], jnp.int32)[None]

    # capture the FFN intermediate of a middle layer
    x = params["embed"][toks]
    angles = M.rope_freqs(cfg, jnp.arange(x.shape[1]))
    acts = None
    for li, lp in enumerate(params["layers"]):
        h = ref.rmsnorm_ref(x, lp["g_attn"], cfg.norm_eps)
        B, T, _ = h.shape
        q = (h @ lp["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q, k = M.apply_rope(q, angles), M.apply_rope(k, angles)
        attn = M._attention(cfg, q, k, v)
        x = x + attn @ lp["wo"]
        h = ref.rmsnorm_ref(x, lp["g_mlp"], cfg.norm_eps)
        inter = ref.silu_ref(h @ lp["wg"]) * (h @ lp["wu"])
        if li == cfg.n_layers // 2:
            acts = inter
        x = x + inter @ lp["wd"]

    a = np.asarray(acts).reshape(-1)
    exact_zero = float((a == 0.0).mean())
    near_zero = float((np.abs(a) < 1e-3 * np.abs(a).max()).mean())
    out = {
        "exact_zero_frac": exact_zero,
        "near_zero_frac": near_zero,
        "abs_quantiles": {
            str(q): float(np.quantile(np.abs(a), q))
            for q in (0.5, 0.8, 0.9, 0.99)
        },
    }
    path = os.path.join(out_dir, "sparsity_kinds.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[fig3] exact zeros: {exact_zero:.4%} (ReLU would be ~90%+); "
          f"|a| below 0.1% of max: {near_zero:.2%}")
    print(f"[fig3] -> Top-K magnitude selection is required for SwiGLU "
          f"models, as the paper argues ({path})")
    return out


# ---------------------------------------------------------------- Fig 4


def similarity(out_dir, n_tokens=96, sp=0.5):
    """Per-layer-pair cosine similarity + top-k precision of the attention
    input activation (the paper's Fig 4a, computed offline)."""
    params, _ = _load(out_dir)
    cfg = TINY
    toks = jnp.asarray(corpus.eval_corpus()[: n_tokens + 1], jnp.int32)[None]
    k = cfg.k_active(sp, cfg.d_model)

    # collect per-layer attention inputs for every position
    x = params["embed"][toks]
    angles = M.rope_freqs(cfg, jnp.arange(x.shape[1]))
    per_layer = []
    for lp in params["layers"]:
        h = ref.rmsnorm_ref(x, lp["g_attn"], cfg.norm_eps)
        per_layer.append(np.asarray(h[0]))
        B, T, _ = h.shape
        q = (h @ lp["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        kk = (h @ lp["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q, kk = M.apply_rope(q, angles), M.apply_rope(kk, angles)
        attn = M._attention(cfg, q, kk, v)
        x = x + attn @ lp["wo"]
        h2 = ref.rmsnorm_ref(x, lp["g_mlp"], cfg.norm_eps)
        x = x + (ref.silu_ref(h2 @ lp["wg"]) * (h2 @ lp["wu"])) @ lp["wd"]

    rows = []
    for li in range(cfg.n_layers - 1):
        a, b = per_layer[li], per_layer[li + 1]
        cos = float(np.mean(np.sum(a * b, -1)
                            / (np.linalg.norm(a, axis=-1)
                               * np.linalg.norm(b, axis=-1) + 1e-9)))
        prec = []
        for t in range(a.shape[0]):
            ia = set(np.argsort(-np.abs(a[t]))[:k].tolist())
            ib = set(np.argsort(-np.abs(b[t]))[:k].tolist())
            prec.append(len(ia & ib) / k)
        rows.append({"layer_pair": f"{li}->{li+1}", "cosine": cos,
                     "topk_precision": float(np.mean(prec))})
        print(f"[fig4] {li}->{li+1}: cos {cos:.3f} "
              f"precision {np.mean(prec):.3f}")
    path = os.path.join(out_dir, "similarity.json")
    with open(path, "w") as f:
        json.dump({"rows": rows, "k": k}, f, indent=1)
    print(f"[fig4] -> {path}")
    return rows


# ------------------------------------------------- pruned baseline (Fig 1)


def pruned_baseline(out_dir, n_windows=24):
    """Static magnitude pruning (RIA/CFSP-like stand-in): prune each weight
    matrix's smallest-|W| entries at ratio sp, measure ppl. Adds the
    'pruned' column consumed by `activeflow bench pareto`."""
    from .configs import SPARSITY_GRID
    from .aot import load_params

    params, _ = load_params(TINY, out_dir)
    toks = corpus.eval_corpus()[: 128 * n_windows + 1]

    path = os.path.join(out_dir, "distill_eval.json")
    with open(path) as f:
        eval_data = json.load(f)

    for row in eval_data["rows"]:
        sp = row["sp"]
        if sp == 0.0:
            row["pruned"] = row["baseline"]
            continue
        pruned = jax.tree.map(lambda x: x, params)
        layers = []
        for lp in params["layers"]:
            nl = dict(lp)
            for op in ("wq", "wk", "wv", "wo", "wg", "wu", "wd"):
                w = np.asarray(lp[op])
                t = np.quantile(np.abs(w), sp)
                nl[op] = jnp.asarray(np.where(np.abs(w) >= t, w, 0.0))
            layers.append(nl)
        pruned = {**params, "layers": layers}
        row["pruned"] = M.perplexity(pruned, TINY, toks)
        print(f"[pruned] sp={sp}: ppl {row['pruned']:.3f} "
              f"(topk baseline {row['baseline']:.3f})")
    with open(path, "w") as f:
        json.dump(eval_data, f, indent=1)
    print(f"[pruned] updated {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("what", choices=["upper-bound", "sparsity-kinds",
                                     "similarity", "pruned", "all"])
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tokens", type=int, default=48)
    args = ap.parse_args()
    if args.what in ("upper-bound", "all"):
        upper_bound(args.out, n_tokens=args.tokens)
    if args.what in ("sparsity-kinds", "all"):
        sparsity_kinds(args.out)
    if args.what in ("similarity", "all"):
        similarity(args.out)
    if args.what in ("pruned", "all"):
        pruned_baseline(args.out)


if __name__ == "__main__":
    main()
