"""From-scratch pretraining of the tiny byte-level transformer on the
embedded corpus. Produces artifacts/ckpt_dense.npz — the "teacher" for
self-distillation and the dense weights the serving engine loads.

Run: ``cd python && python -m compile.train [--steps N] [--out ../artifacts]``
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import TINY, TrainConfig
from . import model as M


def adamw_init(params):
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": 0}


def adamw_update(params, grads, state, lr, wd=0.01, b1=0.9, b2=0.95,
                 eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def lr_schedule(step, cfg: TrainConfig):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup)
    prog = jnp.clip((step - cfg.warmup) / max(1, cfg.steps - cfg.warmup), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def train(model_cfg=TINY, tcfg: TrainConfig = None, out_dir="../artifacts",
          log=print):
    tcfg = tcfg or TrainConfig()
    params = M.init_params(model_cfg, jax.random.PRNGKey(tcfg.seed))
    opt = adamw_init(params)
    data = corpus.batches(corpus.train_corpus(), tcfg.seq_len,
                          tcfg.batch_size, seed=tcfg.seed + 99)
    eval_toks = corpus.eval_corpus()

    @jax.jit
    def step_fn(params, opt, x, y, lr):
        def loss_fn(p):
            return M.xent_loss(M.dense_forward(p, model_cfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr,
                                   wd=tcfg.weight_decay)
        return params, opt, loss

    t0 = time.time()
    for step in range(tcfg.steps):
        x, y = next(data)
        lr = lr_schedule(step, tcfg)
        params, opt, loss = step_fn(params, opt, jnp.asarray(x),
                                    jnp.asarray(y), lr)
        if step % 20 == 0 or step == tcfg.steps - 1:
            log(f"[train] step {step:4d} loss {float(loss):.4f} "
                f"lr {float(lr):.2e} ({time.time()-t0:.0f}s)")
    ppl = M.perplexity(params, model_cfg, eval_toks[: 40 * tcfg.seq_len],
                       seq_len=tcfg.seq_len)
    log(f"[train] eval ppl (dense) = {ppl:.3f}")

    os.makedirs(out_dir, exist_ok=True)
    from .aot import flatten_ckpt
    path = os.path.join(out_dir, "ckpt_dense.npz")
    np.savez(path, **flatten_ckpt(params))
    log(f"[train] wrote {path}")
    return params, ppl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=TrainConfig.steps)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    tcfg = TrainConfig(steps=args.steps)
    train(TINY, tcfg, args.out)


if __name__ == "__main__":
    main()
