"""AWGF ("Active-Weight GGUF") writer: the cross-layer-group reordered weight
file (paper §3 Fig 9) + block quantization (Q8_0 / Q4_0, paper §6).

Format (mirrored by rust/src/layout/):

    magic   b"AWGF"
    version u32 LE (=1)
    hdr_len u32 LE
    header  JSON (hdr_len bytes): model config, quant kind, group_size N,
            dense-tensor index, sparse-op index (see below)
    pad     zero bytes to the next 4096 boundary
    payload

Sparse ops (wq wk wv wo wg wu wd) are stored **channel-major within each
layer group**: for group g covering layers [l0..l0+N), the rows are laid out

    for c in 0..d_in:  for l in l0..l0+N:  row(l, c)     # one "chunk" per c

so one contiguous read of ``N * row_bytes`` fetches channel c for the whole
group — exactly the large-I/O preload unit of Fig 9. Dense always-resident
tensors (embed, norms, lm_head) are raw little-endian f32.

Quantized rows (blocks of 32 along d_out):
    q8_0: per block f32 scale + 32  i8 (value = q * scale)
    q4_0: per block f32 scale + 16  u8 (two nibbles; value = (n - 8) * scale)
"""

import json
import struct

import numpy as np

from .configs import ModelConfig

SPARSE_OPS = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]
ALIGN = 4096
QBLOCK = 32


# ------------------------------------------------------------ quantization


def q8_0_row_bytes(dout: int) -> int:
    assert dout % QBLOCK == 0
    return (dout // QBLOCK) * (4 + QBLOCK)


def q4_0_row_bytes(dout: int) -> int:
    assert dout % QBLOCK == 0
    return (dout // QBLOCK) * (4 + QBLOCK // 2)


def row_bytes(quant: str, dout: int) -> int:
    if quant == "f32":
        return 4 * dout
    if quant == "q8_0":
        return q8_0_row_bytes(dout)
    if quant == "q4_0":
        return q4_0_row_bytes(dout)
    raise ValueError(quant)


def quantize_row(row: np.ndarray, quant: str) -> bytes:
    """Quantize one f32 row; returns packed bytes."""
    row = np.asarray(row, dtype=np.float32)
    if quant == "f32":
        return row.tobytes()
    out = bytearray()
    for b in range(0, len(row), QBLOCK):
        blk = row[b : b + QBLOCK]
        amax = float(np.max(np.abs(blk)))
        if quant == "q8_0":
            scale = amax / 127.0 if amax > 0 else 1.0
            q = np.clip(np.round(blk / scale), -127, 127).astype(np.int8)
            out += struct.pack("<f", scale) + q.tobytes()
        else:  # q4_0
            scale = amax / 7.0 if amax > 0 else 1.0
            q = np.clip(np.round(blk / scale), -7, 7).astype(np.int8) + 8
            packed = (q[0::2] & 0xF) | ((q[1::2] & 0xF) << 4)
            out += struct.pack("<f", scale) + packed.astype(np.uint8).tobytes()
    return bytes(out)


def dequantize_row(data: bytes, dout: int, quant: str) -> np.ndarray:
    """Inverse of quantize_row (bit-exact with rust layout::quant)."""
    if quant == "f32":
        return np.frombuffer(data, dtype="<f4", count=dout).copy()
    out = np.empty(dout, dtype=np.float32)
    off = 0
    for b in range(0, dout, QBLOCK):
        (scale,) = struct.unpack_from("<f", data, off)
        off += 4
        if quant == "q8_0":
            q = np.frombuffer(data, dtype=np.int8, count=QBLOCK, offset=off)
            off += QBLOCK
            out[b : b + QBLOCK] = q.astype(np.float32) * scale
        else:
            p = np.frombuffer(data, dtype=np.uint8, count=QBLOCK // 2, offset=off)
            off += QBLOCK // 2
            lo = (p & 0xF).astype(np.int32) - 8
            hi = (p >> 4).astype(np.int32) - 8
            blk = np.empty(QBLOCK, dtype=np.float32)
            blk[0::2] = lo
            blk[1::2] = hi
            out[b : b + QBLOCK] = blk * scale
    return out


def quantize_matrix(w: np.ndarray, quant: str) -> np.ndarray:
    """Round-trip a [din,dout] matrix through quantization; returns the f32
    values the runtime will actually see."""
    if quant == "f32":
        return np.asarray(w, np.float32)
    dout = w.shape[1]
    return np.stack([
        dequantize_row(quantize_row(r, quant), dout, quant) for r in w
    ])


# ------------------------------------------------------------- AWGF writer


def op_shapes(cfg: ModelConfig):
    return {
        "wq": (cfg.d_model, cfg.q_dim),
        "wk": (cfg.d_model, cfg.d_kv),
        "wv": (cfg.d_model, cfg.d_kv),
        "wo": (cfg.q_dim, cfg.d_model),
        "wg": (cfg.d_model, cfg.d_ff),
        "wu": (cfg.d_model, cfg.d_ff),
        "wd": (cfg.d_ff, cfg.d_model),
    }


def write_awgf(path: str, params, cfg: ModelConfig, quant: str = "q4_0",
               group_size: int = 4):
    """Write params to `path` in AWGF layout. Returns the header dict."""
    np_params = _to_numpy(params)
    shapes = op_shapes(cfg)
    n_groups = (cfg.n_layers + group_size - 1) // group_size

    # ---- plan offsets
    payload = bytearray()
    dense_index = {}

    def put_dense(name, arr):
        arr = np.ascontiguousarray(arr, dtype="<f4")
        dense_index[name] = {
            "offset": len(payload), "len": arr.nbytes,
            "shape": list(arr.shape),
        }
        payload.extend(arr.tobytes())

    put_dense("embed", np_params["embed"])
    put_dense("g_final", np_params["g_final"])
    put_dense("lm_head", np_params["lm_head"])
    for li in range(cfg.n_layers):
        put_dense(f"g_attn.{li}", np_params["layers"][li]["g_attn"])
        put_dense(f"g_mlp.{li}", np_params["layers"][li]["g_mlp"])

    ops_index = {}
    for op in SPARSE_OPS:
        din, dout = shapes[op]
        rb = row_bytes(quant, dout)
        groups = []
        for g in range(n_groups):
            l0 = g * group_size
            layers = list(range(l0, min(l0 + group_size, cfg.n_layers)))
            # channel-major within the group
            off = len(payload)
            for c in range(din):
                for l in layers:
                    w = np_params["layers"][l][op]
                    payload.extend(quantize_row(w[c], quant))
            groups.append({"layers": layers, "offset": off})
        ops_index[op] = {
            "d_in": din, "d_out": dout, "row_bytes": rb, "groups": groups,
        }

    header = {
        "model": cfg.to_dict(),
        "quant": quant,
        "group_size": group_size,
        "dense": dense_index,
        "ops": ops_index,
    }
    hdr = json.dumps(header).encode()
    pre = b"AWGF" + struct.pack("<II", 1, len(hdr)) + hdr
    pad = (-len(pre)) % ALIGN
    with open(path, "wb") as f:
        f.write(pre + b"\x00" * pad + bytes(payload))
    return header


def quantized_params(params, cfg: ModelConfig, quant: str):
    """The param pytree after a quantize→dequantize round trip — i.e. the f32
    weights the rust engine computes with. Golden vectors use these."""
    np_params = _to_numpy(params)
    out = {
        "embed": np_params["embed"],
        "g_final": np_params["g_final"],
        "lm_head": np_params["lm_head"],
        "layers": [],
    }
    for lp in np_params["layers"]:
        out["layers"].append({
            **{op: quantize_matrix(lp[op], quant) for op in SPARSE_OPS},
            "g_attn": lp["g_attn"],
            "g_mlp": lp["g_mlp"],
        })
    return out


def _to_numpy(tree):
    if isinstance(tree, dict):
        return {k: _to_numpy(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_to_numpy(v) for v in tree]
    return np.asarray(tree, dtype=np.float32)


# ------------------------------------------------------------- AWGF reader
# (python-side reader used by tests; the production reader is rust layout/)


def read_awgf(path: str):
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"AWGF"
    version, hdr_len = struct.unpack_from("<II", data, 4)
    assert version == 1
    header = json.loads(data[12 : 12 + hdr_len])
    payload_off = (12 + hdr_len + ALIGN - 1) // ALIGN * ALIGN
    return header, data[payload_off:]


def read_channel(header, payload, op: str, layer: int, channel: int) -> np.ndarray:
    """Fetch + dequantize one weight row (the runtime's unit of transfer)."""
    info = header["ops"][op]
    quant = header["quant"]
    rb = info["row_bytes"]
    for grp in info["groups"]:
        if layer in grp["layers"]:
            n = len(grp["layers"])
            j = grp["layers"].index(layer)
            off = grp["offset"] + (channel * n + j) * rb
            return dequantize_row(payload[off : off + rb], info["d_out"], quant)
    raise KeyError((op, layer))
