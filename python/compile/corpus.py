"""Embedded multi-domain tiny corpus + byte tokenizer.

Substitute for WikiText-2 / BoolQ / alpaca-c4 (see DESIGN.md §1): the cache
experiments (Fig 6 / Fig 17) only need *distribution shift across contexts and
tasks*, which distinct synthetic domains provide. The generator is
deterministic so python and rust produce identical streams.
"""

from typing import List, Tuple

# ---------------------------------------------------------------- tokenizer

VOCAB_SIZE = 256  # raw bytes


def encode(text: str) -> List[int]:
    return list(text.encode("utf-8", errors="replace"))


def decode(tokens) -> str:
    return bytes(int(t) % 256 for t in tokens).decode("utf-8", errors="replace")


# ---------------------------------------------------------------- generator
# Deterministic xorshift64* PRNG — mirrored exactly in rust/src/util/rng.rs.


class Xorshift:
    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.s = (seed ^ 0x9E3779B97F4A7C15) & self.MASK or 0x2545F4914F6CDD1D

    def next_u64(self) -> int:
        s = self.s
        s ^= (s << 13) & self.MASK
        s ^= s >> 7
        s ^= (s << 17) & self.MASK
        self.s = s
        return (s * 0x2545F4914F6CDD1D) & self.MASK

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]


_DOMAINS = {
    # "task" domains with distinct vocabulary/structure -> distinct hot weights
    "wiki": (
        ["the", "a", "an", "this", "that"],
        ["system", "language", "model", "device", "memory", "history",
         "city", "river", "theory", "century", "network", "protocol"],
        ["is", "was", "describes", "contains", "supports", "denotes"],
        ["large", "small", "early", "modern", "common", "formal"],
    ),
    "code": (
        ["fn", "let", "pub", "use", "impl", "return"],
        ["buffer", "index", "cache", "layer", "weight", "channel",
         "tensor", "queue", "thread", "handle"],
        ["loads", "stores", "maps", "returns", "computes", "updates"],
        ["mutable", "static", "atomic", "sparse", "dense", "packed"],
    ),
    "qa": (
        ["does", "is", "can", "will", "should"],
        ["question", "answer", "passage", "statement", "claim", "fact"],
        ["imply", "confirm", "support", "contradict", "mention"],
        ["true", "false", "yes", "no", "maybe"],
    ),
    "chat": (
        ["please", "could", "thanks", "okay", "sure"],
        ["assistant", "user", "message", "request", "reply", "summary"],
        ["write", "explain", "translate", "summarize", "list"],
        ["helpful", "short", "detailed", "polite", "clear"],
    ),
}

DOMAIN_NAMES = list(_DOMAINS.keys())


def gen_sentence(rng: Xorshift, domain: str) -> str:
    det, nouns, verbs, adjs = _DOMAINS[domain]
    words = [
        rng.choice(det), rng.choice(adjs), rng.choice(nouns),
        rng.choice(verbs), rng.choice(det), rng.choice(adjs),
        rng.choice(nouns),
    ]
    if rng.below(3) == 0:
        words += ["and", rng.choice(nouns)]
    return " ".join(words) + ". "


def gen_text(seed: int, n_sentences: int, domain: str = None) -> str:
    rng = Xorshift(seed)
    out = []
    for _ in range(n_sentences):
        d = domain if domain is not None else DOMAIN_NAMES[rng.below(len(DOMAIN_NAMES))]
        out.append(gen_sentence(rng, d))
    return "".join(out)


def train_corpus(seed: int = 42, n_sentences: int = 12000) -> List[int]:
    return encode(gen_text(seed, n_sentences))


def eval_corpus(seed: int = 1337, n_sentences: int = 800) -> List[int]:
    return encode(gen_text(seed, n_sentences))


def task_corpus(domain: str, seed: int = 7, n_sentences: int = 400) -> List[int]:
    """Single-domain stream — the 'downstream task' stand-ins for Fig 17b."""
    return encode(gen_text(seed, n_sentences, domain))


def batches(tokens: List[int], seq_len: int, batch_size: int, seed: int):
    """Yield (inputs, targets) int32 arrays forever (random crops)."""
    import numpy as np

    toks = np.asarray(tokens, dtype=np.int32)
    rng = Xorshift(seed)
    n = len(toks) - seq_len - 1
    while True:
        idx = [rng.below(n) for _ in range(batch_size)]
        x = np.stack([toks[i : i + seq_len] for i in idx])
        y = np.stack([toks[i + 1 : i + seq_len + 1] for i in idx])
        yield x, y
