"""L1 Pallas kernel: threshold-based activation masking (the paper's "T"
pipeline stage / §6 'kernel for generating active channel indices').

The runtime selects channels by exact top-k (rust engine); training and
analysis use the calibrated-threshold formulation below, which is what the
paper's on-device kernel implements ("maintains activation thresholds
corresponding to different LLM sparsity levels").
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0]
    o_ref[...] = jnp.where(jnp.abs(x) >= t, x, jnp.zeros_like(x))


def threshold_sparsify(x, t):
    """Zero every element of x with |x| < t. x: [1,d], t: scalar array [1]."""
    d = x.shape[-1]
    return pl.pallas_call(
        _mask_kernel,
        in_specs=[
            pl.BlockSpec((1, d), lambda: (0, 0)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, jnp.asarray(t, dtype=x.dtype).reshape(1))


def calibrate_threshold(samples, sp):
    """Per-tensor threshold achieving expected sparsity `sp` over a batch of
    activation samples [n, d]: the sp-quantile of |a|."""
    return jnp.quantile(jnp.abs(samples), sp)
