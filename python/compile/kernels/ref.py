"""Pure-jnp correctness oracles for the Pallas kernels and the sparse-op
semantics shared by python/model.py and rust/src/engine.

Sparse op semantics (DESIGN.md §3, TEAL-exact): for an input activation
``a in R^d`` and active set ``I = topk(|a|, k)``,  ``y = a[I] @ W[I, :]``.
Row (input-channel) sparsity only; output dims stay dense.
"""

import jax.numpy as jnp
import jax


def rmsnorm_ref(x, g, eps=1e-5):
    """RMSNorm over the last axis. Mirrored in rust engine::ops."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def silu_ref(x):
    return x * jax.nn.sigmoid(x)


def topk_indices_ref(a, k):
    """Indices of the k largest |a| entries, **sorted ascending** (the rust
    engine emits ascending index sets so packed-weight gathers are sequential
    in flash order)."""
    _, idx = jax.lax.top_k(jnp.abs(a), k)
    return jnp.sort(idx)


def topk_mask_ref(a, k):
    """0/1 mask keeping the k largest-|a| entries."""
    idx = topk_indices_ref(a, k)
    return jnp.zeros_like(a).at[idx].set(1.0)


def threshold_mask_ref(a, t):
    """TEAL-style calibrated-threshold mask: keep |a| >= t."""
    return (jnp.abs(a) >= t).astype(a.dtype)


def sparse_matmul_ref(xs, w):
    """Packed sparse matmul oracle: xs [1,k] (gathered activation),
    w [k,dout] (packed weight rows) -> [1,dout]."""
    return xs @ w


def sparse_linear_ref(a, w, k):
    """Full sparse linear: a [d], w [d,dout] -> [dout] using top-k rows."""
    idx = topk_indices_ref(a, k)
    return a[idx][None, :] @ w[idx, :]


def masked_linear_ref(a, w, k):
    """Equivalent masked formulation (used by distillation): (a*mask) @ w."""
    return (a * topk_mask_ref(a, k))[None, :] @ w


def gu_ref(xs, wg, wu):
    """SwiGLU gate+up on packed rows: silu(xs@wg) * (xs@wu)."""
    return silu_ref(xs @ wg) * (xs @ wu)
