"""L1 Pallas kernels: packed sparse matmul and fused SwiGLU gate/up.

These are the compute hot-spots of ActiveFlow's sparse decode path
(`y = a[I] @ W[I,:]`). The channel gather happens *outside* the contraction
(in the rust engine / in model.py) so the kernel body is a dense
[1,k] x [k,TILE_D] tile — on a real TPU this keeps the MXU systolic array
fed with dense tiles exactly like the paper keeps NEON kernels dense over
packed channels (DESIGN.md §2 Hardware adaptation).

VMEM schedule: the grid walks output tiles of width TILE_D; per step the
kernel holds xs [1,k] (k<=d_ff*4B = 1.5 KB for tiny, <=56 KB for llama-sim),
a W tile [k, TILE_D] and the output tile — comfortably double-bufferable in
a 16 MB VMEM at TILE_D=128..512.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically (DESIGN.md §8).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-tile width. 128 matches both the MXU lane width and the smallest
# dout in the tiny config; shapes that don't divide are padded by pallas.
TILE_D = 128


def _matmul_kernel(xs_ref, w_ref, o_ref):
    o_ref[...] = xs_ref[...] @ w_ref[...]


@functools.partial(jax.jit, static_argnames=())
def sparse_matmul(xs, w):
    """xs [1,k] @ w [k,dout] -> [1,dout] via a Pallas grid over dout tiles."""
    k = xs.shape[-1]
    dout = w.shape[-1]
    tile = min(TILE_D, dout)
    grid = (pl.cdiv(dout, tile),)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((k, tile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, dout), xs.dtype),
        interpret=True,
    )(xs, w)


def _gu_kernel(xs_ref, wg_ref, wu_ref, o_ref):
    g = xs_ref[...] @ wg_ref[...]
    u = xs_ref[...] @ wu_ref[...]
    o_ref[...] = g * jax.nn.sigmoid(g) * u


def gu_matmul(xs, wg, wu):
    """Fused SwiGLU gate/up: silu(xs@wg) * (xs@wu) -> [1,d_ff].

    Fusing keeps the intermediate g/u tiles in VMEM (never round-tripped to
    HBM), halving the activation traffic of the FFN front half.
    """
    k = xs.shape[-1]
    dff = wg.shape[-1]
    tile = min(TILE_D, dff)
    grid = (pl.cdiv(dff, tile),)
    return pl.pallas_call(
        _gu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((k, tile), lambda j: (0, j)),
            pl.BlockSpec((k, tile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, dff), xs.dtype),
        interpret=True,
    )(xs, wg, wu)
