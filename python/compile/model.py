"""L2: the transformer model (Llama-family: RMSNorm + RoPE + GQA + SwiGLU).

Three faces of the same model, all sharing one param pytree:

  * ``dense_forward``   — batched full-sequence forward for training.
  * ``sparse_forward``  — masked Top-K formulation with STE gradients, used by
                          self-distillation (paper §5).
  * ``*_step`` fns      — the per-op decode-step functions that ``aot.py``
                          lowers to HLO artifacts. Their op split mirrors the
                          rust engine exactly (DESIGN.md §5): rust owns
                          rmsnorm / top-k / gather / residual adds; HLO owns
                          the matmuls (Pallas kernels) and the attention core.

Weight convention: every linear is stored ``[d_in, d_out]`` so that a *row*
is one input channel — the paper's ~4 KB flash transfer unit.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.sparse_matmul import sparse_matmul, gu_matmul

# ------------------------------------------------------------------ params


def init_params(cfg: ModelConfig, key):
    """Xavier-ish init of the full param pytree."""
    def dense(key, din, dout):
        scale = (2.0 / (din + dout)) ** 0.5
        return jax.random.normal(key, (din, dout), jnp.float32) * scale

    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[li], 7)
        layers.append({
            "wq": dense(ks[0], cfg.d_model, cfg.q_dim),
            "wk": dense(ks[1], cfg.d_model, cfg.d_kv),
            "wv": dense(ks[2], cfg.d_model, cfg.d_kv),
            "wo": dense(ks[3], cfg.q_dim, cfg.d_model),
            "wg": dense(ks[4], cfg.d_model, cfg.d_ff),
            "wu": dense(ks[5], cfg.d_model, cfg.d_ff),
            "wd": dense(ks[6], cfg.d_ff, cfg.d_model),
            "g_attn": jnp.ones((cfg.d_model,), jnp.float32),
            "g_mlp": jnp.ones((cfg.d_model,), jnp.float32),
        })
    return {
        "embed": jax.random.normal(keys[-3], (cfg.vocab_size, cfg.d_model)) * 0.02,
        "layers": layers,
        "g_final": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense(keys[-2], cfg.d_model, cfg.vocab_size),
    }


# -------------------------------------------------------------------- RoPE


def rope_freqs(cfg: ModelConfig, positions):
    """[T, head_dim/2] angles for the given positions."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[:, None] * inv[None, :]


def apply_rope(x, angles):
    """x: [..., T, n_heads, head_dim]; angles: [T, head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    # broadcast angles across leading batch axes
    while cos.ndim < x1.ndim:
        cos, sin = cos[None], sin[None]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# --------------------------------------------------------- dense forward


def _attention(cfg, q, k, v, causal_from=0):
    """q: [B,T,nh,hd], k/v: [B,S,nkv,hd] -> [B,T,nh*hd]. Causal over S."""
    B, T = q.shape[0], q.shape[1]
    S = k.shape[1]
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bthd,bshd->bhts", q, k) / (cfg.head_dim ** 0.5)
    # position t (global pos = causal_from + t) may attend to s <= global pos
    tpos = causal_from + jnp.arange(T)[:, None]
    spos = jnp.arange(S)[None, :]
    mask = spos <= tpos
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", att, v)
    return out.reshape(B, T, cfg.q_dim)


def dense_forward(params, cfg: ModelConfig, tokens):
    """tokens [B,T] int32 -> logits [B,T,vocab]."""
    x = params["embed"][tokens]
    B, T, _ = x.shape
    angles = rope_freqs(cfg, jnp.arange(T))
    for lp in params["layers"]:
        h = ref.rmsnorm_ref(x, lp["g_attn"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q, k = apply_rope(q, angles), apply_rope(k, angles)
        attn = _attention(cfg, q, k, v)
        x = x + attn @ lp["wo"]
        h = ref.rmsnorm_ref(x, lp["g_mlp"], cfg.norm_eps)
        x = x + (ref.silu_ref(h @ lp["wg"]) * (h @ lp["wu"])) @ lp["wd"]
    x = ref.rmsnorm_ref(x, params["g_final"], cfg.norm_eps)
    return x @ params["lm_head"]


# ------------------------------------------------- sparse forward (distill)


@jax.custom_vjp
def ste_mask(a, mask):
    """Straight-through-estimated masking (paper §5.1 Eq 10-11): forward
    applies the 0/1 mask, backward passes gradients as identity."""
    return a * mask


def _ste_fwd(a, mask):
    return a * mask, None


def _ste_bwd(_res, g):
    return g, None


ste_mask.defvjp(_ste_fwd, _ste_bwd)


def topk_mask_batched(a, k):
    """0/1 mask of the k largest-|a| entries along the last axis (any rank).

    The input is de-tangented up front: the mask is a selection decision and
    must never carry gradient (STE supplies the identity path instead) — and
    differentiating through sort trips a gather JVP incompatibility in this
    jaxlib build anyway.
    """
    a = jax.lax.stop_gradient(a)
    kth = -jnp.sort(-jnp.abs(a), axis=-1)[..., k - 1 : k]
    return (jnp.abs(a) >= kth).astype(a.dtype)


def _sparse_lin(a, w, k):
    mask = jax.lax.stop_gradient(topk_mask_batched(a, k))
    return ste_mask(a, mask) @ w


def sparse_forward(params, cfg: ModelConfig, tokens, sp: float):
    """Masked Top-K forward with STE — the distillation student. Numerically
    equivalent (same token stream) to the rust engine's gather formulation."""
    ka = cfg.k_active(sp, cfg.d_model)
    ko = cfg.k_active(sp, cfg.q_dim)
    kf = cfg.k_active(sp, cfg.d_ff)
    x = params["embed"][tokens]
    B, T, _ = x.shape
    angles = rope_freqs(cfg, jnp.arange(T))
    for lp in params["layers"]:
        h = ref.rmsnorm_ref(x, lp["g_attn"], cfg.norm_eps)
        hm = ste_mask(h, jax.lax.stop_gradient(topk_mask_batched(h, ka)))
        q = (hm @ lp["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (hm @ lp["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (hm @ lp["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q, k = apply_rope(q, angles), apply_rope(k, angles)
        attn = _attention(cfg, q, k, v)
        x = x + _sparse_lin(attn, lp["wo"], ko)
        h = ref.rmsnorm_ref(x, lp["g_mlp"], cfg.norm_eps)
        hm = ste_mask(h, jax.lax.stop_gradient(topk_mask_batched(h, ka)))
        ff = ref.silu_ref(hm @ lp["wg"]) * (hm @ lp["wu"])
        x = x + _sparse_lin(ff, lp["wd"], kf)
    x = ref.rmsnorm_ref(x, params["g_final"], cfg.norm_eps)
    return x @ params["lm_head"]


# --------------------------------------------------- decode-step functions
# These are what aot.py lowers. Shapes are static per (cfg, sparsity level).


def qkv_step(xs, wq, wk, wv):
    """xs [1,k] (normed+gathered), packed rows -> (q [1,q_dim], k/v [1,d_kv]).
    Hot path: L1 Pallas sparse matmuls."""
    return (
        sparse_matmul(xs, wq),
        sparse_matmul(xs, wk),
        sparse_matmul(xs, wv),
    )


def attn_core_step(cfg: ModelConfig, q, k_new, v_new, kv_k, kv_v, pos):
    """Single-token attention with a static-shape KV cache.

    q [1,q_dim], k_new/v_new [1,d_kv], kv_k/kv_v [cap,d_kv], pos scalar
    i32 -> (attn_out [1,q_dim], kv_k', kv_v'). RoPE applied to q and k_new at
    `pos`; causal mask is `iota <= pos`.

    The window length is read off the cache operand, so one function lowers
    both the full `max_seq` artifact and the length-bucketed
    ``attn_core_<cap>`` family: any cap >= pos+1 is bit-identical to the
    full window, because masked lanes get -1e30 whose softmax weight
    underflows to exactly 0.0 in f32.
    """
    S = kv_k.shape[0]
    angles = rope_freqs(cfg, pos[None].astype(jnp.float32))  # [1, hd/2]
    qh = apply_rope(q.reshape(1, cfg.n_heads, cfg.head_dim), angles)
    kh = apply_rope(k_new.reshape(1, cfg.n_kv_heads, cfg.head_dim), angles)
    kv_k = jax.lax.dynamic_update_slice(kv_k, kh.reshape(1, cfg.d_kv), (pos, 0))
    kv_v = jax.lax.dynamic_update_slice(kv_v, v_new, (pos, 0))

    rep = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(kv_k.reshape(S, cfg.n_kv_heads, cfg.head_dim), rep, axis=1)
    vv = jnp.repeat(kv_v.reshape(S, cfg.n_kv_heads, cfg.head_dim), rep, axis=1)
    att = jnp.einsum("thd,shd->hts", qh, kk) / (cfg.head_dim ** 0.5)
    mask = jnp.arange(S)[None, None, :] <= pos
    att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
    out = jnp.einsum("hts,shd->thd", att, vv).reshape(1, cfg.q_dim)
    return out, kv_k, kv_v


def proj_step(xs, w):
    """Generic packed projection (o_proj / down_proj): xs [1,k] @ w [k,dout]."""
    return sparse_matmul(xs, w)


def gu_step(xs, wg, wu):
    """Fused SwiGLU gate/up on packed rows -> [1,d_ff]."""
    return gu_matmul(xs, wg, wu)


def logits_step(xn, lm_head):
    """Final projection: xn [1,d] (already final-normed in rust) @ [d,vocab]."""
    return sparse_matmul(xn, lm_head)


def dense_layer_step(cfg: ModelConfig, x, wq, wk, wv, wo, wg, wu, wd,
                     g_attn, g_mlp, kv_k, kv_v, pos):
    """Whole dense decode layer (baseline engine artifact): x [1,d] ->
    (x' [1,d], kv_k', kv_v')."""
    h = ref.rmsnorm_ref(x, g_attn, cfg.norm_eps)
    attn, kv_k, kv_v = attn_core_step(
        cfg, h @ wq, h @ wk, h @ wv, kv_k, kv_v, pos)
    x = x + attn @ wo
    h = ref.rmsnorm_ref(x, g_mlp, cfg.norm_eps)
    x = x + (ref.silu_ref(h @ wg) * (h @ wu)) @ wd
    return x, kv_k, kv_v


# ------------------------------------------- python mirror of rust decode
# Used for golden-vector generation and integration tests. Exact top-k with
# ascending index sets, f32, identical op order to rust/src/engine.


def sparse_decode_reference(params, cfg: ModelConfig, sp: float, tokens,
                            n_gen: int = 0):
    """Teacher-forced sparse decode over `tokens` (+ optional greedy
    generation). Returns (all_logits [T+n_gen-?, vocab], generated tokens).
    ``sp=None`` runs the dense path through the same op split."""
    ka = cfg.k_active(sp, cfg.d_model) if sp else cfg.d_model
    ko = cfg.k_active(sp, cfg.q_dim) if sp else cfg.q_dim
    kf = cfg.k_active(sp, cfg.d_ff) if sp else cfg.d_ff
    S = cfg.max_seq
    L = cfg.n_layers
    kv_k = [jnp.zeros((S, cfg.d_kv)) for _ in range(L)]
    kv_v = [jnp.zeros((S, cfg.d_kv)) for _ in range(L)]

    logits_all, generated = [], []
    toks = list(tokens)
    # teacher-forced: logits at every prompt position; generation: logits at
    # positions T-1 .. T+n_gen-2 drive the n_gen greedy tokens.
    total_steps = len(tokens) + n_gen - (1 if n_gen > 0 else 0)
    for pos in range(total_steps):
        t = toks[pos]
        x = params["embed"][t][None, :]
        for li, lp in enumerate(params["layers"]):
            h = ref.rmsnorm_ref(x, lp["g_attn"], cfg.norm_eps)
            idx = ref.topk_indices_ref(h[0], ka)
            xs = h[0][idx][None, :]
            q, kn, vn = qkv_step(xs, lp["wq"][idx], lp["wk"][idx], lp["wv"][idx])
            attn, kv_k[li], kv_v[li] = attn_core_step(
                cfg, q, kn, vn, kv_k[li], kv_v[li], jnp.int32(pos))
            jdx = ref.topk_indices_ref(attn[0], ko)
            x = x + proj_step(attn[0][jdx][None, :], lp["wo"][jdx])
            h = ref.rmsnorm_ref(x, lp["g_mlp"], cfg.norm_eps)
            kdx = ref.topk_indices_ref(h[0], ka)
            ff = gu_step(h[0][kdx][None, :], lp["wg"][kdx], lp["wu"][kdx])
            ldx = ref.topk_indices_ref(ff[0], kf)
            x = x + proj_step(ff[0][ldx][None, :], lp["wd"][ldx])
        xn = ref.rmsnorm_ref(x, params["g_final"], cfg.norm_eps)
        logits = logits_step(xn, params["lm_head"])[0]
        logits_all.append(logits)
        if pos + 1 >= len(toks) and len(generated) < n_gen:
            nxt = int(jnp.argmax(logits))
            toks.append(nxt)
            generated.append(nxt)
    return jnp.stack(logits_all), generated


# ------------------------------------------------------------------- loss


def xent_loss(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], axis=-1))


def perplexity(params, cfg, tokens, sp=None, seq_len=128):
    """Mean perplexity over non-overlapping windows of `tokens`."""
    import numpy as np

    toks = np.asarray(tokens, dtype=np.int32)
    n = (len(toks) - 1) // seq_len
    total, count = 0.0, 0
    for i in range(n):
        x = toks[i * seq_len : (i + 1) * seq_len][None]
        y = toks[i * seq_len + 1 : (i + 1) * seq_len + 1][None]
        if sp is None:
            logits = dense_forward(params, cfg, x)
        else:
            logits = sparse_forward(params, cfg, x, sp)
        total += float(xent_loss(logits, y)) * seq_len
        count += seq_len
    return float(jnp.exp(total / count))
