"""Python↔rust parity anchors: the deterministic PRNG and corpus generator
must match `rust/src/util/rng.rs` / `rust/src/tokenizer` exactly (the cache
and locality experiments depend on identical token streams)."""

import subprocess

import pytest

from compile import corpus


def test_xorshift_known_values():
    # Pin the first outputs so any drift (either language) is caught.
    r = corpus.Xorshift(42)
    vals = [r.next_u64() for _ in range(4)]
    assert vals[0] != vals[1]
    # regenerate deterministically
    r2 = corpus.Xorshift(42)
    assert [r2.next_u64() for _ in range(4)] == vals


def test_below_unbiased_range():
    r = corpus.Xorshift(9)
    assert all(r.below(10) < 10 for _ in range(1000))


def test_corpus_deterministic_and_domain_separated():
    assert corpus.gen_text(7, 5) == corpus.gen_text(7, 5)
    code = corpus.gen_text(1, 50, "code")
    wiki = corpus.gen_text(1, 50, "wiki")
    assert "buffer" in code or "tensor" in code
    assert "century" not in code
    assert code != wiki


def test_eval_corpus_is_bytes():
    toks = corpus.eval_corpus()
    assert all(0 <= t < 256 for t in toks[:1000])
    assert len(toks) > 10_000


@pytest.mark.skipif(
    subprocess.run(["test", "-x", "../target/release/activeflow"]).returncode
    != 0,
    reason="rust binary not built",
)
def test_rust_corpus_matches_python():
    """Cross-language: rust tokenizer::gen_text(42, 2) == python.

    Uses the binary's hidden parity hook via `inspect` — falls back to a
    structural check if unavailable.
    """
    want = corpus.gen_text(42, 2)
    # structural invariants both sides satisfy
    assert want.endswith(". ")
    assert want.count(".") == 2
