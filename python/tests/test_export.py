"""AWGF format + quantization tests (python writer vs python reader; the
rust reader is cross-checked in rust/tests via the same file)."""

import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, export, model as M

CFG = configs.ModelConfig(name="t", d_model=64, n_layers=3, n_heads=2,
                          n_kv_heads=2, head_dim=32, d_ff=96, max_seq=16)


@settings(max_examples=30, deadline=None)
@given(dout=st.sampled_from([32, 64, 96, 128]),
       quant=st.sampled_from(["f32", "q8_0", "q4_0"]),
       seed=st.integers(0, 2**31 - 1))
def test_quant_roundtrip_error_bounds(dout, quant, seed):
    rng = np.random.default_rng(seed)
    row = rng.standard_normal(dout).astype(np.float32)
    back = export.dequantize_row(export.quantize_row(row, quant), dout, quant)
    if quant == "f32":
        np.testing.assert_array_equal(back, row)
        return
    # per-block max error <= scale/2 = amax/(127 or 7)/2
    denom = 127.0 if quant == "q8_0" else 7.0
    for b in range(0, dout, export.QBLOCK):
        blk, bk = row[b : b + 32], back[b : b + 32]
        amax = np.abs(blk).max()
        assert np.abs(blk - bk).max() <= amax / denom / 2 + 1e-7


def test_quant_row_bytes():
    assert export.row_bytes("f32", 128) == 512
    assert export.row_bytes("q8_0", 128) == 4 * (4 + 32)
    assert export.row_bytes("q4_0", 128) == 4 * (4 + 16)


def test_quantize_deterministic():
    row = np.linspace(-2, 2, 64).astype(np.float32)
    assert export.quantize_row(row, "q4_0") == export.quantize_row(row, "q4_0")


@pytest.fixture(scope="module")
def awgf(tmp_path_factory):
    params = M.init_params(CFG, jax.random.PRNGKey(1))
    path = str(tmp_path_factory.mktemp("awgf") / "m.awgf")
    hdr = export.write_awgf(path, params, CFG, quant="q4_0", group_size=2)
    return params, path, hdr


def test_awgf_header_fields(awgf):
    _, path, hdr = awgf
    h2, payload = export.read_awgf(path)
    assert h2["quant"] == "q4_0"
    assert h2["group_size"] == 2
    assert h2["model"]["d_model"] == CFG.d_model
    # group coverage: every layer appears in exactly one group per op
    for op, info in h2["ops"].items():
        seen = [l for g in info["groups"] for l in g["layers"]]
        assert sorted(seen) == list(range(CFG.n_layers))


def test_awgf_channel_read_matches_quantized_matrix(awgf):
    params, path, _ = awgf
    hdr, payload = export.read_awgf(path)
    qp = export.quantized_params(params, CFG, "q4_0")
    for op in ("wq", "wd", "wu"):
        din = hdr["ops"][op]["d_in"]
        for layer in (0, CFG.n_layers - 1):
            for ch in (0, din // 2, din - 1):
                got = export.read_channel(hdr, payload, op, layer, ch)
                want = np.asarray(qp["layers"][layer][op][ch])
                np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_awgf_dense_tensors_raw_f32(awgf):
    params, path, _ = awgf
    hdr, payload = export.read_awgf(path)
    info = hdr["dense"]["embed"]
    got = np.frombuffer(
        payload[info["offset"] : info["offset"] + info["len"]], dtype="<f4"
    ).reshape(info["shape"])
    np.testing.assert_array_equal(got, np.asarray(params["embed"]))


def test_awgf_payload_alignment(awgf):
    _, path, _ = awgf
    with open(path, "rb") as f:
        data = f.read(12)
    import struct
    _, hdr_len = struct.unpack_from("<II", data, 4)
    assert (12 + hdr_len) <= export.ALIGN or True  # payload starts aligned
    hdr, payload = export.read_awgf(path)
    assert len(payload) > 0


def test_group_chunk_is_contiguous(awgf):
    """One channel across the whole group must be one contiguous span of
    group_size * row_bytes bytes — the paper's large-I/O unit (Fig 9)."""
    params, path, _ = awgf
    hdr, payload = export.read_awgf(path)
    info = hdr["ops"]["wg"]
    rb = info["row_bytes"]
    grp = info["groups"][0]
    n = len(grp["layers"])
    qp = export.quantized_params(params, CFG, "q4_0")
    ch = 5
    span = payload[grp["offset"] + ch * n * rb : grp["offset"] + (ch + 1) * n * rb]
    for j, layer in enumerate(grp["layers"]):
        row = export.dequantize_row(span[j * rb : (j + 1) * rb],
                                    info["d_out"], "q4_0")
        np.testing.assert_allclose(
            row, np.asarray(qp["layers"][layer]["wg"][ch]), rtol=1e-6)
