"""L2 model tests: shapes, sparse/dense equivalences, KV-cache decode parity,
STE gradient flow, RoPE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = configs.ModelConfig(name="test", d_model=64, n_layers=2, n_heads=4,
                          n_kv_heads=2, head_dim=16, d_ff=96, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_param_count_matches_config(params):
    n = sum(int(np.prod(np.shape(v))) for v in jax.tree.leaves(params))
    assert n == CFG.param_count()


def test_dense_forward_shape(params):
    toks = jnp.zeros((2, 10), jnp.int32)
    assert M.dense_forward(params, CFG, toks).shape == (2, 10, CFG.vocab_size)


def test_sparse_forward_shape(params):
    toks = jnp.zeros((2, 10), jnp.int32)
    out = M.sparse_forward(params, CFG, toks, 0.5)
    assert out.shape == (2, 10, CFG.vocab_size)
    assert np.isfinite(np.asarray(out)).all()


def test_sparse_approaches_dense_as_sp_to_zero(params):
    toks = (jnp.arange(12, dtype=jnp.int32) % CFG.vocab_size)[None]
    dense = M.dense_forward(params, CFG, toks)
    sp_tiny = M.sparse_forward(params, CFG, toks, 1.0 / CFG.d_ff / 2)
    np.testing.assert_allclose(np.asarray(sp_tiny), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)
    # and a genuinely sparse forward must differ
    sp_hi = M.sparse_forward(params, CFG, toks, 0.8)
    assert np.abs(np.asarray(sp_hi) - np.asarray(dense)).max() > 1e-3


def test_sparse_error_monotone_in_sparsity(params):
    """Higher sparsity ⇒ larger deviation from dense (paper Fig 1 shape)."""
    toks = (jnp.arange(16, dtype=jnp.int32) * 7 % CFG.vocab_size)[None]
    dense = np.asarray(M.dense_forward(params, CFG, toks))
    errs = []
    for sp in (0.3, 0.6, 0.9):
        out = np.asarray(M.sparse_forward(params, CFG, toks, sp))
        errs.append(float(np.mean((out - dense) ** 2)))
    assert errs[0] < errs[1] < errs[2]


def test_decode_reference_matches_dense_forward(params):
    toks = list(range(1, 9))
    logits, _ = M.sparse_decode_reference(params, CFG, None, toks)
    batch = M.dense_forward(params, CFG, jnp.asarray(toks, jnp.int32)[None])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(batch[0]),
                               rtol=2e-3, atol=5e-4)


def test_decode_reference_greedy_generation(params):
    toks = list(range(4))
    logits, gen = M.sparse_decode_reference(params, CFG, 0.5, toks, n_gen=4)
    assert len(gen) == 4
    assert logits.shape[0] == len(toks) + 4 - 1
    assert all(0 <= t < CFG.vocab_size for t in gen)


def test_attn_core_step_kv_update(params):
    lp = params["layers"][0]
    h = jax.random.normal(jax.random.PRNGKey(3), (1, CFG.d_model))
    kv_k = jnp.zeros((CFG.max_seq, CFG.d_kv))
    kv_v = jnp.zeros((CFG.max_seq, CFG.d_kv))
    out, kv_k2, kv_v2 = M.attn_core_step(
        CFG, h @ lp["wq"], h @ lp["wk"], h @ lp["wv"], kv_k, kv_v,
        jnp.int32(0))
    assert out.shape == (1, CFG.q_dim)
    # only row 0 written
    assert np.abs(np.asarray(kv_k2[0])).sum() > 0
    np.testing.assert_array_equal(np.asarray(kv_k2[1:]), 0)
    # pos 0 attends only to itself -> output = v row repeated per GQA group
    rep = CFG.n_heads // CFG.n_kv_heads
    v0 = np.asarray(kv_v2[0]).reshape(CFG.n_kv_heads, CFG.head_dim)
    got = np.asarray(out).reshape(CFG.n_heads, CFG.head_dim)
    np.testing.assert_allclose(got, np.repeat(v0, rep, axis=0),
                               rtol=1e-5, atol=1e-6)


def test_ste_gradient_flows_through_mask():
    """Paper §5.1: without STE most gradients are zeroed; with STE they pass."""
    a = jnp.linspace(-1, 1, 16)
    w = jnp.eye(16)

    def loss_ste(a):
        mask = jax.lax.stop_gradient(M.topk_mask_batched(a, 4))
        return jnp.sum(M.ste_mask(a, mask) @ w)

    def loss_hard(a):
        mask = jax.lax.stop_gradient(M.topk_mask_batched(a, 4))
        return jnp.sum((a * mask) @ w)

    g_ste = np.asarray(jax.grad(loss_ste)(a))
    g_hard = np.asarray(jax.grad(loss_hard)(a))
    assert (g_ste != 0).all()             # identity gradient everywhere
    assert (g_hard == 0).sum() == 12      # hard mask kills 12/16


def test_rope_preserves_norm_and_relative_phase():
    angles = M.rope_freqs(CFG, jnp.arange(8))
    x = jax.random.normal(jax.random.PRNGKey(5), (8, CFG.n_heads,
                                                  CFG.head_dim))
    y = M.apply_rope(x, angles)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(x[0]),
                               rtol=1e-6, atol=1e-6)


def test_rmsnorm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 64))
    g = jnp.ones((64,))
    y1 = np.asarray(ref.rmsnorm_ref(x, g))
    y2 = np.asarray(ref.rmsnorm_ref(x * 10.0, g))
    np.testing.assert_allclose(y1, y2, rtol=1e-4)


def test_xent_loss_uniform_logits():
    logits = jnp.zeros((2, 3, CFG.vocab_size))
    tgt = jnp.zeros((2, 3), jnp.int32)
    got = float(M.xent_loss(logits, tgt))
    assert abs(got - np.log(CFG.vocab_size)) < 1e-5
