"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes / k / seeds; assert_allclose against ref.py is the
core correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sparse_matmul import sparse_matmul, gu_matmul
from compile.kernels.topk_mask import threshold_sparsify, calibrate_threshold

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-4, 1e-5


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------ sparse matmul


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 192),
    dout=st.sampled_from([32, 64, 128, 130, 256, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_matmul_matches_ref(k, dout, seed):
    xs = rand(seed, 1, k)
    w = rand(seed + 1, k, dout)
    np.testing.assert_allclose(
        sparse_matmul(xs, w), ref.sparse_matmul_ref(xs, w),
        rtol=RTOL, atol=ATOL)


def test_sparse_matmul_identity():
    xs = jnp.ones((1, 8))
    np.testing.assert_allclose(sparse_matmul(xs, jnp.eye(8)), xs, rtol=1e-6)


def test_sparse_matmul_zero_input():
    out = sparse_matmul(jnp.zeros((1, 16)), rand(0, 16, 64))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((1, 64)))


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 128),
    dff=st.sampled_from([32, 128, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gu_matmul_matches_ref(k, dff, seed):
    xs = rand(seed, 1, k)
    wg = rand(seed + 1, k, dff)
    wu = rand(seed + 2, k, dff)
    np.testing.assert_allclose(
        gu_matmul(xs, wg, wu), ref.gu_ref(xs, wg, wu),
        rtol=1e-3, atol=1e-4)


def test_gu_is_silu_gated():
    xs = rand(3, 1, 16)
    wg, wu = rand(4, 16, 32), rand(5, 16, 32)
    g = np.asarray(xs @ wg)
    u = np.asarray(xs @ wu)
    expect = g / (1 + np.exp(-g)) * u
    np.testing.assert_allclose(gu_matmul(xs, wg, wu), expect,
                               rtol=1e-3, atol=1e-4)


# ------------------------------------------------------------ threshold/topk


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([8, 64, 128, 384]),
       t=st.floats(0.0, 3.0),
       seed=st.integers(0, 2**31 - 1))
def test_threshold_sparsify_matches_where(d, t, seed):
    x = rand(seed, 1, d)
    got = threshold_sparsify(x, t)
    want = jnp.where(jnp.abs(x) >= t, x, jnp.zeros_like(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([16, 128, 384]),
       k=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_topk_indices_props(d, k, seed):
    a = rand(seed, d)
    idx = np.asarray(ref.topk_indices_ref(a, k))
    assert len(idx) == k
    assert len(set(idx.tolist())) == k                      # unique
    assert (np.diff(idx) > 0).all() or k == 1               # ascending
    # every selected |a| >= every unselected |a|
    sel = set(idx.tolist())
    amag = np.abs(np.asarray(a))
    lo = max((amag[i] for i in range(d) if i not in sel), default=-1.0)
    assert all(amag[i] >= lo - 1e-7 for i in sel)


def test_topk_mask_consistent_with_indices():
    a = rand(9, 64)
    k = 13
    mask = np.asarray(ref.topk_mask_ref(a, k))
    idx = np.asarray(ref.topk_indices_ref(a, k))
    assert mask.sum() == k
    assert mask[idx].all()


def test_calibrated_threshold_hits_target_sparsity():
    samples = rand(11, 512, 128)
    for sp in (0.5, 0.8):
        t = calibrate_threshold(samples, sp)
        frac_zeroed = float((jnp.abs(samples) < t).mean())
        assert abs(frac_zeroed - sp) < 0.02


def test_sparse_linear_equals_masked_linear():
    a = rand(21, 128)
    w = rand(22, 128, 64)
    k = 40
    np.testing.assert_allclose(
        ref.sparse_linear_ref(a, w, k), ref.masked_linear_ref(a, w, k),
        rtol=1e-5, atol=1e-6)
