"""Self-distillation components (paper §5): KLD/CE loss, gamma schedule,
gradient flow through the sparse student."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model as M
from compile.distill import kld, sd_loss

CFG = configs.ModelConfig(name="t", d_model=64, n_layers=2, n_heads=2,
                          n_kv_heads=2, head_dim=32, d_ff=96, max_seq=16)


def test_kld_self_is_zero():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16))
    assert abs(float(kld(logits, logits))) < 1e-6


def test_kld_nonnegative_and_asymmetric():
    a = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    b = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 16))
    assert float(kld(a, b)) > 0
    assert float(kld(b, a)) > 0
    assert abs(float(kld(a, b)) - float(kld(b, a))) > 1e-6


def test_sd_loss_gamma_extremes():
    t = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 16))
    s = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 16))
    full_kld = sd_loss(t, s, gamma=1.0)
    full_ce = sd_loss(t, s, gamma=0.0)
    mid = sd_loss(t, s, gamma=0.5)
    np.testing.assert_allclose(float(mid),
                               0.5 * float(full_kld) + 0.5 * float(full_ce),
                               rtol=1e-5)


def test_gamma_schedule_monotone():
    d = configs.DistillConfig()
    gs = [d.gamma(sp) for sp in (0.3, 0.5, 0.7, 0.9)]
    assert all(0.0 <= g <= 1.0 for g in gs)
    assert gs == sorted(gs, reverse=True)  # high sparsity -> CE-heavy


def test_distill_gradient_reaches_all_weights():
    """STE must let gradients reach every sparse-op weight matrix."""
    params = M.init_params(CFG, jax.random.PRNGKey(5))
    x = jnp.zeros((1, 8), jnp.int32)
    t_logits = M.dense_forward(params, CFG, x)

    def loss_fn(p):
        s_logits = M.sparse_forward(p, CFG, x, 0.8)
        return sd_loss(t_logits, s_logits, gamma=0.4)

    grads = jax.grad(loss_fn)(params)
    for li, lp in enumerate(grads["layers"]):
        for op in ("wq", "wk", "wv", "wo", "wg", "wu", "wd"):
            g = np.asarray(lp[op])
            assert np.abs(g).sum() > 0, f"layer {li} {op}: zero gradient"


def test_one_distill_all_scale_loss_finite_across_grid():
    params = M.init_params(CFG, jax.random.PRNGKey(6))
    x = jnp.zeros((1, 8), jnp.int32)
    t_logits = M.dense_forward(params, CFG, x)
    for sp in configs.SPARSITY_GRID:
        s_logits = M.sparse_forward(params, CFG, x, sp)
        v = float(sd_loss(t_logits, s_logits, configs.DistillConfig().gamma(sp)))
        assert np.isfinite(v)
