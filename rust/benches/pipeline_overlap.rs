//! Meso-bench: decode-token latency with and without the swapping pipeline
//! (serial on-demand vs cross-layer preload), the engine-level view of
//! paper Fig 15/16b.

mod support;

use activeflow::baselines;
use activeflow::cache::CachePolicy;
use activeflow::device::PIXEL6;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::tokenizer;
use support::Bench;

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let b = Bench::new("pipeline_overlap");
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    let configs: Vec<(&str, EngineOptions)> = vec![
        (
            "serial_ondemand",
            baselines::serial_options(0.6, &PIXEL6, ClockMode::Modeled, 1.0),
        ),
        (
            "preload_n1",
            EngineOptions {
                sparsity: 0.6,
                group_size: 1,
                swap_mode: SwapMode::Preload,
                cache_bytes: 0,
                cache_policy: CachePolicy::Contextual,
                device: &PIXEL6,
                clock: ClockMode::Modeled,
                bw_scale: 1.0,
                trigger: PreloadTrigger::FirstLayer,
                io_queue_depth: 0,
                kv_block_tokens: 16,
                attn_buckets: true,
            },
        ),
        (
            "preload_n4_cache",
            EngineOptions {
                sparsity: 0.6,
                group_size: 4,
                swap_mode: SwapMode::Preload,
                cache_bytes: 512 * 1024,
                cache_policy: CachePolicy::Contextual,
                device: &PIXEL6,
                clock: ClockMode::Modeled,
                bw_scale: 1.0,
                trigger: PreloadTrigger::FirstLayer,
                io_queue_depth: 0,
                kv_block_tokens: 16,
                attn_buckets: true,
            },
        ),
    ];
    for (label, opts) in configs {
        let mut eng = SwapEngine::open(&dir, opts).unwrap();
        eng.forced_logits(&prompt).unwrap(); // warm KV + cache
        let mut tok = 0usize;
        b.run(label, 2, 30, || {
            if eng.kv_pos() + 1 >= eng.model().max_seq {
                eng.reset_sequence();
            }
            eng.decode_token(prompt[tok % prompt.len()]).unwrap();
            tok += 1;
        });
    }
}
