//! Governor re-budget bench: tokens/sec across a scripted DRAM budget
//! step-down on ONE live engine (no restarts), plus the settle time of
//! every re-budget. Writes `BENCH_governor.json` (override with
//! `--out PATH`) so the perf trajectory of the live control loop is
//! tracked the same way `BENCH_decode.json` tracks the decode hot path.
//!
//! Requires `make artifacts`; self-skips otherwise.

mod support;

use activeflow::cache::CachePolicy;
use activeflow::costmodel::{self, Geometry};
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::governor::{
    DramGovernor, GovernorConfig, PressureSchedule, RebudgetTrigger,
};
use activeflow::layout::AwgfFile;
use activeflow::tokenizer;
use activeflow::util::human_bytes;
use activeflow::util::json::{arr, num, obj, s};

const TOKENS_PER_PHASE: u64 = 24;

fn out_path() -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "../BENCH_governor.json".into())
}

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let cfg = activeflow::config::ArtifactConfig::load(&dir).unwrap();
    let awgf = AwgfFile::open(&cfg.weights_file).unwrap();
    let geo = Geometry::from_awgf(&awgf);
    let dev = &device::PIXEL6;
    let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    // budget staircase: 90% → 45% → 15% of the model on top of KV
    let spec = [0.9, 0.45, 0.15]
        .iter()
        .enumerate()
        .map(|(i, frac)| {
            let b = geo.kv_bytes + (geo.model_bytes as f64 * frac) as u64;
            format!("{}@{}", b, i as u64 * TOKENS_PER_PHASE)
        })
        .collect::<Vec<_>>()
        .join(",");
    let mut schedule = PressureSchedule::parse(&spec).unwrap();
    let first_budget = schedule.steps()[0].budget;
    let r0 = costmodel::search(dev, &geo, first_budget, 0.85, 1.0, &grid)
        .expect("largest budget feasible");

    let mut eng = SwapEngine::open(&dir, EngineOptions {
        sparsity: r0.params.sp,
        group_size: r0.params.n_group,
        swap_mode: SwapMode::Preload,
        cache_bytes: r0.params.cache_bytes,
        cache_policy: CachePolicy::Contextual,
        device: dev,
        clock: ClockMode::Timed,
        bw_scale: 1.0,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    })
    .unwrap();
    // serial single-sequence bench: a KV pool sized for one sequence, so
    // the planner's budget split stays comparable to the PR2/PR3 points
    // (no phantom KV reserved for concurrency the bench never drives)
    let gcfg = GovernorConfig {
        max_seqs: 1,
        ..GovernorConfig::default()
    };
    let mut gov = DramGovernor::new(&eng, gcfg, first_budget);

    println!("\n== bench: governor_rebudget ==");
    println!(
        "{:>10} {:>6} {:>3} {:>10} {:>8} {:>9} {:>7}",
        "budget", "sp", "N", "cache", "tok/s", "settle", "evict"
    );

    let mut decoded = 0u64;
    let mut phases = Vec::new();
    while let Some(budget) = schedule.due(decoded) {
        let d = gov
            .set_budget(&mut eng, budget, RebudgetTrigger::Schedule)
            .unwrap();
        let before = eng.metrics.clone();
        eng.generate(&prompt, TOKENS_PER_PHASE as usize, 0.0).unwrap();
        decoded += TOKENS_PER_PHASE;
        let wall = (eng.metrics.wall - before.wall).as_secs_f64();
        let toks = eng.metrics.tokens - before.tokens;
        let tps = toks as f64 / wall.max(1e-9);
        let ledger = eng.pool_ledger();
        assert!(
            ledger.cache_bytes <= d.cache_target,
            "cache above target after re-budget"
        );
        println!(
            "{:>10} {:>6.2} {:>3} {:>10} {:>8.2} {:>7.1}ms {:>7}",
            human_bytes(budget),
            d.new_sp,
            d.new_group,
            human_bytes(d.cache_target),
            tps,
            d.settle.as_secs_f64() * 1e3,
            d.evicted_rows
        );
        phases.push(obj(vec![
            ("budget_bytes", num(budget as f64)),
            ("applied", activeflow::util::json::Value::Bool(d.applied)),
            ("sparsity", num(d.new_sp)),
            ("group_size", num(d.new_group as f64)),
            ("cache_target_bytes", num(d.cache_target as f64)),
            ("slab_cap_bytes", num(d.slab_cap as f64)),
            ("evicted_rows", num(d.evicted_rows as f64)),
            ("settle_ms", num(d.settle.as_secs_f64() * 1e3)),
            ("tokens_per_sec", num(tps)),
            ("ledger_cache_bytes", num(ledger.cache_bytes as f64)),
            ("ledger_preload_bytes", num(ledger.preload_bytes as f64)),
            ("ledger_compute_bytes", num(ledger.compute_bytes as f64)),
        ]));
    }

    let m = &eng.metrics;
    let v = obj(vec![
        ("bench", s("governor-rebudget")),
        ("device", s(dev.name)),
        ("tokens_per_phase", num(TOKENS_PER_PHASE as f64)),
        ("phases", arr(phases)),
        ("rebudgets_applied", num(m.rebudgets_applied as f64)),
        ("rebudgets_skipped", num(m.rebudgets_skipped as f64)),
        ("rebudget_rows_evicted", num(m.rebudget_rows_evicted as f64)),
        ("level_switches", num(m.level_switches as f64)),
        (
            "rebudget_settle_ms",
            num(m.rebudget_settle.as_secs_f64() * 1e3),
        ),
    ]);
    let out = out_path();
    let mut text = v.to_string();
    text.push('\n');
    std::fs::write(&out, &text).unwrap();
    println!(
        "governor bench: {} re-budgets on one live engine, {} rows \
         evicted, {} level switches; wrote {out}",
        m.rebudgets_applied, m.rebudget_rows_evicted, m.level_switches
    );
}
