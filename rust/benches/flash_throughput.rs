//! Micro-bench: flash simulator read path (pread + dequant accounting) by
//! chunk size, per device profile. Paper Fig 7's engine-level counterpart.

mod support;

use activeflow::device;
use activeflow::flash::{ClockMode, FlashDevice};
use support::Bench;

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let path = dir.join("model.awgf");
    let b = Bench::new("flash_throughput");
    for dev in device::ALL {
        let flash =
            FlashDevice::open(&path, dev, ClockMode::Modeled, 1.0).unwrap();
        for chunk in [4usize << 10, 64 << 10, 512 << 10] {
            let mut buf = vec![0u8; chunk.min(1 << 20)];
            let mut off = 0u64;
            b.run(
                &format!("{}/read_{}k", dev.name, chunk >> 10),
                10,
                200,
                || {
                    flash.read_into(off % (1 << 18), &mut buf).unwrap();
                    off += 4096;
                },
            );
        }
    }
    // modeled throughput table (the actual Fig 7 series)
    for dev in device::ALL {
        let flash =
            FlashDevice::open(&path, dev, ClockMode::Modeled, 1.0).unwrap();
        for chunk in [4usize << 10, 64 << 10, 1 << 20] {
            let bw = flash.measure_throughput(chunk, 2 << 20).unwrap();
            println!(
                "modeled {} chunk={:>6}K -> {:>8.1} MB/s",
                dev.name,
                chunk >> 10,
                bw / 1e6
            );
        }
    }
}
