//! Criterion-lite: warmup + timed iterations + percentile report. (The
//! offline vendor set has no criterion; harness=false benches use this.)

use std::time::Instant;

pub struct Bench {
    name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        println!("\n== bench: {name} ==");
        Bench { name }
    }

    /// Run `f` `iters` times after `warmup` runs; print mean/p50/p90.
    pub fn run<F: FnMut()>(&self, label: &str, warmup: usize, iters: usize,
                           mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        println!(
            "{:<40} {:>10.2} us/iter (p50 {:>9.2}, p90 {:>9.2}, n={})",
            format!("{}::{label}", self.name),
            mean,
            pct(0.5),
            pct(0.9),
            iters
        );
    }
}

/// Artifacts present? (benches self-skip without them)
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("model_config.json").exists() {
        Some(dir)
    } else {
        println!("[skip] artifacts not built — run `make artifacts`");
        None
    }
}
