//! End-to-end decode-token latency across the sparsity grid (modeled
//! flash — isolates the compute+bookkeeping path; paper Fig 14a's
//! engine-side counterpart) plus the dense baseline.

mod support;

use activeflow::baselines::DenseInMemory;
use activeflow::cache::CachePolicy;
use activeflow::device::PIXEL6;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::tokenizer;
use support::Bench;

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let b = Bench::new("sparse_decode");
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    for sp in [0.5, 0.6, 0.8, 0.9] {
        let mut eng = SwapEngine::open(
            &dir,
            EngineOptions {
                sparsity: sp,
                group_size: 4,
                swap_mode: SwapMode::Preload,
                cache_bytes: 256 * 1024,
                cache_policy: CachePolicy::Contextual,
                device: &PIXEL6,
                clock: ClockMode::Modeled,
                bw_scale: 1.0,
                trigger: PreloadTrigger::FirstLayer,
                io_queue_depth: 0,
                kv_block_tokens: 16,
                attn_buckets: true,
            },
        )
        .unwrap();
        eng.forced_logits(&prompt).unwrap();
        let mut t = 0usize;
        b.run(&format!("decode_token_sp{:02}", (sp * 100.0) as u32), 2, 25,
              || {
            if eng.kv_pos() + 1 >= eng.model().max_seq {
                eng.reset_sequence();
            }
            eng.decode_token(prompt[t % prompt.len()]).unwrap();
            t += 1;
        });
    }

    let mut dense = DenseInMemory::open(&dir).unwrap();
    dense.forced_logits(&prompt).unwrap();
    let mut t = 0usize;
    b.run("decode_token_dense_in_memory", 2, 25, || {
        if t % 64 == 63 {
            dense.reset_sequence();
        }
        dense.decode_token(prompt[t % prompt.len()]).unwrap();
        t += 1;
    });
}
