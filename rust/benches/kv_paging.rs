//! Paged-KV bench: admitted concurrency and aggregate throughput of a
//! mixed-length workload under a FIXED KV byte budget — block-granular
//! accounting (this PR) vs PR 4's whole-`max_seq`-window accounting.
//!
//! The whole-window ledger charged every sequence `kv_per_seq = full
//! window` up front, so a budget of two windows admitted exactly two
//! sequences no matter how short they were. Block accounting charges
//! only the blocks a sequence has written, so the same budget holds
//! every short sequence of the workload at once — admitted concurrency
//! must be **strictly higher** (the acceptance assert), and the extra
//! interleaving gives the cross-token preload chains more peer-compute
//! to hide under (aggregate tok/s recorded for the `check-perf --kv`
//! trajectory gate).
//!
//! Timed flash clock (reads really sleep) at a bandwidth where I/O
//! matters, like `sched_interleave`. Writes `BENCH_kv.json` (`--out`).
//! Requires `make artifacts`; self-skips otherwise.

mod support;

use std::time::Instant;

use activeflow::cache::CachePolicy;
use activeflow::device;
use activeflow::engine::{
    EngineOptions, PreloadTrigger, SwapEngine, SwapMode,
};
use activeflow::flash::ClockMode;
use activeflow::sched::{SchedConfig, Scheduler, SeqRequest, SubmitOutcome};
use activeflow::tokenizer;
use activeflow::util::json::{num, obj, s};

const N_SEQS: usize = 6;
/// Mixed generation lengths — the workload the whole-window charge
/// penalizes most (every one of these is far below max_seq).
const GEN_LENS: [usize; N_SEQS] = [4, 6, 8, 10, 12, 14];
const BW_SCALE: f64 = 0.05;
const KV_BLOCK_TOKENS: usize = 16;

fn opts() -> EngineOptions {
    EngineOptions {
        sparsity: 0.6,
        group_size: 4,
        swap_mode: SwapMode::Preload,
        cache_bytes: 256 * 1024,
        cache_policy: CachePolicy::Contextual,
        device: &device::PIXEL6,
        clock: ClockMode::Timed,
        bw_scale: BW_SCALE,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: KV_BLOCK_TOKENS,
        attn_buckets: true,
    }
}

fn out_path() -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "../BENCH_kv.json".into())
}

fn req(prompt: &[u32], n: usize, seed: u64) -> SeqRequest {
    SeqRequest {
        prompt: prompt.to_vec(),
        n_tokens: n,
        temp: 0.0,
        seed,
        eos: None,
        deadline_waves: None,
        req_id: 0,
        client: None,
    }
}

/// Run the mixed workload through a scheduler with `max_seqs` slots and
/// a `capacity_blocks`-bounded pool; returns (outputs by id, peak
/// concurrency, aggregate tok/s, oom preemptions).
fn run_workload(
    dir: &std::path::Path,
    prompt: &[u32],
    max_seqs: usize,
    capacity_blocks: usize,
) -> (Vec<(u64, Vec<u32>)>, u64, f64, u64) {
    let mut engine = SwapEngine::open(dir, opts()).unwrap();
    engine.set_cross_token_preload(true);
    engine.generate(prompt, 4, 0.0).unwrap(); // warm artifacts + cache
    engine.reset_sequence(); // release the warmup's KV blocks
    engine.set_kv_capacity_blocks(capacity_blocks);
    let mut sched = Scheduler::new(engine, SchedConfig {
        max_seqs,
        queue_cap: N_SEQS + 2,
    });
    for (i, &n) in GEN_LENS.iter().enumerate() {
        let r = sched.submit(req(prompt, n, i as u64));
        assert!(
            !matches!(r, SubmitOutcome::Rejected { .. }),
            "submission {i} rejected: {r:?}"
        );
    }
    let t0 = Instant::now();
    let mut finished = Vec::new();
    while sched.has_work() {
        finished.extend(sched.wave());
    }
    let wall = t0.elapsed();
    let st = sched.stats();
    let mut out: Vec<(u64, Vec<u32>)> = finished
        .into_iter()
        .map(|f| {
            assert!(!f.truncated, "budget workload must not truncate");
            (f.id, f.outcome.expect("decode failed"))
        })
        .collect();
    out.sort();
    let pool = sched.backend().kv_pool_stats();
    assert_eq!(pool.in_use_blocks, 0, "free-count invariant after drain");
    assert!(
        pool.peak_blocks <= capacity_blocks,
        "pool ceiling violated: peak {} > capacity {capacity_blocks}",
        pool.peak_blocks
    );
    let tps = st.tokens_out as f64 / wall.as_secs_f64();
    (out, st.peak_active, tps, st.kv_preempted_oom)
}

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let prompt = tokenizer::encode("the sparse model swaps ");

    println!("\n== bench: kv_paging ==");

    // The fixed budget: exactly two whole-window sequences' worth of KV.
    // PR 4's accounting admitted floor(budget / full_window) = 2 — that
    // IS the baseline, enforced via the scheduler ceiling.
    let probe = SwapEngine::open(&dir, opts()).unwrap();
    let full_window = probe.kv_per_seq_bytes();
    let block_bytes = probe.kv_block_bytes();
    drop(probe);
    let kv_budget = 2 * full_window;
    let whole_window_ceiling = (kv_budget / full_window) as usize; // = 2
    let capacity_blocks = (kv_budget / block_bytes) as usize;

    let (base_out, base_peak, base_tps, _) =
        run_workload(&dir, &prompt, whole_window_ceiling, capacity_blocks);
    let (paged_out, paged_peak, paged_tps, oom) =
        run_workload(&dir, &prompt, N_SEQS, capacity_blocks);

    println!(
        "kv budget {} ({} blocks x {}B): whole-window admits {} \
         ({base_tps:.2} tok/s) -> block-granular admits {} \
         ({paged_tps:.2} tok/s, {:.2}x), oom preemptions {}",
        kv_budget,
        capacity_blocks,
        block_bytes,
        base_peak,
        paged_peak,
        paged_tps / base_tps,
        oom,
    );

    // acceptance: same budget, strictly more admitted concurrency
    assert_eq!(base_peak as usize, whole_window_ceiling);
    assert!(
        (paged_peak as usize) > whole_window_ceiling,
        "block-granular admission ({paged_peak}) must exceed the \
         whole-window ceiling ({whole_window_ceiling}) for mixed-length \
         sequences under the same KV budget"
    );
    // bit-safety under the pool: concurrency must not change any stream
    assert_eq!(
        paged_out, base_out,
        "the same requests must decode to the same tokens regardless of \
         admitted concurrency"
    );

    let v = obj(vec![
        ("bench", s("kv-paging")),
        ("device", s(device::PIXEL6.name)),
        ("n_seqs", num(N_SEQS as f64)),
        ("bw_scale", num(BW_SCALE)),
        ("kv_block_tokens", num(KV_BLOCK_TOKENS as f64)),
        ("kv_budget_bytes", num(kv_budget as f64)),
        ("kv_blocks_total", num(capacity_blocks as f64)),
        ("whole_window_ceiling", num(whole_window_ceiling as f64)),
        ("admitted_concurrency", num(paged_peak as f64)),
        ("baseline_tokens_per_sec", num(base_tps)),
        ("aggregate_tokens_per_sec", num(paged_tps)),
        ("speedup_vs_whole_window", num(paged_tps / base_tps)),
        ("kv_preemptions_oom", num(oom as f64)),
    ]);
    let out = out_path();
    let mut text = v.to_string();
    text.push('\n');
    std::fs::write(&out, &text).unwrap();
    println!("wrote {out}");
}
