//! Micro-bench: the decode fetch path's preload-store representation — the
//! contiguous `PartSlab` (one buffer + offset index, single-lock batched
//! cache inserts) against the old per-row store (`HashMap<(TensorId, u32),
//! Vec<f32>>` + one cache lock per inserted row). This is pure bookkeeping
//! overhead: no flash I/O, exactly what the slab refactor removed from the
//! hot path. The reference implementation is kept here (not in src/) so
//! the shipped pipeline holds zero per-row allocations.

mod support;

use std::collections::HashMap;
use std::sync::Arc;

use activeflow::cache::{CachePolicy, SharedCache, WeightCache};
use activeflow::layout::{OpKind, TensorId};
use activeflow::pipeline::PartSlab;
use activeflow::util::rng::Xorshift;
use support::Bench;

const D_IN: usize = 4096; // llama-7b-like channel count
const D_OUT: usize = 128;
const K: usize = 1024; // active channels per fetch (sp 0.75)

fn fetch_channels(rng: &mut Xorshift) -> Vec<usize> {
    let mut chs: Vec<usize> = (0..K).map(|_| rng.below(D_IN as u64) as usize)
        .collect();
    chs.sort_unstable();
    chs.dedup();
    chs
}

fn shared_cache() -> Arc<SharedCache> {
    SharedCache::new(WeightCache::new(
        &[(TensorId::new(0, OpKind::Wq), D_IN, D_OUT)],
        u64::MAX,
        CachePolicy::Contextual,
    ))
}

fn main() {
    let b = Bench::new("fetch_packed");
    let layers: Arc<[usize]> = Arc::from(&[0usize][..]);
    let all: Vec<usize> = (0..D_IN).collect();
    let row: Vec<f32> = (0..D_OUT).map(|j| j as f32).collect();
    let id = TensorId::new(0, OpKind::Wq);

    // ---- store build: dequant destination per preloaded row
    b.run("build_slab_store", 3, 50, || {
        let mut slab = PartSlab::new(OpKind::Wq, layers.clone(), &all, D_OUT);
        for ch in 0..D_IN {
            slab.row_mut(0, ch).unwrap().copy_from_slice(&row);
        }
        assert!(slab.row(0, D_IN - 1).is_some());
    });
    b.run("build_hashmap_store (old)", 3, 50, || {
        let mut store: HashMap<(TensorId, u32), Vec<f32>> =
            HashMap::with_capacity(D_IN);
        for ch in 0..D_IN {
            store.insert((id, ch as u32), row.clone()); // per-row Vec
        }
        assert!(store.contains_key(&(id, (D_IN - 1) as u32)));
    });

    // ---- steady-state fetch: gather K rows into packed + cache inserts
    let mut slab = PartSlab::new(OpKind::Wq, layers.clone(), &all, D_OUT);
    let mut store: HashMap<(TensorId, u32), Vec<f32>> =
        HashMap::with_capacity(D_IN);
    for ch in 0..D_IN {
        slab.row_mut(0, ch).unwrap().copy_from_slice(&row);
        store.insert((id, ch as u32), row.clone());
    }
    let mut packed = vec![0f32; K * D_OUT];
    let mut rng = Xorshift::new(0xFE7C);
    let mut chs = fetch_channels(&mut rng);

    let cache = shared_cache();
    b.run("slab_fetch_single_lock", 10, 2_000, || {
        let mut c = cache.lock(); // ONE acquisition for the whole fetch
        let tc = c.tensor_mut(id);
        for (slot, &ch) in chs.iter().enumerate() {
            let r = slab.row(0, ch).unwrap();
            packed[slot * D_OUT..(slot + 1) * D_OUT].copy_from_slice(r);
            tc.lookup(ch);
        }
        let rows: &[f32] = &packed;
        tc.insert_rows(chs.iter().enumerate().map(|(slot, &ch)| {
            (ch, &rows[slot * D_OUT..(slot + 1) * D_OUT])
        }));
        drop(c);
        chs = fetch_channels(&mut rng);
    });
    println!(
        "    slab path lock acquisitions: {} over 2010 fetches",
        cache.lock_acquires()
    );

    let cache = shared_cache();
    b.run("hashmap_fetch_lock_per_row (old)", 10, 2_000, || {
        {
            // old path, pass 1: lookup lock
            let mut c = cache.lock();
            let tc = c.tensor_mut(id);
            for &ch in chs.iter() {
                tc.lookup(ch);
            }
        }
        for (slot, &ch) in chs.iter().enumerate() {
            let r = store.get(&(id, ch as u32)).unwrap(); // per-row hash
            packed[slot * D_OUT..(slot + 1) * D_OUT].copy_from_slice(r);
            // old path, pass 2: re-lock the cache for every row offered
            let mut c = cache.lock();
            c.tensor_mut(id)
                .insert(ch, &packed[slot * D_OUT..(slot + 1) * D_OUT]);
        }
        chs = fetch_channels(&mut rng);
    });
    println!(
        "    per-row path lock acquisitions: {} over 2010 fetches",
        cache.lock_acquires()
    );
}
