//! Continuous-batching bench: aggregate decode throughput of N sequences
//! interleaved through the scheduler vs the same N run serially through
//! `generate()`, on the **timed** flash clock (reads really sleep, so
//! wall-clock overlap is faithful).
//!
//! Why interleaving wins: the serial engine pays every token's layer-group
//! 0 as a cold on-demand fetch (there is no activation to predict it from
//! until the token starts). The scheduler's cross-token preload issues
//! group 0 of a sequence's next token the moment its current token ends —
//! and the loader streams it while the *other* sequences compute their
//! tokens, off the critical path. Serial decode has no "other sequences",
//! so that I/O idle time is structural, not a tuning artifact.
//!
//! Self-asserts (acceptance gates):
//!   1. aggregate modeled tokens/sec, ≥2 interleaved sequences  >  the
//!      serial-baseline aggregate;
//!   2. a `set_budget` issued mid-generation is applied within one
//!      scheduler wave (engine reconfigured while the sequence is still
//!      live — not deferred to end-of-request);
//!   3. the flight-recorder trace of the interleaved run contains at
//!      least one loader `preload_part` span that overlaps an engine
//!      compute span (`step`/`layer_fetch`) on the shared trace clock —
//!      the observable form of "I/O rides under compute".
//!
//! Writes `BENCH_sched.json` (`--out PATH`) for the `check-perf --sched`
//! trajectory gate, and a Chrome trace-event JSON (`--trace-out PATH`)
//! for `scripts/check_trace.py` / `make trace-smoke`. Requires
//! `make artifacts`; self-skips otherwise.

mod support;

use std::time::Instant;

use activeflow::cache::CachePolicy;
use activeflow::costmodel::Geometry;
use activeflow::device;
use activeflow::engine::{
    EngineOptions, PreloadTrigger, SwapEngine, SwapMode,
};
use activeflow::flash::ClockMode;
use activeflow::governor::{DramGovernor, GovernorConfig, RebudgetTrigger};
use activeflow::layout::AwgfFile;
use activeflow::sched::{SchedConfig, Scheduler, SeqRequest, SubmitOutcome};
use activeflow::tokenizer;
use activeflow::trace::SpanKind;
use activeflow::util::json::{num, obj, s, Value};

const N_SEQS: usize = 3;
const TOKENS: usize = 12;
/// Flash slow enough that I/O matters, fast enough that the device has
/// idle time during compute — the regime where overlap is winnable (a
/// saturated channel can't be overlapped, an instant one needn't be).
const BW_SCALE: f64 = 0.05;

fn opts() -> EngineOptions {
    EngineOptions {
        sparsity: 0.6,
        group_size: 4,
        swap_mode: SwapMode::Preload,
        cache_bytes: 256 * 1024,
        cache_policy: CachePolicy::Contextual,
        device: &device::PIXEL6,
        clock: ClockMode::Timed,
        bw_scale: BW_SCALE,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

fn flag_path(flag: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.into())
}

fn out_path() -> String {
    flag_path("--out", "../BENCH_sched.json")
}

fn trace_out_path() -> String {
    flag_path("--trace-out", "../trace_sched.json")
}

fn req(prompt: &[u32], seed: u64) -> SeqRequest {
    SeqRequest {
        prompt: prompt.to_vec(),
        n_tokens: TOKENS,
        temp: 0.0,
        seed,
        eos: None,
        deadline_waves: None,
        req_id: 0,
        client: None,
    }
}

fn main() {
    let Some(dir) = support::artifacts_dir() else { return };
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    println!("\n== bench: sched_interleave ==");

    // ---- serial baseline: N back-to-back generate() calls, one engine
    let mut serial = SwapEngine::open(&dir, opts()).unwrap();
    // warm once so both paths start with compiled artifacts + warm cache
    serial.generate(&prompt, 4, 0.0).unwrap();
    let t0 = Instant::now();
    for _ in 0..N_SEQS {
        let out = serial.generate(&prompt, TOKENS, 0.0).unwrap();
        assert_eq!(out.len(), TOKENS);
    }
    let serial_wall = t0.elapsed();
    let serial_tps = (N_SEQS * TOKENS) as f64 / serial_wall.as_secs_f64();
    let serial_io_wait = serial.metrics.io_wait_engine;

    // ---- interleaved: same N sequences through the scheduler
    let mut engine = SwapEngine::open(&dir, opts()).unwrap();
    engine.set_cross_token_preload(true);
    engine.generate(&prompt, 4, 0.0).unwrap(); // same warmup
    // flight recorder on for the measured run only (warmup spans would
    // muddy the overlap check below)
    engine.trace_handle().set_enabled(true);
    engine.trace_handle().clear();
    let mut sched = Scheduler::new(engine, SchedConfig {
        max_seqs: N_SEQS,
        queue_cap: 8,
    });
    for i in 0..N_SEQS {
        let r = sched.submit(req(&prompt, i as u64));
        assert!(matches!(r, SubmitOutcome::Admitted { .. }), "{r:?}");
    }
    let t0 = Instant::now();
    let mut finished = Vec::new();
    while sched.has_work() {
        finished.extend(sched.wave());
    }
    let inter_wall = t0.elapsed();
    for f in &finished {
        assert_eq!(
            f.outcome.as_ref().expect("interleaved decode failed").len(),
            TOKENS
        );
    }
    let st = sched.stats();
    let inter_tps = st.tokens_out as f64 / inter_wall.as_secs_f64();
    let inter_io_wait = sched.backend().metrics.io_wait_engine;
    let ct_preloads = sched.backend().metrics.cross_token_preloads;
    assert!(ct_preloads > 0, "cross-token preload chains never issued");
    let itl_p99_us = sched.backend().metrics.h_itl_us.p99();

    // ---- flight recorder: dump the trace and prove I/O-under-compute
    let trace = sched.backend().trace_handle().clone();
    trace.set_enabled(false);
    let spans = trace.snapshot_spans();
    let (_len, _cap, dropped) = trace.ring_stats();
    assert_eq!(dropped, 0, "trace ring overflowed during the bench run");
    let preloads: Vec<_> = spans
        .iter()
        .filter(|e| e.kind == SpanKind::PreloadPart)
        .collect();
    let computes: Vec<_> = spans
        .iter()
        .filter(|e| {
            e.kind == SpanKind::Step || e.kind == SpanKind::LayerFetch
        })
        .collect();
    assert!(!preloads.is_empty(), "no preload_part spans recorded");
    assert!(!computes.is_empty(), "no compute spans recorded");
    let overlaps = preloads.iter().any(|p| {
        computes.iter().any(|c| {
            p.t0_us < c.t0_us + c.dur_us && c.t0_us < p.t0_us + p.dur_us
        })
    });
    assert!(
        overlaps,
        "no preload_part span overlaps a compute span — the loader is \
         not running under compute ({} preload spans, {} compute spans)",
        preloads.len(),
        computes.len()
    );
    let tpath = trace_out_path();
    let mut ttext = activeflow::trace::chrome_trace(&trace).to_string();
    ttext.push('\n');
    std::fs::write(&tpath, &ttext).unwrap();
    println!(
        "trace: {} spans ({} preload, {} compute), overlap verified; \
         wrote {tpath}",
        spans.len(),
        preloads.len(),
        computes.len()
    );

    println!(
        "aggregate decode ({N_SEQS} seqs x {TOKENS} toks, bw_scale \
         {BW_SCALE}): serial {serial_tps:.2} tok/s -> interleaved \
         {inter_tps:.2} tok/s ({:.2}x); engine io-wait {:.1}ms -> {:.1}ms; \
         {} waves, avg {:.0}us",
        inter_tps / serial_tps,
        serial_io_wait.as_secs_f64() * 1e3,
        inter_io_wait.as_secs_f64() * 1e3,
        st.waves,
        st.avg_wave().as_secs_f64() * 1e6,
    );
    assert!(
        inter_tps > serial_tps,
        "interleaved aggregate ({inter_tps:.2} tok/s) must beat the \
         serial baseline ({serial_tps:.2} tok/s): cross-token preload \
         chains should overlap each sequence's group-0 I/O with its \
         peers' compute"
    );

    // ---- mid-generation re-budget applies within one wave
    let cfgf = activeflow::config::ArtifactConfig::load(&dir).unwrap();
    let geo = Geometry::from_awgf(&AwgfFile::open(&cfgf.weights_file).unwrap());
    let mut engine = SwapEngine::open(&dir, opts()).unwrap();
    engine.set_cross_token_preload(true);
    let mut gov = DramGovernor::new(
        &engine,
        GovernorConfig::default(),
        device::PIXEL6.dram_bytes,
    );
    let mut sched = Scheduler::new(engine, SchedConfig {
        max_seqs: 2,
        queue_cap: 4,
    });
    let r = sched.submit(req(&prompt, 99));
    assert!(matches!(r, SubmitOutcome::Admitted { .. }));
    // run until the sequence is genuinely mid-GENERATION (past prefill,
    // some but not all tokens produced)
    while sched.stats().tokens_out < 2 {
        assert!(sched.has_work(), "sequence finished before the rebudget");
        finished.extend(sched.wave());
    }
    let tokens_at_apply = sched.stats().tokens_out;
    assert!(
        (tokens_at_apply as usize) < TOKENS,
        "rebudget must land before the request completes"
    );
    assert_eq!(sched.active(), 1, "sequence must still be live");
    let budget = geo.kv_bytes + (geo.model_bytes as f64 * 0.4) as u64;
    // the wave boundary IS the safe point: the governor applies to the
    // engine synchronously here — within one wave of the request by
    // construction — and the assertions below prove it took effect
    // while the generation is in flight, not deferred to end-of-request
    let d = gov
        .set_budget(sched.backend_mut(), budget, RebudgetTrigger::Command)
        .unwrap();
    assert!(d.applied, "mid-generation re-budget refused: {}", d.note);
    sched.set_max_active(d.max_seqs);
    assert_eq!(
        sched.backend().opts.cache_bytes,
        d.cache_target,
        "engine reconfigured while the sequence is live"
    );
    assert_eq!(sched.active(), 1, "sequence survives the live re-budget");
    assert_eq!(
        sched.stats().tokens_out,
        tokens_at_apply,
        "no extra wave ran between issuing and applying the re-budget"
    );
    let done = loop {
        let fin = sched.wave();
        if !fin.is_empty() {
            break fin;
        }
        assert!(sched.has_work(), "sequence lost after the re-budget");
    };
    assert_eq!(
        done[0].outcome.as_ref().expect("decode after rebudget").len(),
        TOKENS,
        "generation completes under the new configuration"
    );
    println!(
        "mid-generation set_budget: applied at the wave boundary after \
         {tokens_at_apply} of {TOKENS} tokens (sp={:.2} N={} cache={} \
         max_seqs={}), {} rows evicted",
        d.new_sp, d.new_group, d.cache_target, d.max_seqs, d.evicted_rows
    );

    let v = obj(vec![
        ("bench", s("sched-interleave")),
        ("device", s(device::PIXEL6.name)),
        ("n_seqs", num(N_SEQS as f64)),
        ("tokens_per_seq", num(TOKENS as f64)),
        ("bw_scale", num(BW_SCALE)),
        ("serial_tokens_per_sec", num(serial_tps)),
        ("aggregate_tokens_per_sec", num(inter_tps)),
        ("speedup", num(inter_tps / serial_tps)),
        ("sched_waves", num(st.waves as f64)),
        // admission-control ledger: constant for this fixed workload, but
        // carried so the perf trajectory sees a scheduler that starts
        // rejecting or preempting (check_perf notes any swing)
        ("seqs_admitted", num(st.seqs_admitted as f64)),
        ("seqs_queued", num(st.seqs_queued as f64)),
        ("seqs_rejected", num(st.seqs_rejected as f64)),
        ("seqs_preempted", num(st.seqs_preempted as f64)),
        ("seqs_completed", num(st.seqs_completed as f64)),
        ("seqs_timed_out", num(st.seqs_timed_out as f64)),
        ("seqs_panicked", num(st.seqs_panicked as f64)),
        (
            "wave_avg_us",
            num(st.avg_wave().as_secs_f64() * 1e6),
        ),
        ("cross_token_preloads", num(ct_preloads as f64)),
        ("itl_p99_us", num(itl_p99_us as f64)),
        (
            "io_wait_engine_us_serial",
            num(serial_io_wait.as_secs_f64() * 1e6),
        ),
        (
            "io_wait_engine_us_interleaved",
            num(inter_io_wait.as_secs_f64() * 1e6),
        ),
        ("rebudget_tokens_at_apply", num(tokens_at_apply as f64)),
        ("rebudget_applied_mid_generation", Value::Bool(d.applied)),
    ]);
    let out = out_path();
    let mut text = v.to_string();
    text.push('\n');
    std::fs::write(&out, &text).unwrap();
    println!("wrote {out}");
}
