//! Loader-overlap bench: preload-critical-path time of a multi-run part
//! through the async ReadQueue vs. the old sequential-read baseline, under
//! the **modeled** clock (deterministic, machine-independent).
//!
//! The "part" is the loader's real unit of work: K coalesced chunk runs
//! that must ALL land before the part publishes. Sequentially each run
//! pays the device's full fixed latency; submitted together they share
//! queue-depth-bounded waves, so the critical path amortizes the latency
//! across the batch (paper §6 / LLM-in-a-flash). The bench asserts the
//! queued path is strictly faster on the modeled clock — the acceptance
//! gate for the async read path — and prints both along with wall time.
//!
//! Self-contained: builds its own scratch flash file; no artifacts needed.

mod support;

use std::io::Write;
use std::sync::atomic::Ordering;

use activeflow::device::PIXEL6;
use activeflow::flash::{ClockMode, FlashDevice, ReadQueue};
use support::Bench;

/// Runs per simulated part (a Wq/Wk/Wv site with scattered channels).
const RUNS: usize = 12;
/// Bytes per run: a cross-layer chunk of a few channels.
const RUN_BYTES: usize = 32 << 10;
const ITERS: usize = 50;

fn scratch_file() -> std::path::PathBuf {
    let path = std::env::temp_dir()
        .join(format!("awf_loader_overlap_{}", std::process::id()));
    let mut f = std::fs::File::create(&path).unwrap();
    let data: Vec<u8> = (0..RUNS * RUN_BYTES).map(|i| (i % 251) as u8).collect();
    f.write_all(&data).unwrap();
    path
}

fn busy_ns(dev: &FlashDevice) -> u64 {
    dev.stats.busy_ns.load(Ordering::Relaxed)
}

fn main() {
    let b = Bench::new("loader_overlap");
    let path = scratch_file();
    let reqs: Vec<(u64, usize)> = (0..RUNS)
        .map(|i| ((i * RUN_BYTES) as u64, RUN_BYTES))
        .collect();

    // -- sequential baseline: the pre-queue loader, one read() per run
    let seq_dev =
        FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 1.0).unwrap();
    let before = busy_ns(&seq_dev);
    b.run("sequential_reads", 2, ITERS, || {
        for &(off, len) in &reqs {
            seq_dev.read(off, len).unwrap();
        }
    });
    let seq_modeled =
        (busy_ns(&seq_dev) - before) / (ITERS + 2) as u64;

    // -- async queue: submit every run up front, reap as completions land
    let q_dev =
        FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 1.0).unwrap();
    let queue = ReadQueue::new(q_dev.clone(), 0); // device-default depth
    let before = busy_ns(&q_dev);
    b.run("queued_submit_reap", 2, ITERS, || {
        let tags = queue.submit_many(&reqs);
        for t in tags {
            queue.wait(t).unwrap();
        }
    });
    let q_modeled = (busy_ns(&q_dev) - before) / (ITERS + 2) as u64;

    let st = queue.io_stats();
    println!(
        "modeled critical path per part ({RUNS} runs x {}KB, {}): \
         sequential {:.1}us -> queued {:.1}us ({:.2}x); \
         io_batches={} inflight_peak={}",
        RUN_BYTES >> 10,
        PIXEL6.name,
        seq_modeled as f64 / 1e3,
        q_modeled as f64 / 1e3,
        seq_modeled as f64 / q_modeled.max(1) as f64,
        st.batches,
        st.inflight_peak,
    );
    assert!(
        q_modeled < seq_modeled,
        "queued preload critical path ({q_modeled}ns) must beat the \
         sequential baseline ({seq_modeled}ns) on the modeled clock"
    );
    // Non-urgent (preload) waves are split at depth/2 so urgent
    // on-demand reads never wait out a full-depth wave: RUNS runs land
    // in ceil(RUNS / (depth/2)) partial waves, each paying one fixed
    // latency — still amortizing all the rest (vs RUNS latencies
    // sequentially), minus one wave of slack for rounding.
    let lat_ns = (PIXEL6.flash_latency * 1e9) as u64;
    let split_cap = (queue.depth() / 2).max(1);
    let waves = RUNS.div_ceil(split_cap) as u64;
    assert!(
        seq_modeled - q_modeled > (RUNS as u64 - waves - 1) * lat_ns,
        "amortization must recover the per-run fixed latencies beyond \
         one per partial wave (saved {}ns, expected > {}ns at {} waves)",
        seq_modeled - q_modeled,
        (RUNS as u64 - waves - 1) * lat_ns,
        waves
    );
    std::fs::remove_file(path).ok();
}
