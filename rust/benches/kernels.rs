//! Kernel hot-path bench (PERF.md "Kernel hot paths"): the two rewrites
//! from the bucketed-attention PR, each self-asserting.
//!
//! (a) **Dequant:** the block-kernel `dequantize_row` (fixed 32-lane
//! loops, hoisted scale, bounds-check-free zips — written to
//! autovectorize) must beat the retained value-by-value
//! `dequantize_row_scalar` reference by ≥1.5× wall clock on both q8_0
//! and q4_0, decoding the same bytes bit-identically. Pure `layout::
//! quant` — needs no artifacts, so this half always gates.
//!
//! (b) **Bucketed attention:** a short decode through the smallest
//! compiled `attn_core_<cap>` windows must move strictly fewer host
//! bytes (`host_copy_bytes`) than the same decode through the monolithic
//! `[max_seq, d_kv]` gather, with a token-identical stream. Self-skips
//! without artifacts (keys written as 0 → the `--kernels` gate skips).
//!
//! Writes `BENCH_kernels.json` (`--out`) for the `check-perf --kernels`
//! trajectory gate; the four kernel counters land here so the counters
//! pass's watched-unemitted rule sees a bench emitter for each.

mod support;

use std::hint::black_box;
use std::time::Instant;

use activeflow::cache::CachePolicy;
use activeflow::device;
use activeflow::engine::{
    EngineOptions, PreloadTrigger, SwapEngine, SwapMode,
};
use activeflow::flash::ClockMode;
use activeflow::layout::quant::{
    dequantize_row, dequantize_row_scalar, quantize_row, Quant,
};
use activeflow::tokenizer;
use activeflow::util::json::{num, obj, s};
use activeflow::util::rng::Xorshift;

/// Row width for the dequant microbench — a realistic FFN row, a
/// multiple of QBLOCK.
const DOUT: usize = 1024;
const N_ROWS: usize = 256;
/// Decode passes per timing sample; best-of-TRIALS wall clock on each
/// side keeps scheduler noise out of the ratio.
const PASSES: usize = 40;
const TRIALS: usize = 5;
const MIN_SPEEDUP: f64 = 1.5;
const N_GEN: usize = 10;

fn opts() -> EngineOptions {
    EngineOptions {
        sparsity: 0.6,
        group_size: 4,
        swap_mode: SwapMode::Preload,
        cache_bytes: 256 * 1024,
        cache_policy: CachePolicy::Contextual,
        device: &device::PIXEL6,
        clock: ClockMode::Modeled,
        bw_scale: 1.0,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

fn out_path() -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "../BENCH_kernels.json".into())
}

/// Best-of-`TRIALS` wall time (µs) for `PASSES` full decodes of every
/// packed row through `f`.
fn time_decode<F: FnMut(&[u8], &mut [f32])>(
    rows: &[Vec<u8>],
    scratch: &mut [f32],
    mut f: F,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..TRIALS {
        let t0 = Instant::now();
        for _ in 0..PASSES {
            for r in rows {
                f(black_box(r), scratch);
            }
            black_box(&scratch);
        }
        best = best.min(t0.elapsed().as_secs_f64() * 1e6);
    }
    best
}

/// Dequant half: speedup (block kernels / scalar reference) for one
/// quant kind, with a bit-exactness cross-check on every row.
fn bench_dequant(q: Quant) -> f64 {
    let mut rng = Xorshift::new(0x9e3779b97f4a7c15);
    let rows: Vec<Vec<u8>> = (0..N_ROWS)
        .map(|_| {
            let row: Vec<f32> =
                (0..DOUT).map(|_| rng.f32_range(-2.0, 2.0)).collect();
            quantize_row(&row, q)
        })
        .collect();
    let mut a = vec![0f32; DOUT];
    let mut b = vec![0f32; DOUT];
    for r in &rows {
        dequantize_row(r, q, &mut a);
        dequantize_row_scalar(r, q, &mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{}: block kernel diverged from scalar reference",
            q.name()
        );
    }
    // warm both paths once before timing
    let scalar_us =
        time_decode(&rows, &mut b, |r, d| dequantize_row_scalar(r, q, d));
    let vector_us =
        time_decode(&rows, &mut a, |r, d| dequantize_row(r, q, d));
    let speedup = scalar_us / vector_us;
    let rows_total = (N_ROWS * PASSES) as f64;
    println!(
        "kernels::dequant_{}  scalar {:>9.1} us  block {:>9.1} us  \
         ({speedup:.2}x, {:.1} Mrow/s)",
        q.name(),
        scalar_us,
        vector_us,
        rows_total / vector_us
    );
    assert!(
        speedup >= MIN_SPEEDUP,
        "{} block kernel is only {speedup:.2}x the scalar reference \
         (acceptance floor {MIN_SPEEDUP}x)",
        q.name()
    );
    speedup
}

/// Attention half: decode the same short sequence bucketed and
/// monolithic; returns the kernel counters, or zeros when the artifact
/// set has no `attn_core_<cap>` programs.
fn bench_attention(
    dir: &std::path::Path,
) -> (u64, u64, u64, u64, u64, f64) {
    let prompt = tokenizer::encode("the sparse model swaps ");
    let mut bucketed = SwapEngine::open(dir, opts()).unwrap();
    let mut mono_opts = opts();
    mono_opts.attn_buckets = false;
    let mut mono = SwapEngine::open(dir, mono_opts).unwrap();
    let tb = bucketed.generate(&prompt, N_GEN, 0.0).unwrap();
    let tm = mono.generate(&prompt, N_GEN, 0.0).unwrap();
    assert_eq!(
        tb, tm,
        "bucketed attention changed the decoded stream — bit-safety \
         broken, not a perf question"
    );
    let mb = &bucketed.metrics;
    if mb.attn_bucket_cap == 0 {
        println!(
            "kernels::attention  [skip] no attn_core_<cap> artifacts — \
             monolithic fallback ran (rebuild with `make artifacts`)"
        );
        return (0, 0, 0, 0, 0, 0.0);
    }
    let mono_bytes = mono.metrics.host_copy_bytes;
    assert!(
        mb.host_copy_bytes < mono_bytes,
        "bucketed host_copy_bytes {} must be strictly below the \
         monolithic gather baseline {mono_bytes} for short sequences",
        mb.host_copy_bytes
    );
    let reduction = mono_bytes as f64 / mb.host_copy_bytes as f64;
    println!(
        "kernels::attention  host_copy {} -> {} bytes ({reduction:.2}x \
         less), peak bucket cap {} (max_seq {})",
        mono_bytes,
        mb.host_copy_bytes,
        mb.attn_bucket_cap,
        bucketed.model().max_seq
    );
    (
        mb.host_copy_bytes,
        mono_bytes,
        mb.attn_bucket_cap,
        mb.dequant_rows_vectorized,
        mb.subslab_waste_bytes,
        reduction,
    )
}

fn main() {
    println!("\n== bench: kernels ==");
    let sp_q8 = bench_dequant(Quant::Q8_0);
    let sp_q4 = bench_dequant(Quant::Q4_0);

    let (copy, copy_mono, cap, rows_vec, waste, reduction) =
        match support::artifacts_dir() {
            Some(dir) => bench_attention(&dir),
            None => (0, 0, 0, 0, 0, 0.0),
        };

    let v = obj(vec![
        ("bench", s("kernels")),
        ("device", s(device::PIXEL6.name)),
        ("dequant_rows", num((N_ROWS * PASSES) as f64)),
        ("dequant_speedup_q8_0", num(sp_q8)),
        ("dequant_speedup_q4_0", num(sp_q4)),
        ("host_copy_bytes", num(copy as f64)),
        ("host_copy_bytes_monolithic", num(copy_mono as f64)),
        ("host_copy_reduction", num(reduction)),
        ("attn_bucket_cap", num(cap as f64)),
        ("dequant_rows_vectorized", num(rows_vec as f64)),
        ("subslab_waste_bytes", num(waste as f64)),
    ]);
    let out = out_path();
    let mut text = v.to_string();
    text.push('\n');
    std::fs::write(&out, &text).unwrap();
    println!("wrote {out}");
}
