//! Micro-bench: contextual weight-cache hot paths (lookup hit, miss+insert,
//! eviction scan) — the L3 operations on every active channel of every op
//! of every layer, ~500×/token.

mod support;

use activeflow::cache::{CachePolicy, TensorCache};
use activeflow::util::rng::Xorshift;
use support::Bench;

fn main() {
    let b = Bench::new("cache_policy");
    let d_in = 4096; // llama-7b-like row count
    let row_len = 128;
    let row = vec![1.0f32; row_len];

    // pure hits
    let mut c = TensorCache::new(d_in, row_len, d_in, CachePolicy::Contextual);
    for ch in 0..d_in {
        c.lookup(ch);
        c.insert(ch, &row);
    }
    let mut i = 0usize;
    b.run("lookup_hit", 1000, 200_000, || {
        let ch = (i * 37) % d_in;
        assert!(c.lookup(ch).is_some());
        i += 1;
    });

    // miss + LFU insert at 25% capacity (steady-state eviction pressure)
    let mut c =
        TensorCache::new(d_in, row_len, d_in / 4, CachePolicy::Contextual);
    let mut rng = Xorshift::new(7);
    b.run("miss_insert_evict_25pct", 1000, 50_000, || {
        let ch = (rng.below(d_in as u64)) as usize;
        if c.lookup(ch).is_none() {
            c.insert(ch, &row);
        }
    });
    println!(
        "steady-state hit rate at 25% capacity, uniform access: {:.3} \
         (skewed contexts do much better — see `activeflow bench \
         cache-policy`)",
        c.hit_rate()
    );

    // context reset cost (per-sequence)
    let mut c = TensorCache::new(d_in, row_len, d_in / 2,
                                 CachePolicy::Contextual);
    b.run("reset_context", 100, 20_000, || {
        c.reset_context();
    });
}
