//! Micro-bench: the §4.1 elastic parameter search — runs on every memory-
//! pressure event in the serving path, so it must be cheap.

mod support;

use activeflow::costmodel::{self, Geometry};
use activeflow::device::{ALL, PIXEL6};
use support::Bench;

fn main() {
    let b = Bench::new("costmodel_search");
    let grid = [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95];
    let geo = Geometry::llama7b_q4();

    let mut budget = 1u64 << 30;
    b.run("search_llama7b", 100, 100_000, || {
        budget = 1 << 30 | (budget.wrapping_mul(6364136223846793005) % (2 << 30));
        let _ = costmodel::search(&PIXEL6, &geo, budget, 0.85, 1.0, &grid);
    });

    let mixtral = Geometry::mixtral8x7b_q4();
    b.run("search_all_devices_mixtral", 100, 30_000, || {
        for dev in ALL {
            let _ =
                costmodel::search(dev, &mixtral, 2_900 << 20, 0.85, 1.0, &grid);
        }
    });

    b.run("evaluate_single_point", 100, 200_000, || {
        let p = costmodel::PipelineParams {
            sp: 0.7,
            n_group: 4,
            cache_bytes: 256 << 20,
            hit_rate: 0.7,
            similarity: 0.85,
        };
        let _ = costmodel::evaluate(&PIXEL6, &geo, &p, 1.0);
    });
}
