//! Device profiles (paper Table 2): memory bandwidth, flash throughput
//! curve, compute rate, and power rails for the three evaluation phones.
//!
//! The flash curve follows the classic fixed-latency + streaming-bandwidth
//! model: a read of `c` bytes costs `t = lat + c / max_bw`, so effective
//! throughput `c/t` rises with chunk size and saturates at `max_bw` —
//! reproducing the shape of paper Fig 7 (MB/s at 4 KB chunks, GB/s above
//! ~1 MB chunks).

/// Power rail model for [`crate::metrics::EnergyModel`] (paper Fig 19).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerRails {
    /// Idle platform power (W).
    pub idle_w: f64,
    /// Incremental power while CPU computes (W).
    pub compute_w: f64,
    /// Incremental power while flash streams (W).
    pub flash_w: f64,
    /// Incremental power while DRAM streams at full bandwidth (W).
    pub dram_w: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Human label from the paper (Device 1/2/3).
    pub label: &'static str,
    /// DRAM bandwidth (bytes/s) available to the decode kernels.
    pub mem_bw: f64,
    /// Flash saturated bandwidth (bytes/s) — "MaxBW" in Table 2.
    pub flash_max_bw: f64,
    /// Per-I/O fixed latency (s) — controls the Fig 7 knee.
    pub flash_latency: f64,
    /// Modeled effective command queue depth of the flash controller: how
    /// many reads the device keeps in flight at once. Reads submitted
    /// together are serviced in waves of up to this many, and the per-I/O
    /// fixed latency is paid once per *wave*, not once per read — the
    /// amortization "LLM in a flash" (arXiv 2312.11514) attributes most of
    /// the usable small-read bandwidth to.
    pub queue_depth: usize,
    /// Sustained compute rate (FLOP/s) of the big cores.
    pub compute_flops: f64,
    /// *Effective* decode bandwidth (bytes of weights the CPU decode loop
    /// actually consumes per second — llama.cpp-class Q4 matvec, well below
    /// the DRAM peak). Calibrated against the paper's §7.2 Mixtral numbers;
    /// this is what the cost model's T_comp uses.
    pub decode_bw: f64,
    /// Total DRAM size in bytes (Table 2).
    pub dram_bytes: u64,
    pub power: PowerRails,
}

impl DeviceProfile {
    /// Modeled duration of a single flash read of `len` bytes.
    pub fn flash_read_seconds(&self, len: u64) -> f64 {
        self.flash_latency + len as f64 / self.flash_max_bw
    }

    /// Modeled duration of `n` reads totalling `total` bytes submitted as
    /// one batch: the device services them in waves of up to `queue_depth`
    /// concurrent reads, so the fixed latency is charged once per wave
    /// while the payload streams back-to-back at max bandwidth.
    pub fn flash_batch_seconds(&self, n: usize, total: u64) -> f64 {
        self.flash_batch_seconds_at(n, total, self.flash_max_bw)
    }

    /// The same wave model at an explicit effective bandwidth — the flash
    /// simulator passes its `bw_scale`-adjusted bandwidth through here so
    /// the batch formula lives in exactly one place.
    pub fn flash_batch_seconds_at(&self, n: usize, total: u64, bw: f64) -> f64 {
        let waves = n.max(1).div_ceil(self.queue_depth.max(1));
        waves as f64 * self.flash_latency + total as f64 / bw
    }

    /// Effective flash throughput (bytes/s) at a given chunk size — the
    /// quantity plotted in paper Fig 7.
    pub fn flash_throughput(&self, chunk: u64) -> f64 {
        chunk as f64 / self.flash_read_seconds(chunk)
    }

    /// Small-chunk bandwidth BW^small_flash at the weight-channel size
    /// (cost-model Table 1).
    pub fn bw_small(&self, channel_bytes: u64) -> f64 {
        self.flash_throughput(channel_bytes)
    }

    /// Large-chunk bandwidth BW^large_flash at the preload chunk size.
    pub fn bw_large(&self, chunk_bytes: u64) -> f64 {
        self.flash_throughput(chunk_bytes)
    }
}

/// Device 1: OnePlus 12 — X4+A720+A520, 16 GB, UFS 4.0 (5.8 GB/s).
pub const ONEPLUS12: DeviceProfile = DeviceProfile {
    name: "oneplus12",
    label: "Device 1 (OnePlus 12, UFS 4.0)",
    mem_bw: 60.0e9,
    flash_max_bw: 5.8e9,
    flash_latency: 45e-6,
    queue_depth: 32,
    compute_flops: 80.0e9,
    decode_bw: 5.7e9,
    dram_bytes: 16 * (1 << 30),
    power: PowerRails { idle_w: 0.9, compute_w: 2.6, flash_w: 1.1, dram_w: 0.9 },
};

/// Device 2: Pixel 6 — X1+A76+A55, 8 GB, UFS 3.1 (4.2 GB/s).
pub const PIXEL6: DeviceProfile = DeviceProfile {
    name: "pixel6",
    label: "Device 2 (Pixel 6, UFS 3.1)",
    mem_bw: 34.0e9,
    flash_max_bw: 4.2e9,
    flash_latency: 70e-6,
    queue_depth: 16,
    compute_flops: 35.0e9,
    decode_bw: 4.5e9,
    dram_bytes: 8 * (1 << 30),
    power: PowerRails { idle_w: 0.8, compute_w: 2.2, flash_w: 1.0, dram_w: 0.8 },
};

/// Device 3: Infinix ZERO 30 — A76+A55, 8 GB, UFS 2.2 (3.6 GB/s).
pub const INFINIX_ZERO30: DeviceProfile = DeviceProfile {
    name: "infinix",
    label: "Device 3 (Infinix ZERO 30, UFS 2.2)",
    mem_bw: 17.0e9,
    flash_max_bw: 3.6e9,
    flash_latency: 120e-6,
    queue_depth: 8,
    compute_flops: 18.0e9,
    decode_bw: 2.0e9,
    dram_bytes: 8 * (1 << 30),
    power: PowerRails { idle_w: 0.7, compute_w: 1.8, flash_w: 0.9, dram_w: 0.7 },
};

pub const ALL: [&DeviceProfile; 3] = [&ONEPLUS12, &PIXEL6, &INFINIX_ZERO30];

pub fn by_name(name: &str) -> Option<&'static DeviceProfile> {
    ALL.iter().copied().find(|d| d.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(by_name("pixel6").unwrap().name, "pixel6");
        assert!(by_name("iphone99").is_none());
    }

    #[test]
    fn throughput_monotone_in_chunk_size() {
        for d in ALL {
            let mut last = 0.0;
            for chunk in [4u64 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20] {
                let bw = d.flash_throughput(chunk);
                assert!(bw > last, "{}: bw not monotone", d.name);
                last = bw;
            }
        }
    }

    #[test]
    fn throughput_saturates_near_max() {
        for d in ALL {
            let bw = d.flash_throughput(64 << 20);
            assert!(bw > 0.9 * d.flash_max_bw);
            assert!(bw < d.flash_max_bw);
        }
    }

    #[test]
    fn small_chunks_are_mbps_not_gbps() {
        // Paper Fig 7: naive 4 KB channel reads collapse to MB/s.
        for d in ALL {
            let bw = d.flash_throughput(4 << 10);
            assert!(bw < 0.1e9, "{}: 4KB bw should be <100MB/s", d.name);
        }
    }

    #[test]
    fn batched_reads_amortize_fixed_latency() {
        // A batch within the queue depth pays ONE fixed latency; the same
        // reads issued one by one pay it n times.
        for d in ALL {
            let n = d.queue_depth; // one full wave
            let chunk = 64u64 << 10;
            let batch = d.flash_batch_seconds(n, n as u64 * chunk);
            let serial = n as f64 * d.flash_read_seconds(chunk);
            assert!(
                batch < serial,
                "{}: batch {batch} !< serial {serial}",
                d.name
            );
            // exactly one latency + streamed bytes
            let want =
                d.flash_latency + (n as u64 * chunk) as f64 / d.flash_max_bw;
            assert!((batch - want).abs() < 1e-12, "{}", d.name);
        }
    }

    #[test]
    fn batch_waves_bounded_by_queue_depth() {
        let d = &PIXEL6;
        let n = d.queue_depth * 2 + 1; // three waves
        let batch = d.flash_batch_seconds(n, 0);
        assert!((batch - 3.0 * d.flash_latency).abs() < 1e-12);
        // a batch of one degenerates to the single-read model
        assert!(
            (d.flash_batch_seconds(1, 4096) - d.flash_read_seconds(4096))
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn device_ordering_matches_table2() {
        // UFS 4.0 > 3.1 > 2.2 at every chunk size.
        for chunk in [4u64 << 10, 1 << 20] {
            let a = ONEPLUS12.flash_throughput(chunk);
            let b = PIXEL6.flash_throughput(chunk);
            let c = INFINIX_ZERO30.flash_throughput(chunk);
            assert!(a > b && b > c);
        }
    }
}
