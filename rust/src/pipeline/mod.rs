//! The active-weight swapping pipeline (paper §4, Fig 10/11).
//!
//! A dedicated **loader thread** (the paper binds it to a little core; we
//! spawn a plain thread — the flash simulator sleeps during I/O so the
//! compute thread genuinely overlaps) services preload requests at
//! layer-group granularity:
//!
//!   compute thread                    loader thread
//!   ──────────────                    ─────────────
//!   layer l0 of group G:
//!     topk(h)  ──request(G+1, qkv)──▶  read cross-layer chunks (Fig 9),
//!     exec qkv / attn / o / gu / down   dequantize *into the part slab*
//!     ...layers l0+1..l0+N-1...
//!   group G+1: wait(part) — usually already complete → near-zero stall
//!
//! Per-part completion signalling lets the engine start consuming Wq/Wk/Wv
//! of the next group while its Wd part is still streaming.
//!
//! **Slab store.** Each `(seq, op)` part is one contiguous `Vec<f32>` slab
//! laid out `[channel-major][layer][d_out]` plus a small index (sorted
//! channel list + per-row fill bitmap) — no per-row heap allocations. The
//! loader dequantizes flash chunks directly into their final slab slots;
//! the engine clones an `Arc<PartSlab>` out of the store (one map lock per
//! part) and then borrows row slices lock-free. LLM-in-a-flash-style
//! bundling (arXiv 2312.11514): rows land in their packed layout in place.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::flash::FlashDevice;
use crate::layout::{quant, AwgfFile, OpKind};

/// Key of a preload part: (monotonic group sequence number, op family).
pub type PartKey = (u64, OpKind);

/// One preload job: fetch `channels` of `op` for every layer in `layers`
/// (a runtime layer group, sequence number `seq`). The loader maps runtime
/// layers onto the file's fixed layout groups — a runtime group smaller
/// than the on-flash group reads only the contiguous sub-span of each
/// chunk covering the requested layers.
///
/// `layers` and `channels` are shared slices: sibling ops of one site
/// (Wq/Wk/Wv) clone the same `Arc<[usize]>` pointers whenever their
/// filtered channel lists coincide — no per-op `Vec` copies.
///
/// The **issuer** filters out channels that are already cache-resident
/// for the op (engine: one brief containment-only lock per site) — the
/// loader itself never touches the weight cache, which is what makes the
/// engine's wait-under-guard fetch path safe (PERF.md). `skipped_cached`
/// carries the per-op filtered row count so `LoaderStats` keeps its
/// historical meaning.
pub struct PreloadJob {
    pub seq: u64,
    pub op: OpKind,
    pub layers: Arc<[usize]>,
    pub channels: Arc<[usize]>,
    pub skipped_cached: u64,
}

enum Msg {
    Job(PreloadJob),
    Stop,
}

/// Contiguous dequantized rows of one preload part, laid out
/// `[channel-major][layer][d_out]`:
///
/// ```text
/// data = [ ch[0]·layer[0]·f32[d_out] | ch[0]·layer[1]·… | ch[1]·layer[0]·… ]
/// ```
///
/// The index is the sorted `channels` list (binary-searched) plus a fill
/// bitmap — rows the loader never wrote (channel not in the job's
/// pre-filtered list, or a failed read) stay unfilled and `row()` returns
/// `None` for them, which sends the engine down its on-demand path exactly
/// like a store miss did under the old per-row `HashMap`.
pub struct PartSlab {
    pub op: OpKind,
    layers: Arc<[usize]>,
    channels: Vec<usize>,
    d_out: usize,
    filled: Vec<bool>,
    data: Vec<f32>,
}

impl PartSlab {
    pub fn new(
        op: OpKind,
        layers: Arc<[usize]>,
        channels: &[usize],
        d_out: usize,
    ) -> PartSlab {
        let mut channels = channels.to_vec();
        channels.sort_unstable();
        channels.dedup();
        let rows = channels.len() * layers.len();
        PartSlab {
            op,
            layers,
            channels,
            d_out,
            filled: vec![false; rows],
            data: vec![0f32; rows * d_out],
        }
    }

    fn slot(&self, layer: usize, channel: usize) -> Option<usize> {
        let ci = self.channels.binary_search(&channel).ok()?;
        let li = self.layers.iter().position(|&l| l == layer)?;
        Some(ci * self.layers.len() + li)
    }

    /// Borrow one dequantized row (engine consumption, lock-free through
    /// the part's `Arc`). `None` until the loader has filled that row.
    pub fn row(&self, layer: usize, channel: usize) -> Option<&[f32]> {
        let s = self.slot(layer, channel)?;
        if !self.filled[s] {
            return None;
        }
        Some(&self.data[s * self.d_out..(s + 1) * self.d_out])
    }

    /// Mutable row slot for the loader's in-place dequantization; marks the
    /// row filled.
    pub fn row_mut(&mut self, layer: usize, channel: usize) -> Option<&mut [f32]> {
        let s = self.slot(layer, channel)?;
        self.filled[s] = true;
        Some(&mut self.data[s * self.d_out..(s + 1) * self.d_out])
    }

    pub fn layers(&self) -> &[usize] {
        &self.layers
    }

    /// Sorted, deduplicated channel index of the slab.
    pub fn channels(&self) -> &[usize] {
        &self.channels
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Slab payload bytes (the live M_cl component of this part).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

#[derive(Default)]
struct SharedState {
    /// Completed parts. A part appears here only once fully loaded.
    slabs: Mutex<HashMap<PartKey, Arc<PartSlab>>>,
    done: Mutex<std::collections::HashSet<PartKey>>,
    /// Highest retired group seq (seqs are monotonic). A slab finishing
    /// after its group was retired is dropped instead of published — the
    /// engine has already moved on and nothing would ever free it.
    retired: Mutex<u64>,
    /// Loader-side statistics.
    stats: Mutex<LoaderStats>,
}

#[derive(Debug, Default, Clone)]
pub struct LoaderStats {
    pub chunks_read: u64,
    pub bytes_read: u64,
    pub channels_loaded: u64,
    pub channels_skipped_cached: u64,
    /// Bytes currently held by live part slabs.
    pub slab_bytes: u64,
    /// High-water mark of `slab_bytes` (M_cl peak, loader view).
    pub slab_bytes_peak: u64,
    /// Modeled flash busy time.
    pub busy: Duration,
}

/// Handle owned by the engine.
pub struct Pipeline {
    tx: Sender<Msg>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>, // bumped on every completion
    handle: Option<JoinHandle<()>>,
}

impl Pipeline {
    pub fn spawn(awgf: Arc<AwgfFile>, flash: Arc<FlashDevice>) -> Pipeline {
        let (tx, rx) = channel();
        let shared = Arc::new(SharedState::default());
        let cv = Arc::new(Condvar::new());
        let cv_guard = Arc::new(Mutex::new(0u64));
        let worker = LoaderWorker {
            awgf,
            flash,
            shared: shared.clone(),
            cv: cv.clone(),
            cv_guard: cv_guard.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("awf-loader".into())
            .spawn(move || worker.run(rx))
            .expect("spawn loader thread");
        Pipeline {
            tx,
            shared,
            cv,
            cv_guard,
            handle: Some(handle),
        }
    }

    /// Enqueue a preload part (non-blocking — the submit side of io_uring).
    pub fn request(&self, job: PreloadJob) {
        let _ = self.tx.send(Msg::Job(job));
    }

    /// Block until part `(seq, op)` has been fully loaded. Returns false on
    /// timeout (loader wedged/dead) — the engine then falls back to
    /// on-demand loading instead of hanging the decode.
    pub fn wait_part(&self, key: PartKey) -> bool {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut gen = self.cv_guard.lock().unwrap();
        loop {
            if self.shared.done.lock().unwrap().contains(&key) {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                eprintln!("[pipeline] wait_part timeout on {key:?}");
                return false;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(gen, deadline - now)
                .unwrap();
            gen = g;
        }
    }

    pub fn part_ready(&self, key: PartKey) -> bool {
        self.shared.done.lock().unwrap().contains(&key)
    }

    /// Clone the completed part's slab out of the store — one map lock,
    /// after which the engine reads rows without any synchronization.
    pub fn part(&self, key: PartKey) -> Option<Arc<PartSlab>> {
        self.shared.slabs.lock().unwrap().get(&key).cloned()
    }

    /// Drop a fully consumed group's slabs + completion marks (frees
    /// M_cl). Holding the `retired` guard across the removals excludes the
    /// loader's publish: a part finishing after this point sees the raised
    /// high-water mark and is dropped, never leaked (seqs are monotonic,
    /// so retiring `seq` can also cover any abandoned earlier groups).
    pub fn retire_group(&self, seq: u64) {
        let mut retired = self.shared.retired.lock().unwrap();
        *retired = (*retired).max(seq);
        let mut freed = 0u64;
        {
            let mut slabs = self.shared.slabs.lock().unwrap();
            slabs.retain(|(s, _), slab| {
                if *s <= seq {
                    freed += slab.bytes();
                    false
                } else {
                    true
                }
            });
        }
        if freed > 0 {
            let mut st = self.shared.stats.lock().unwrap();
            st.slab_bytes = st.slab_bytes.saturating_sub(freed);
        }
        self.shared
            .done
            .lock()
            .unwrap()
            .retain(|(s, _)| *s > seq);
    }

    /// Bytes currently held in preload slabs (the live M_cl component).
    pub fn stored_bytes(&self) -> u64 {
        let slabs = self.shared.slabs.lock().unwrap();
        slabs.values().map(|s| s.bytes()).sum()
    }

    pub fn loader_stats(&self) -> LoaderStats {
        self.shared.stats.lock().unwrap().clone()
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoaderWorker {
    awgf: Arc<AwgfFile>,
    flash: Arc<FlashDevice>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>,
}

impl LoaderWorker {
    fn run(self, rx: Receiver<Msg>) {
        while let Ok(msg) = rx.recv() {
            match msg {
                Msg::Stop => break,
                Msg::Job(job) => {
                    let slab = match self.process(&job) {
                        Ok(s) => Some(s),
                        Err(e) => {
                            eprintln!("[loader] preload failed: {e:#}");
                            None // still mark done: waiters fall back
                        }
                    };
                    // Publish + mark done under the `retired` guard: if the
                    // engine retired this group while we were loading (its
                    // fetch never needed to wait), the slab is dropped here
                    // instead of leaking in the store forever.
                    {
                        let retired = self.shared.retired.lock().unwrap();
                        if job.seq > *retired {
                            if let Some(slab) = slab {
                                let bytes = slab.bytes();
                                self.shared
                                    .slabs
                                    .lock()
                                    .unwrap()
                                    .insert((job.seq, job.op), Arc::new(slab));
                                let mut st =
                                    self.shared.stats.lock().unwrap();
                                st.slab_bytes += bytes;
                                st.slab_bytes_peak =
                                    st.slab_bytes_peak.max(st.slab_bytes);
                            }
                            self.shared
                                .done
                                .lock()
                                .unwrap()
                                .insert((job.seq, job.op));
                        }
                    }
                    // wake waiters (also on the retired/error paths, so a
                    // racing wait_part re-checks instead of sleeping on)
                    let mut gen = self.cv_guard.lock().unwrap();
                    *gen += 1;
                    drop(gen);
                    self.cv.notify_all();
                }
            }
        }
    }

    fn process(&self, job: &PreloadJob) -> Result<PartSlab> {
        let info = self.awgf.op(job.op);
        let dout = info.d_out;
        let rb = info.row_bytes;
        let quant = self.awgf.quant;

        // The part's slab, allocated once; every read dequantizes straight
        // into its final slot (no per-row scratch, no per-row Vec). The
        // channel list arrives pre-filtered (issuer dropped cache-resident
        // channels); account the skips for the historical stat.
        if job.skipped_cached > 0 {
            self.shared.stats.lock().unwrap().channels_skipped_cached +=
                job.skipped_cached;
        }
        let mut slab =
            PartSlab::new(job.op, job.layers.clone(), &job.channels, dout);

        // Partition the runtime layers by on-flash layout group; within a
        // layout group the requested layers occupy consecutive row slots of
        // every chunk, so each (layout-group, channel) is one contiguous
        // sub-span read.
        let mut by_group: Vec<(usize, Vec<usize>)> = Vec::new();
        for &l in job.layers.iter() {
            let g = info
                .groups
                .iter()
                .position(|grp| grp.layers.contains(&l))
                .ok_or_else(|| anyhow::anyhow!("layer {l} not in layout"))?;
            match by_group.last_mut() {
                Some((gg, ls)) if *gg == g => ls.push(l),
                _ => by_group.push((g, vec![l])),
            }
        }

        for (g, layers) in by_group {
            let grp = &info.groups[g];
            let j_of = |l: usize| grp.layers.iter().position(|&x| x == l).unwrap();
            let j_min = layers.iter().map(|&l| j_of(l)).min().unwrap();
            let j_max = layers.iter().map(|&l| j_of(l)).max().unwrap();
            let span = (j_max - j_min + 1) * rb;
            let full_chunk = span == grp.layers.len() * rb;
            let n_layers = layers.len();

            // Coalesce adjacent channels into single I/Os — only valid when
            // the sub-span is the whole chunk (otherwise reads have gaps).
            let mut runs: Vec<(usize, usize)> = Vec::new();
            for &ch in slab.channels() {
                match runs.last_mut() {
                    Some((s, l)) if full_chunk && *s + *l == ch => *l += 1,
                    _ => runs.push((ch, 1)),
                }
            }

            for (start_ch, len) in runs {
                let (chunk_off, chunk_len) =
                    self.awgf.chunk_span(job.op, g, start_ch);
                let (off, stride) = if full_chunk {
                    (chunk_off, chunk_len)
                } else {
                    (chunk_off + (j_min * rb) as u64, span)
                };
                let total = if full_chunk { chunk_len * len } else { span };
                let buf = self.flash.read(off, total)?;
                {
                    let mut st = self.shared.stats.lock().unwrap();
                    st.chunks_read += 1;
                    st.bytes_read += total as u64;
                    st.channels_loaded += (len * n_layers) as u64;
                    st.busy += Duration::from_nanos(
                        self.flash.model_read_ns(total as u64),
                    );
                }
                for ci in 0..len {
                    let ch = start_ch + ci;
                    for &layer in &layers {
                        let base = ci * stride + (j_of(layer) - j_min) * rb;
                        let row = slab
                            .row_mut(layer, ch)
                            .expect("slab covers all job channels");
                        quant::dequantize_row(&buf[base..base + rb], quant, row);
                    }
                }
            }
        }

        Ok(slab)
    }
}

#[cfg(test)]
mod tests {
    // Pipeline tests need a real AWGF file; they live in
    // rust/tests/pipeline_integration.rs (built from artifacts/model.awgf)
    // and in the in-memory harness below using a synthetic file.
    use super::*;
    use crate::config::ModelConfig;
    use crate::device::PIXEL6;
    use crate::flash::ClockMode;
    use crate::layout::TensorId;
    use crate::util::prop::{check, GenExt};

    /// Build a tiny synthetic AWGF file on disk via the python-compatible
    /// writer logic (re-implemented in the test for independence).
    fn synth_awgf(dir: &std::path::Path) -> std::path::PathBuf {
        use crate::layout::quant::{quantize_row, Quant};
        let cfg = ModelConfig {
            n_layers: 2,
            ..ModelConfig::tiny()
        };
        let path = dir.join("synth.awgf");
        // header json mirroring export.py, single op (wq) for brevity
        let mut payload: Vec<u8> = Vec::new();
        // dense: embed [vocab,d] zeros
        let embed_len = cfg.vocab_size * cfg.d_model * 4;
        let embed_off = payload.len();
        payload.extend(std::iter::repeat(0u8).take(embed_len));
        // op wq: d_in=128 rows of d_out=128, layers [0,1] in one group
        let rb = crate::layout::row_bytes(Quant::Q8_0, cfg.d_model);
        let wq_off = payload.len();
        for c in 0..cfg.d_model {
            for l in 0..2usize {
                let row: Vec<f32> = (0..cfg.d_model)
                    .map(|j| (c * 2 + l) as f32 + j as f32 * 1e-3)
                    .collect();
                payload.extend(quantize_row(&row, Quant::Q8_0));
            }
        }
        let hdr = format!(
            r#"{{"model":{{"name":"synth","vocab_size":{v},"d_model":{d},
"n_layers":2,"n_heads":4,"n_kv_heads":2,"head_dim":32,"d_ff":384,
"max_seq":16,"rope_theta":10000.0,"norm_eps":1e-5}},
"quant":"q8_0","group_size":2,
"dense":{{"embed":{{"offset":{eo},"len":{el},"shape":[{v},{d}]}}}},
"ops":{{"wq":{{"d_in":{d},"d_out":{d},"row_bytes":{rb},
"groups":[{{"layers":[0,1],"offset":{wo}}}]}}}}}}"#,
            v = cfg.vocab_size,
            d = cfg.d_model,
            eo = embed_off,
            el = embed_len,
            rb = rb,
            wo = wq_off,
        );
        let mut file = Vec::new();
        file.extend(b"AWGF");
        file.extend(1u32.to_le_bytes());
        file.extend((hdr.len() as u32).to_le_bytes());
        file.extend(hdr.as_bytes());
        while file.len() % 4096 != 0 {
            file.push(0);
        }
        file.extend(&payload);
        std::fs::write(&path, file).unwrap();
        path
    }

    fn setup() -> (Arc<AwgfFile>, Arc<FlashDevice>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("awf_pipe_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = synth_awgf(&dir);
        let awgf = Arc::new(AwgfFile::open(&path).unwrap());
        let flash =
            FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 1.0).unwrap();
        (awgf, flash, path)
    }

    fn job(seq: u64, layers: &[usize], channels: &[usize]) -> PreloadJob {
        PreloadJob {
            seq,
            op: OpKind::Wq,
            layers: Arc::from(layers),
            channels: Arc::from(channels),
            skipped_cached: 0,
        }
    }

    #[test]
    fn preload_roundtrip_values_match_layout() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(1, &[0, 1], &[3, 4, 5, 100]));
        pipe.wait_part((1, OpKind::Wq));
        let slab = pipe.part((1, OpKind::Wq)).expect("slab published");
        for l in 0..2usize {
            for ch in [3usize, 4, 5, 100] {
                let row = slab
                    .row(l, ch)
                    .unwrap_or_else(|| panic!("missing row l{l} ch{ch}"));
                // synth rows encode (c*2+l) in element 0 (q8_0 tolerance)
                let want = (ch * 2 + l) as f32;
                assert!(
                    (row[0] - want).abs() <= want.abs() / 127.0 + 1e-2,
                    "l{l} ch{ch}: {} != {want}",
                    row[0]
                );
            }
        }
        // rows are borrowed, not consumed — a second read sees them too
        assert!(slab.row(0, 3).is_some());
        // unrequested channels are store misses
        assert!(slab.row(0, 7).is_none());
    }

    #[test]
    fn adjacent_channels_coalesce_into_one_chunk() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        let chs: Vec<usize> = (10..20).collect(); // one contiguous run
        pipe.request(job(7, &[0, 1], &chs));
        pipe.wait_part((7, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.chunks_read, 1, "10 adjacent channels = 1 I/O");
        assert_eq!(st.channels_loaded, 20);
        assert!(st.slab_bytes_peak > 0);
    }

    #[test]
    fn issuer_filtered_channels_stay_out_of_the_slab() {
        // The engine filters cache-resident channels *before* sending the
        // job (the loader never touches the cache — PERF.md): a job whose
        // channel list had ch42 filtered out must not load it, and the
        // skip count it carries lands in the historical stat.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(PreloadJob {
            seq: 2,
            op: OpKind::Wq,
            layers: Arc::from(&[0usize, 1][..]),
            channels: Arc::from(&[41usize, 43][..]), // 42 filtered out
            skipped_cached: 2,                       // ch42 × 2 layers
        });
        pipe.wait_part((2, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.channels_skipped_cached, 2);
        assert_eq!(st.channels_loaded, 4); // 2 channels × 2 layers
        let slab = pipe.part((2, OpKind::Wq)).unwrap();
        assert!(slab.row(0, 42).is_none(), "filtered row stays unfilled");
        assert!(slab.row(0, 41).is_some());
        assert!(slab.row(1, 43).is_some());
    }

    #[test]
    fn retire_group_frees_store() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(3, &[0, 1], &[0, 1]));
        pipe.wait_part((3, OpKind::Wq));
        assert!(pipe.stored_bytes() > 0);
        pipe.retire_group(3);
        assert_eq!(pipe.stored_bytes(), 0);
        assert_eq!(pipe.loader_stats().slab_bytes, 0);
        assert!(!pipe.part_ready((3, OpKind::Wq)));
        assert!(pipe.part((3, OpKind::Wq)).is_none());
    }

    #[test]
    fn slab_finishing_after_retire_is_dropped_not_leaked() {
        // The engine retires a group as soon as it finishes consuming it —
        // possibly while the loader is still reading that group's last
        // part (a fully cache-served fetch never waits). The late slab
        // must be dropped, and the byte accounting must not drift.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.retire_group(5); // group 5 already consumed and retired
        pipe.request(job(5, &[0, 1], &[1, 2])); // loader finishes late
        pipe.request(job(6, &[0, 1], &[3]));
        assert!(pipe.wait_part((6, OpKind::Wq))); // FIFO: 5 processed first
        assert!(!pipe.part_ready((5, OpKind::Wq)));
        assert!(pipe.part((5, OpKind::Wq)).is_none(), "late slab dropped");
        let bytes6 = pipe.part((6, OpKind::Wq)).unwrap().bytes();
        assert_eq!(pipe.stored_bytes(), bytes6);
        assert_eq!(pipe.loader_stats().slab_bytes, bytes6,
                   "accounting excludes the dropped slab");
    }

    #[test]
    fn pipeline_shutdown_clean() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        drop(pipe); // must join without deadlock
    }

    #[test]
    fn slab_rows_hold_no_per_row_allocations() {
        // the whole part is exactly one contiguous buffer: channels×layers
        // rows of d_out floats, regardless of access order
        let layers: Arc<[usize]> = Arc::from(&[0usize, 1][..]);
        let mut slab = PartSlab::new(OpKind::Wq, layers, &[9, 4, 4, 2], 8);
        assert_eq!(slab.channels(), &[2, 4, 9]); // sorted + deduped
        assert_eq!(slab.bytes(), (3 * 2 * 8 * 4) as u64);
        assert!(slab.row(0, 4).is_none(), "unfilled row is a miss");
        slab.row_mut(0, 4).unwrap().fill(7.0);
        assert_eq!(slab.row(0, 4).unwrap(), &[7.0f32; 8][..]);
        assert!(slab.row(1, 4).is_none(), "per-(layer,channel) fill");
        assert!(slab.row(0, 3).is_none(), "unknown channel");
        assert!(slab.row_mut(2, 4).is_none(), "unknown layer");
    }

    /// The slab store must be bit-identical to the old per-row HashMap
    /// store: both dequantize the same flash bytes with the same codec, so
    /// for every random (layers, channels, cache-filter state) each loaded
    /// row must equal an independently read+dequantized reference row
    /// exactly, and filtered channels must stay store misses.
    #[test]
    fn slab_store_bit_identical_to_per_row_reference() {
        let (awgf, flash, _p) = setup();
        check("slab-vs-hashmap", |g| {
            let n_layers = g.usize_in(1, 2);
            let layers: Vec<usize> = if n_layers == 2 {
                vec![0, 1]
            } else {
                vec![g.usize_in(0, 1)]
            };
            let k = g.usize_in(1, 24);
            let requested = g.subset(128, k);
            // random cache state: the issuer filters a random subset of
            // the requested channels out of the job (as the engine does
            // for fully cache-resident channels)
            let pre = g.subset(128, g.usize_in(0, 16));
            let channels: Vec<usize> = requested
                .iter()
                .copied()
                .filter(|ch| !pre.contains(ch))
                .collect();
            let pipe = Pipeline::spawn(awgf.clone(), flash.clone());
            pipe.request(PreloadJob {
                seq: 1,
                op: OpKind::Wq,
                layers: Arc::from(&layers[..]),
                channels: Arc::from(&channels[..]),
                skipped_cached: ((requested.len() - channels.len())
                    * layers.len()) as u64,
            });
            if !pipe.wait_part((1, OpKind::Wq)) {
                return Err("loader timed out".into());
            }
            let slab = pipe.part((1, OpKind::Wq)).unwrap();
            // reference: the old per-row path — read each (layer, channel)
            // row span individually and dequantize into its own Vec
            let mut reference: HashMap<(TensorId, u32), Vec<f32>> =
                HashMap::new();
            for &l in &layers {
                for &ch in &channels {
                    let (off, len) = awgf.row_span(OpKind::Wq, l, ch);
                    let buf = flash.read(off, len).map_err(|e| e.to_string())?;
                    let mut row = vec![0f32; 128];
                    quant::dequantize_row(&buf, awgf.quant, &mut row);
                    reference.insert((TensorId::new(l, OpKind::Wq), ch as u32), row);
                }
            }
            for &l in &layers {
                for &ch in &requested {
                    match slab.row(l, ch) {
                        Some(got) => {
                            if pre.contains(&ch) {
                                return Err(format!(
                                    "filtered ch{ch} must stay a store miss"
                                ));
                            }
                            let want = &reference
                                [&(TensorId::new(l, OpKind::Wq), ch as u32)];
                            if got != want.as_slice() {
                                return Err(format!(
                                    "row l{l} ch{ch} differs from reference"
                                ));
                            }
                        }
                        None => {
                            if !pre.contains(&ch) {
                                return Err(format!(
                                    "row l{l} ch{ch} missing from slab"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
