//! The active-weight swapping pipeline (paper §4, Fig 10/11).
//!
//! A dedicated **loader thread** (the paper binds it to a little core; we
//! spawn a plain thread — the flash simulator sleeps during I/O so the
//! compute thread genuinely overlaps) services preload requests at
//! layer-group granularity:
//!
//!   compute thread                    loader thread
//!   ──────────────                    ─────────────
//!   layer l0 of group G:
//!     topk(h)  ──request(G+1, qkv)──▶  read cross-layer chunks (Fig 9),
//!     exec qkv / attn / o / gu / down   dequantize, fill the group store
//!     ...layers l0+1..l0+N-1...
//!   group G+1: wait(part) — usually already complete → near-zero stall
//!
//! Per-part completion signalling lets the engine start consuming Wq/Wk/Wv
//! of the next group while its Wd part is still streaming.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::cache::WeightCache;
use crate::flash::FlashDevice;
use crate::layout::{quant, AwgfFile, OpKind, TensorId};

/// Key of a preload part: (monotonic group sequence number, op family).
pub type PartKey = (u64, OpKind);

/// One preload job: fetch `channels` of `op` for every layer in `layers`
/// (a runtime layer group, sequence number `seq`). The loader maps runtime
/// layers onto the file's fixed layout groups — a runtime group smaller
/// than the on-flash group reads only the contiguous sub-span of each
/// chunk covering the requested layers.
pub struct PreloadJob {
    pub seq: u64,
    pub op: OpKind,
    pub layers: Vec<usize>,
    pub channels: Vec<usize>,
}

enum Msg {
    Job(PreloadJob),
    Stop,
}

/// Rows preloaded for upcoming layers, keyed by (tensor, channel).
#[derive(Default)]
pub struct GroupStore {
    pub rows: HashMap<(TensorId, u32), Vec<f32>>,
}

#[derive(Default)]
struct SharedState {
    /// Completed parts and their row stores (merged per group seq).
    stores: Mutex<HashMap<u64, GroupStore>>,
    done: Mutex<std::collections::HashSet<PartKey>>,
    /// Loader-side statistics.
    stats: Mutex<LoaderStats>,
}

#[derive(Debug, Default, Clone)]
pub struct LoaderStats {
    pub chunks_read: u64,
    pub bytes_read: u64,
    pub channels_loaded: u64,
    pub channels_skipped_cached: u64,
    /// Modeled flash busy time.
    pub busy: Duration,
}

/// Handle owned by the engine.
pub struct Pipeline {
    tx: Sender<Msg>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>, // bumped on every completion
    handle: Option<JoinHandle<()>>,
}

impl Pipeline {
    pub fn spawn(
        awgf: Arc<AwgfFile>,
        flash: Arc<FlashDevice>,
        cache: Arc<Mutex<WeightCache>>,
    ) -> Pipeline {
        let (tx, rx) = channel();
        let shared = Arc::new(SharedState::default());
        let cv = Arc::new(Condvar::new());
        let cv_guard = Arc::new(Mutex::new(0u64));
        let worker = LoaderWorker {
            awgf,
            flash,
            cache,
            shared: shared.clone(),
            cv: cv.clone(),
            cv_guard: cv_guard.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("awf-loader".into())
            .spawn(move || worker.run(rx))
            .expect("spawn loader thread");
        Pipeline {
            tx,
            shared,
            cv,
            cv_guard,
            handle: Some(handle),
        }
    }

    /// Enqueue a preload part (non-blocking — the submit side of io_uring).
    pub fn request(&self, job: PreloadJob) {
        let _ = self.tx.send(Msg::Job(job));
    }

    /// Block until part `(seq, op)` has been fully loaded. Returns false on
    /// timeout (loader wedged/dead) — the engine then falls back to
    /// on-demand loading instead of hanging the decode.
    pub fn wait_part(&self, key: PartKey) -> bool {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut gen = self.cv_guard.lock().unwrap();
        loop {
            if self.shared.done.lock().unwrap().contains(&key) {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                eprintln!("[pipeline] wait_part timeout on {key:?}");
                return false;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(gen, deadline - now)
                .unwrap();
            gen = g;
        }
    }

    pub fn part_ready(&self, key: PartKey) -> bool {
        self.shared.done.lock().unwrap().contains(&key)
    }

    /// Take a preloaded row out of the group store (engine consumption).
    pub fn take_row(&self, seq: u64, id: TensorId, channel: usize) -> Option<Vec<f32>> {
        let mut stores = self.shared.stores.lock().unwrap();
        stores
            .get_mut(&seq)?
            .rows
            .remove(&(id, channel as u32))
    }

    /// Drop a fully consumed group's store + completion marks (frees M_cl).
    pub fn retire_group(&self, seq: u64) {
        self.shared.stores.lock().unwrap().remove(&seq);
        self.shared
            .done
            .lock()
            .unwrap()
            .retain(|(s, _)| *s != seq);
    }

    /// Bytes currently held in preload stores (the live M_cl component).
    pub fn stored_bytes(&self) -> u64 {
        let stores = self.shared.stores.lock().unwrap();
        stores
            .values()
            .map(|g| {
                g.rows.values().map(|r| (r.len() * 4) as u64).sum::<u64>()
            })
            .sum()
    }

    pub fn loader_stats(&self) -> LoaderStats {
        self.shared.stats.lock().unwrap().clone()
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoaderWorker {
    awgf: Arc<AwgfFile>,
    flash: Arc<FlashDevice>,
    cache: Arc<Mutex<WeightCache>>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>,
}

impl LoaderWorker {
    fn run(self, rx: Receiver<Msg>) {
        while let Ok(msg) = rx.recv() {
            match msg {
                Msg::Stop => break,
                Msg::Job(job) => {
                    if let Err(e) = self.process(&job) {
                        eprintln!("[loader] preload failed: {e:#}");
                    }
                    // mark part done + wake waiters
                    self.shared
                        .done
                        .lock()
                        .unwrap()
                        .insert((job.seq, job.op));
                    let mut gen = self.cv_guard.lock().unwrap();
                    *gen += 1;
                    drop(gen);
                    self.cv.notify_all();
                }
            }
        }
    }

    fn process(&self, job: &PreloadJob) -> Result<()> {
        let info = self.awgf.op(job.op);
        let dout = info.d_out;
        let rb = info.row_bytes;
        let quant = self.awgf.quant;

        // Partition the runtime layers by on-flash layout group; within a
        // layout group the requested layers occupy consecutive row slots of
        // every chunk, so each (layout-group, channel) is one contiguous
        // sub-span read.
        let mut by_group: Vec<(usize, Vec<usize>)> = Vec::new();
        for &l in &job.layers {
            let g = info
                .groups
                .iter()
                .position(|grp| grp.layers.contains(&l))
                .ok_or_else(|| anyhow::anyhow!("layer {l} not in layout"))?;
            match by_group.last_mut() {
                Some((gg, ls)) if *gg == g => ls.push(l),
                _ => by_group.push((g, vec![l])),
            }
        }

        for (g, layers) in by_group {
            let grp = &info.groups[g];
            let j_of = |l: usize| grp.layers.iter().position(|&x| x == l).unwrap();
            let j_min = layers.iter().map(|&l| j_of(l)).min().unwrap();
            let j_max = layers.iter().map(|&l| j_of(l)).max().unwrap();
            let span = (j_max - j_min + 1) * rb;
            let full_chunk = span == grp.layers.len() * rb;
            let n_layers = layers.len();

            // Skip channels already cached for every requested layer.
            let mut to_read: Vec<usize> =
                Vec::with_capacity(job.channels.len());
            {
                let cache = self.cache.lock().unwrap();
                for &ch in &job.channels {
                    let all_cached = layers.iter().all(|&l| {
                        cache
                            .tensors
                            .get(&TensorId::new(l, job.op))
                            .map(|t| t.contains(ch))
                            .unwrap_or(false)
                    });
                    if all_cached {
                        self.shared
                            .stats
                            .lock()
                            .unwrap()
                            .channels_skipped_cached += n_layers as u64;
                    } else {
                        to_read.push(ch);
                    }
                }
            }

            // Coalesce adjacent channels into single I/Os — only valid when
            // the sub-span is the whole chunk (otherwise reads have gaps).
            let mut runs: Vec<(usize, usize)> = Vec::new();
            for &ch in &to_read {
                match runs.last_mut() {
                    Some((s, l)) if full_chunk && *s + *l == ch => *l += 1,
                    _ => runs.push((ch, 1)),
                }
            }

            let mut row_f32 = vec![0f32; dout];
            for (start_ch, len) in runs {
                let (chunk_off, chunk_len) =
                    self.awgf.chunk_span(job.op, g, start_ch);
                let (off, stride) = if full_chunk {
                    (chunk_off, chunk_len)
                } else {
                    (chunk_off + (j_min * rb) as u64, span)
                };
                let total = if full_chunk { chunk_len * len } else { span };
                let buf = self.flash.read(off, total)?;
                {
                    let mut st = self.shared.stats.lock().unwrap();
                    st.chunks_read += 1;
                    st.bytes_read += total as u64;
                    st.channels_loaded += (len * n_layers) as u64;
                    st.busy += Duration::from_nanos(
                        self.flash.model_read_ns(total as u64),
                    );
                }
                let mut stores = self.shared.stores.lock().unwrap();
                let store = stores.entry(job.seq).or_default();
                for ci in 0..len {
                    let ch = start_ch + ci;
                    for &layer in &layers {
                        let base = ci * stride + (j_of(layer) - j_min) * rb;
                        quant::dequantize_row(
                            &buf[base..base + rb],
                            quant,
                            &mut row_f32,
                        );
                        store.rows.insert(
                            (TensorId::new(layer, job.op), ch as u32),
                            row_f32.clone(),
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Pipeline tests need a real AWGF file; they live in
    // rust/tests/pipeline_integration.rs (built from artifacts/model.awgf)
    // and in the in-memory harness below using a synthetic file.
    use super::*;
    use crate::cache::{CachePolicy, WeightCache};
    use crate::config::ModelConfig;
    use crate::device::PIXEL6;
    use crate::flash::ClockMode;

    /// Build a tiny synthetic AWGF file on disk via the python-compatible
    /// writer logic (re-implemented in the test for independence).
    fn synth_awgf(dir: &std::path::Path) -> std::path::PathBuf {
        use crate::layout::quant::{quantize_row, Quant};
        let cfg = ModelConfig {
            n_layers: 2,
            ..ModelConfig::tiny()
        };
        let path = dir.join("synth.awgf");
        // header json mirroring export.py, single op (wq) for brevity
        let mut payload: Vec<u8> = Vec::new();
        // dense: embed [vocab,d] zeros
        let embed_len = cfg.vocab_size * cfg.d_model * 4;
        let embed_off = payload.len();
        payload.extend(std::iter::repeat(0u8).take(embed_len));
        // op wq: d_in=128 rows of d_out=128, layers [0,1] in one group
        let rb = crate::layout::row_bytes(Quant::Q8_0, cfg.d_model);
        let wq_off = payload.len();
        for c in 0..cfg.d_model {
            for l in 0..2usize {
                let row: Vec<f32> = (0..cfg.d_model)
                    .map(|j| (c * 2 + l) as f32 + j as f32 * 1e-3)
                    .collect();
                payload.extend(quantize_row(&row, Quant::Q8_0));
            }
        }
        let hdr = format!(
            r#"{{"model":{{"name":"synth","vocab_size":{v},"d_model":{d},
"n_layers":2,"n_heads":4,"n_kv_heads":2,"head_dim":32,"d_ff":384,
"max_seq":16,"rope_theta":10000.0,"norm_eps":1e-5}},
"quant":"q8_0","group_size":2,
"dense":{{"embed":{{"offset":{eo},"len":{el},"shape":[{v},{d}]}}}},
"ops":{{"wq":{{"d_in":{d},"d_out":{d},"row_bytes":{rb},
"groups":[{{"layers":[0,1],"offset":{wo}}}]}}}}}}"#,
            v = cfg.vocab_size,
            d = cfg.d_model,
            eo = embed_off,
            el = embed_len,
            rb = rb,
            wo = wq_off,
        );
        let mut file = Vec::new();
        file.extend(b"AWGF");
        file.extend(1u32.to_le_bytes());
        file.extend((hdr.len() as u32).to_le_bytes());
        file.extend(hdr.as_bytes());
        while file.len() % 4096 != 0 {
            file.push(0);
        }
        file.extend(&payload);
        std::fs::write(&path, file).unwrap();
        path
    }

    fn setup() -> (Arc<AwgfFile>, Arc<FlashDevice>, Arc<Mutex<WeightCache>>,
                   std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("awf_pipe_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = synth_awgf(&dir);
        let awgf = Arc::new(AwgfFile::open(&path).unwrap());
        let flash =
            FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 1.0).unwrap();
        let dims: Vec<(TensorId, usize, usize)> = (0..2)
            .map(|l| (TensorId::new(l, OpKind::Wq), 128, 128))
            .collect();
        let cache = Arc::new(Mutex::new(WeightCache::new(
            &dims,
            64 * 1024,
            CachePolicy::Contextual,
        )));
        (awgf, flash, cache, path)
    }

    #[test]
    fn preload_roundtrip_values_match_layout() {
        let (awgf, flash, cache, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash, cache);
        pipe.request(PreloadJob {
            seq: 1,
            op: OpKind::Wq,
            layers: vec![0, 1],
            channels: vec![3, 4, 5, 100],
        });
        pipe.wait_part((1, OpKind::Wq));
        for l in 0..2usize {
            for ch in [3usize, 4, 5, 100] {
                let row = pipe
                    .take_row(1, TensorId::new(l, OpKind::Wq), ch)
                    .unwrap_or_else(|| panic!("missing row l{l} ch{ch}"));
                // synth rows encode (c*2+l) in element 0 (q8_0 tolerance)
                let want = (ch * 2 + l) as f32;
                assert!(
                    (row[0] - want).abs() <= want.abs() / 127.0 + 1e-2,
                    "l{l} ch{ch}: {} != {want}",
                    row[0]
                );
            }
        }
        // consumed rows are gone
        assert!(pipe
            .take_row(1, TensorId::new(0, OpKind::Wq), 3)
            .is_none());
    }

    #[test]
    fn adjacent_channels_coalesce_into_one_chunk() {
        let (awgf, flash, cache, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash, cache);
        pipe.request(PreloadJob {
            seq: 7,
            op: OpKind::Wq,
            layers: vec![0, 1],
            channels: (10..20).collect(), // one contiguous run
        });
        pipe.wait_part((7, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.chunks_read, 1, "10 adjacent channels = 1 I/O");
        assert_eq!(st.channels_loaded, 20);
    }

    #[test]
    fn cached_channels_are_skipped() {
        let (awgf, flash, cache, _p) = setup();
        // pre-cache channel 42 for both layers
        {
            let mut c = cache.lock().unwrap();
            let row = vec![0f32; 128];
            for l in 0..2 {
                let t = c.tensor_mut(TensorId::new(l, OpKind::Wq));
                t.lookup(42);
                t.insert(42, &row);
            }
        }
        let pipe = Pipeline::spawn(awgf, flash, cache);
        pipe.request(PreloadJob {
            seq: 2,
            op: OpKind::Wq,
            layers: vec![0, 1],
            channels: vec![41, 42, 43],
        });
        pipe.wait_part((2, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.channels_skipped_cached, 2); // ch42 × 2 layers
        assert!(pipe
            .take_row(2, TensorId::new(0, OpKind::Wq), 42)
            .is_none());
        assert!(pipe
            .take_row(2, TensorId::new(0, OpKind::Wq), 41)
            .is_some());
    }

    #[test]
    fn retire_group_frees_store() {
        let (awgf, flash, cache, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash, cache);
        pipe.request(PreloadJob {
            seq: 3,
            op: OpKind::Wq,
            layers: vec![0, 1],
            channels: vec![0, 1],
        });
        pipe.wait_part((3, OpKind::Wq));
        assert!(pipe.stored_bytes() > 0);
        pipe.retire_group(3);
        assert_eq!(pipe.stored_bytes(), 0);
        assert!(!pipe.part_ready((3, OpKind::Wq)));
    }

    #[test]
    fn pipeline_shutdown_clean() {
        let (awgf, flash, cache, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash, cache);
        drop(pipe); // must join without deadlock
    }
}
