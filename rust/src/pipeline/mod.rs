//! The active-weight swapping pipeline (paper §4, Fig 10/11).
//!
//! A dedicated **loader thread** (the paper binds it to a little core; we
//! spawn a plain thread — the flash simulator sleeps during I/O so the
//! compute thread genuinely overlaps) services preload requests at
//! layer-group granularity:
//!
//!   compute thread                    loader thread
//!   ──────────────                    ─────────────
//!   layer l0 of group G:
//!     topk(h)  ──request(G+1, qkv)──▶  read cross-layer chunks (Fig 9),
//!     exec qkv / attn / o / gu / down   dequantize *into the part slab*
//!     ...layers l0+1..l0+N-1...
//!   group G+1: wait(part) — usually already complete → near-zero stall
//!
//! Per-part completion signalling lets the engine start consuming Wq/Wk/Wv
//! of the next group while its Wd part is still streaming.
//!
//! **Async I/O.** The loader does not read synchronously: every coalesced
//! chunk run of every part of a batch is *planned* first, then submitted
//! to the shared [`ReadQueue`] in one atomic group, and only then reaped —
//! so the runs of one part, and across sibling parts of one
//! `PreloadBatch`, are in flight together and share device waves (one
//! fixed latency per queue-depth's worth of reads instead of one per
//! chunk). Dequantization into slab rows happens as completions land.
//!
//! **Slab store.** Each `(seq, op)` part is one contiguous `Vec<f32>` slab
//! laid out `[channel-major][layer][d_out]` plus a small index (sorted
//! channel list + per-row fill bitmap) — no per-row heap allocations. The
//! loader dequantizes flash chunks directly into their final slab slots;
//! the engine clones an `Arc<PartSlab>` out of the store (one map lock per
//! part) and then borrows row slices lock-free. LLM-in-a-flash-style
//! bundling (arXiv 2312.11514): rows land in their packed layout in place.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::flash::{FlashDevice, IoClass, ReadQueue};
use crate::layout::{quant, AwgfFile, OpKind};
use crate::trace::{SpanCtx, SpanEvent, SpanKind, TraceHandle, TID_LOADER};

/// Key of a preload part: (monotonic group sequence number, op family).
pub type PartKey = (u64, OpKind);

/// One on-flash layout-group partition of a part request: the channels to
/// load for `layers[lo..hi]` of the owning batch. The **issuer** filters
/// cache-resident channels *per partition* (engine: one brief
/// containment-only lock per site) — a channel resident for every layer
/// of one partition but missing somewhere in another is read only where
/// it is actually needed. The loader itself never touches the weight
/// cache, which is what makes the engine's wait-under-guard fetch path
/// safe (PERF.md).
#[derive(Debug, Clone)]
pub struct PartSpan {
    /// Start index (inclusive) into the batch's `layers`.
    pub lo: usize,
    /// End index (exclusive) into the batch's `layers`.
    pub hi: usize,
    /// Filtered channels to load for this partition's layers.
    pub channels: Arc<[usize]>,
}

/// One preload part: fetch one op family's spans for the batch's layer
/// group. `skipped_cached` carries the filtered row count so
/// `LoaderStats` keeps its historical meaning.
#[derive(Debug, Clone)]
pub struct PartRequest {
    pub op: OpKind,
    pub spans: Vec<PartSpan>,
    pub skipped_cached: u64,
}

/// One preload **batch**: every op part of one activation site for the
/// upcoming runtime layer group `seq`, delivered to the loader as a
/// single channel message (formerly one send per op — 3 for Wq/Wk/Wv).
/// Sibling parts whose filtered span lists coincide share the same
/// channel `Arc`s — no per-op `Vec` copies.
pub struct PreloadBatch {
    pub seq: u64,
    /// The runtime group's layers, shared by every part.
    pub layers: Arc<[usize]>,
    pub parts: Vec<PartRequest>,
    /// Causal context of the decode step that requested the preload:
    /// carried into the flash submission and onto the batch's
    /// `preload_part` spans so the trace attributes loader I/O to the
    /// request that pays for it. [`SpanCtx::NONE`] for untracked work.
    pub ctx: SpanCtx,
}

impl PreloadBatch {
    /// Single-part convenience (tests, hand-built requests): one op, one
    /// span covering the whole group.
    pub fn single(
        seq: u64,
        layers: Arc<[usize]>,
        op: OpKind,
        channels: Arc<[usize]>,
        skipped_cached: u64,
    ) -> PreloadBatch {
        let hi = layers.len();
        PreloadBatch {
            seq,
            layers,
            parts: vec![PartRequest {
                op,
                spans: vec![PartSpan {
                    lo: 0,
                    hi,
                    channels,
                }],
                skipped_cached,
            }],
            ctx: SpanCtx::NONE,
        }
    }
}

enum Msg {
    Batch(PreloadBatch),
    Stop,
}

/// Contiguous dequantized rows of one preload part, laid out
/// `[channel-major][layer][d_out]`:
///
/// ```text
/// data = [ ch[0]·layer[0]·f32[d_out] | ch[0]·layer[1]·… | ch[1]·layer[0]·… ]
/// ```
///
/// The index is the sorted `channels` list (binary-searched) plus a fill
/// bitmap — rows the loader never wrote (channel not in the job's
/// pre-filtered list, or a failed read) stay unfilled and `row()` returns
/// `None` for them, which sends the engine down its on-demand path exactly
/// like a store miss did under the old per-row `HashMap`.
///
/// When a straddling runtime group's partitions filter to *different*
/// channel lists, the slab is built from per-span **sub-slabs** (`segs`)
/// instead of one union layout: each span gets exactly its own
/// `channels × layers[lo..hi]` rows, packed back to back. The old union
/// allocation materialized every (union channel, layer) row and left the
/// out-of-span ones permanently unfilled — pure DRAM waste the governor
/// ledger still had to carry (`LoaderStats::subslab_waste_bytes` counts
/// what the split saves). `segs` empty = classic single-segment union
/// layout (single-span parts, identical lists — the common case).
pub struct PartSlab {
    pub op: OpKind,
    layers: Arc<[usize]>,
    channels: Vec<usize>,
    segs: Vec<SlabSeg>,
    d_out: usize,
    filled: Vec<bool>,
    data: Vec<f32>,
}

/// One per-span sub-slab of a split [`PartSlab`]: rows for
/// `channels × layers[lo..hi]`, channel-major, starting at row `base`.
struct SlabSeg {
    lo: usize,
    hi: usize,
    channels: Vec<usize>,
    base: usize,
}

impl PartSlab {
    pub fn new(
        op: OpKind,
        layers: Arc<[usize]>,
        channels: &[usize],
        d_out: usize,
    ) -> PartSlab {
        let mut channels = channels.to_vec();
        channels.sort_unstable();
        channels.dedup();
        Self::from_sorted(op, layers, channels, d_out)
    }

    /// Construct from an already sorted + deduplicated channel list,
    /// taking ownership — the loader path normalizes the union once for
    /// its cap pre-check and must not pay a second sort/dedup/copy here.
    pub fn from_sorted(
        op: OpKind,
        layers: Arc<[usize]>,
        channels: Vec<usize>,
        d_out: usize,
    ) -> PartSlab {
        debug_assert!(channels.windows(2).all(|w| w[0] < w[1]));
        let rows = channels.len() * layers.len();
        PartSlab {
            op,
            layers,
            channels,
            segs: Vec::new(),
            d_out,
            filled: vec![false; rows],
            data: vec![0f32; rows * d_out],
        }
    }

    /// Lay out per-span sub-slabs: one row block per span, packed back to
    /// back. `span_chs[i]` is span i's sorted + deduplicated channel
    /// list; spans are clamped to the layer range so a malformed
    /// hand-built span degrades to empty rather than panicking.
    fn build_segs(
        layers_len: usize,
        spans: &[PartSpan],
        span_chs: Vec<Vec<usize>>,
    ) -> (Vec<SlabSeg>, usize) {
        let mut segs = Vec::with_capacity(spans.len());
        let mut rows = 0usize;
        for (span, chs) in spans.iter().zip(span_chs) {
            let hi = span.hi.min(layers_len);
            let lo = span.lo.min(hi);
            let n = chs.len() * (hi - lo);
            segs.push(SlabSeg {
                lo,
                hi,
                channels: chs,
                base: rows,
            });
            rows += n;
        }
        (segs, rows)
    }

    /// Construct a **split** slab: one sub-slab per span (see `segs` on
    /// the struct doc). `union` stays the public `channels()` index;
    /// `span_chs` must be sorted + deduplicated per span.
    pub fn from_spans(
        op: OpKind,
        layers: Arc<[usize]>,
        spans: &[PartSpan],
        span_chs: Vec<Vec<usize>>,
        union: Vec<usize>,
        d_out: usize,
    ) -> PartSlab {
        let (segs, rows) = Self::build_segs(layers.len(), spans, span_chs);
        PartSlab {
            op,
            layers,
            channels: union,
            segs,
            d_out,
            filled: vec![false; rows],
            data: vec![0f32; rows * d_out],
        }
    }

    fn slot(&self, layer: usize, channel: usize) -> Option<usize> {
        let li = self.layers.iter().position(|&l| l == layer)?;
        if self.segs.is_empty() {
            let ci = self.channels.binary_search(&channel).ok()?;
            return Some(ci * self.layers.len() + li);
        }
        for seg in &self.segs {
            if li >= seg.lo && li < seg.hi {
                if let Ok(ci) = seg.channels.binary_search(&channel) {
                    return Some(
                        seg.base + ci * (seg.hi - seg.lo) + (li - seg.lo),
                    );
                }
            }
        }
        None
    }

    /// Borrow one dequantized row (engine consumption, lock-free through
    /// the part's `Arc`). `None` until the loader has filled that row.
    pub fn row(&self, layer: usize, channel: usize) -> Option<&[f32]> {
        let s = self.slot(layer, channel)?;
        if !self.filled[s] {
            return None;
        }
        Some(&self.data[s * self.d_out..(s + 1) * self.d_out])
    }

    /// Mutable row slot for the loader's in-place dequantization; marks the
    /// row filled.
    pub fn row_mut(&mut self, layer: usize, channel: usize) -> Option<&mut [f32]> {
        let s = self.slot(layer, channel)?;
        self.filled[s] = true;
        Some(&mut self.data[s * self.d_out..(s + 1) * self.d_out])
    }

    pub fn layers(&self) -> &[usize] {
        &self.layers
    }

    /// Sorted, deduplicated channel index of the slab.
    pub fn channels(&self) -> &[usize] {
        &self.channels
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Slab payload bytes (the live M_cl component of this part — the
    /// row count the admission reservation was priced from).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Real allocation size (≥ `bytes()` after a shrinking `reset`) —
    /// what a parked slab in the reuse pool actually costs.
    pub fn capacity_bytes(&self) -> u64 {
        (self.data.capacity() * 4) as u64
    }

    /// Re-arm a retired slab for a new request of the **same op** (same
    /// `d_out` by construction), reusing its data and fill-bitmap
    /// allocations — the loader's steady-state slab traffic stops
    /// allocating once the reuse pool warms up (ROADMAP "slab reuse
    /// pool"). `channels` must arrive sorted + deduplicated, like
    /// [`PartSlab::from_sorted`]'s.
    pub fn reset(&mut self, layers: Arc<[usize]>, channels: Vec<usize>) {
        debug_assert!(channels.windows(2).all(|w| w[0] < w[1]));
        let rows = channels.len() * layers.len();
        self.layers = layers;
        self.channels = channels;
        self.segs.clear();
        self.rearm(rows);
    }

    /// [`PartSlab::reset`] for a **split** request: re-arms the retired
    /// slab with per-span sub-slabs instead of the union layout.
    pub fn reset_spans(
        &mut self,
        layers: Arc<[usize]>,
        spans: &[PartSpan],
        span_chs: Vec<Vec<usize>>,
        union: Vec<usize>,
    ) {
        let (segs, rows) = Self::build_segs(layers.len(), spans, span_chs);
        self.layers = layers;
        self.channels = union;
        self.segs = segs;
        self.rearm(rows);
    }

    fn rearm(&mut self, rows: usize) {
        self.filled.clear();
        self.filled.resize(rows, false);
        self.data.clear();
        self.data.resize(rows * self.d_out, 0.0);
        // drop capacity slack from a larger previous life: the live
        // reservation (and the M_cl ledger) price this slab at its row
        // count, so retained extra capacity would be unaccounted DRAM.
        // Same-shape recycling — the steady state — never shrinks.
        self.data.shrink_to(rows * self.d_out);
        self.filled.shrink_to(rows);
    }
}

/// Reuse-pool bound: retired slabs past it are simply freed (the pool
/// must cap steady-state memory, not become a second store).
const SLAB_POOL_CAP: usize = 16;

/// Retired-group bookkeeping. Groups used to retire strictly in seq order,
/// so a single high-water mark sufficed; interleaved sequences retire out
/// of order (sequence A's cross-token chain outlives groups B allocated
/// and retired after it), so retirement is **exact** now: a retired seq
/// above the floor parks in `above` until every seq below it has retired
/// too, then the floor compacts over the contiguous prefix. The floor
/// keeps `above` bounded as long as every allocated seq is eventually
/// retired — which the engine guarantees on every path, including decode
/// errors (`step` retires its allocations on the error path) and sequence
/// teardown (`end_seq` retires the pending cross-token chain).
#[derive(Default)]
struct RetiredState {
    /// Every seq ≤ floor is retired.
    floor: u64,
    /// Retired seqs above the floor (awaiting compaction).
    above: std::collections::BTreeSet<u64>,
}

impl RetiredState {
    /// Idempotent: retiring an already-retired seq is a no-op.
    fn retire(&mut self, seq: u64) {
        if seq > self.floor {
            self.above.insert(seq);
        }
        while self.above.remove(&(self.floor + 1)) {
            self.floor += 1;
        }
    }

    fn is_retired(&self, seq: u64) -> bool {
        seq <= self.floor || self.above.contains(&seq)
    }
}

struct SharedState {
    /// Completed parts. A part appears here only once fully loaded.
    slabs: Mutex<HashMap<PartKey, Arc<PartSlab>>>,
    done: Mutex<std::collections::HashSet<PartKey>>,
    /// Exactly-retired groups (floor + out-of-order set). A slab finishing
    /// after its group was retired is dropped instead of published — the
    /// engine has already moved on and nothing would ever free it.
    retired: Mutex<RetiredState>,
    /// Governor's preload-pool ceiling (bytes). A part whose (pre-I/O
    /// computable) slab size would push the live slab bytes past it is
    /// dropped before any flash read — still marked done, so the engine
    /// falls back to on-demand. `u64::MAX` = unthrottled.
    slab_cap: AtomicU64,
    /// Loader-side statistics.
    stats: Mutex<LoaderStats>,
    /// Retired `PartSlab`s awaiting reuse (sole-owner slabs reclaimed by
    /// `retire_group` and the loader's own drop paths). Keyed by nothing:
    /// the loader searches for a same-op entry and `reset`s it. Locked
    /// standalone — never while another pipeline lock is held.
    slab_pool: Mutex<Vec<PartSlab>>,
}

impl Default for SharedState {
    fn default() -> SharedState {
        SharedState {
            slabs: Mutex::new(HashMap::new()),
            done: Mutex::new(std::collections::HashSet::new()),
            retired: Mutex::new(RetiredState::default()),
            slab_cap: AtomicU64::new(u64::MAX),
            stats: Mutex::new(LoaderStats::default()),
            slab_pool: Mutex::new(Vec::new()),
        }
    }
}

impl SharedState {
    /// Offer a retired slab to the reuse pool. Pooled bytes are REAL
    /// DRAM, so they are (a) accounted in `LoaderStats::slab_pool_bytes`
    /// (and through it in the ledger's M_cl via `stored_bytes`) and (b)
    /// admitted only while live + pooled + incoming bytes fit the
    /// governor's slab cap — the pool lives in the cap's slack, never
    /// past it. Bounded by count too; overflow simply drops the slab.
    /// (Lock order here and in the loader's take path: stats →
    /// slab_pool.)
    fn pool_slab(&self, slab: PartSlab) {
        let cap = self.slab_cap.load(Ordering::Relaxed);
        let bytes = slab.capacity_bytes();
        let mut st = self.stats.lock().unwrap();
        if st.slab_bytes
            .saturating_add(st.slab_pool_bytes)
            .saturating_add(bytes)
            > cap
        {
            return;
        }
        let mut pool = self.slab_pool.lock().unwrap();
        if pool.len() < SLAB_POOL_CAP {
            st.slab_pool_bytes += bytes;
            pool.push(slab);
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct LoaderStats {
    pub chunks_read: u64,
    pub bytes_read: u64,
    pub channels_loaded: u64,
    pub channels_skipped_cached: u64,
    /// Bytes held by live part slabs, **including** reservations for parts
    /// admitted under the cap but still loading — reserving at admission
    /// is what keeps concurrently loading parts from jointly overshooting
    /// the governor's ceiling.
    pub slab_bytes: u64,
    /// High-water mark of `slab_bytes` (M_cl peak, loader view).
    pub slab_bytes_peak: u64,
    /// Loader messages received (one per site batch — the batched send
    /// path delivers all sibling ops of a site in one message).
    pub batch_msgs: u64,
    /// Parts loaded **and published** (one per op of each batch); a part
    /// dropped for budget or retirement does not count.
    pub parts_loaded: u64,
    /// Parts dropped unpublished because the slab store hit the
    /// governor's byte ceiling; their waiters fell back to on-demand.
    pub slabs_dropped_budget: u64,
    /// Parts whose slab came from the reuse pool (a retired same-op slab
    /// `reset` in place) instead of a fresh allocation.
    pub slabs_recycled: u64,
    /// Bytes parked in the slab reuse pool — real DRAM the ledger's M_cl
    /// term must see (`stored_bytes` adds it to the live slabs) and the
    /// slab-cap admission counts against the ceiling.
    pub slab_pool_bytes: u64,
    /// Parts whose flash reads (or request planning) failed: no slab was
    /// published, waiters fell back to on-demand. Surfaced by the server
    /// as `parts_failed` so loader trouble is visible beyond stderr.
    pub parts_failed: u64,
    /// Rows dequantized into slabs through the vectorized block kernels
    /// (`layout::quant::dequantize_row`). The engine delta-folds this
    /// into `DecodeMetrics::dequant_rows_vectorized` alongside its own
    /// on-demand rows.
    pub rows_dequantized: u64,
    /// Union-allocation bytes the per-span sub-slab split avoided:
    /// admitted parts whose span channel lists diverge allocate exactly
    /// `Σ span_channels × span_layers` rows instead of
    /// `union_channels × all_layers`. Delta-folded into
    /// `DecodeMetrics::subslab_waste_bytes`.
    pub subslab_waste_bytes: u64,
    /// Modeled flash busy time.
    pub busy: Duration,
}

/// Handle owned by the engine.
pub struct Pipeline {
    tx: Sender<Msg>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>, // bumped on every completion
    handle: Option<JoinHandle<()>>,
}

impl Pipeline {
    /// Spawn with a private read queue at the device's default depth
    /// (tests, standalone use). The engine shares one queue between the
    /// loader and its on-demand path via [`Pipeline::spawn_with_queue`].
    pub fn spawn(awgf: Arc<AwgfFile>, flash: Arc<FlashDevice>) -> Pipeline {
        let queue = ReadQueue::new(flash, 0);
        Pipeline::spawn_with_queue(awgf, queue)
    }

    pub fn spawn_with_queue(
        awgf: Arc<AwgfFile>,
        queue: Arc<ReadQueue>,
    ) -> Pipeline {
        Pipeline::spawn_with_queue_traced(awgf, queue, None)
    }

    /// [`Pipeline::spawn_with_queue`] with a flight recorder attached:
    /// the loader records one [`SpanKind::PreloadPart`] span per part
    /// (batch receipt → slab publish) while tracing is enabled.
    pub fn spawn_with_queue_traced(
        awgf: Arc<AwgfFile>,
        queue: Arc<ReadQueue>,
        trace: Option<TraceHandle>,
    ) -> Pipeline {
        let (tx, rx) = channel();
        let shared = Arc::new(SharedState::default());
        let cv = Arc::new(Condvar::new());
        let cv_guard = Arc::new(Mutex::new(0u64));
        let worker = LoaderWorker {
            awgf,
            queue,
            shared: shared.clone(),
            cv: cv.clone(),
            cv_guard: cv_guard.clone(),
            trace,
        };
        let handle = std::thread::Builder::new()
            .name("awf-loader".into())
            .spawn(move || worker.run(rx))
            .expect("spawn loader thread");
        Pipeline {
            tx,
            shared,
            cv,
            cv_guard,
            handle: Some(handle),
        }
    }

    /// Enqueue a preload batch (non-blocking — the submit side of
    /// io_uring). One message covers every op part of the site.
    pub fn request(&self, batch: PreloadBatch) {
        let _ = self.tx.send(Msg::Batch(batch));
    }

    /// Set the preload slab-store byte ceiling (runtime DRAM governor).
    /// Takes effect for the next part the loader handles.
    pub fn set_slab_cap(&self, bytes: u64) {
        self.shared.slab_cap.store(bytes.max(1), Ordering::Relaxed);
    }

    pub fn slab_cap(&self) -> u64 {
        self.shared.slab_cap.load(Ordering::Relaxed)
    }

    /// Block until part `(seq, op)` has been fully loaded. Returns false on
    /// timeout (loader wedged/dead) — the engine then falls back to
    /// on-demand loading instead of hanging the decode.
    pub fn wait_part(&self, key: PartKey) -> bool {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let mut gen = self.cv_guard.lock().unwrap();
        loop {
            if self.shared.done.lock().unwrap().contains(&key) {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                eprintln!("[pipeline] wait_part timeout on {key:?}");
                return false;
            }
            let (g, _timeout) = self
                .cv
                .wait_timeout(gen, deadline - now)
                .unwrap();
            gen = g;
        }
    }

    pub fn part_ready(&self, key: PartKey) -> bool {
        self.shared.done.lock().unwrap().contains(&key)
    }

    /// Clone the completed part's slab out of the store — one map lock,
    /// after which the engine reads rows without any synchronization.
    pub fn part(&self, key: PartKey) -> Option<Arc<PartSlab>> {
        self.shared.slabs.lock().unwrap().get(&key).cloned()
    }

    /// Drop a fully consumed group's slabs + completion marks (frees
    /// M_cl). Retirement is **exact**: only this seq is dropped, so an
    /// interleaved sequence's outstanding chain (a *lower* seq consumed
    /// *later*) survives other sequences retiring newer groups around it.
    /// Holding the `retired` guard across the removals excludes the
    /// loader's publish: a part finishing after this point sees its seq
    /// retired and is dropped, never leaked. Idempotent.
    pub fn retire_group(&self, seq: u64) {
        let mut retired = self.shared.retired.lock().unwrap();
        retired.retire(seq);
        let mut freed = 0u64;
        let mut reclaimed: Vec<Arc<PartSlab>> = Vec::new();
        {
            let mut slabs = self.shared.slabs.lock().unwrap();
            slabs.retain(|(s, _), slab| {
                if retired.is_retired(*s) {
                    freed += slab.bytes();
                    reclaimed.push(slab.clone());
                    false
                } else {
                    true
                }
            });
        }
        if freed > 0 {
            let mut st = self.shared.stats.lock().unwrap();
            st.slab_bytes = st.slab_bytes.saturating_sub(freed);
        }
        self.shared
            .done
            .lock()
            .unwrap()
            .retain(|(s, _)| !retired.is_retired(*s));
        drop(retired);
        // slabs nobody else still borrows go to the reuse pool — the
        // loader resets them for later same-op parts instead of
        // allocating (an engine still holding a fetch-time Arc clone
        // just means this one is freed normally)
        for arc in reclaimed {
            if let Ok(slab) = Arc::try_unwrap(arc) {
                self.shared.pool_slab(slab);
            }
        }
    }

    /// Bytes currently held in preload slabs — live published parts PLUS
    /// the reuse pool's parked slabs (the full M_cl the ledger must see:
    /// pooled allocations are real DRAM even though no part owns them).
    pub fn stored_bytes(&self) -> u64 {
        let live: u64 = {
            let slabs = self.shared.slabs.lock().unwrap();
            slabs.values().map(|s| s.bytes()).sum()
        };
        live + self.shared.stats.lock().unwrap().slab_pool_bytes
    }

    pub fn loader_stats(&self) -> LoaderStats {
        self.shared.stats.lock().unwrap().clone()
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct LoaderWorker {
    awgf: Arc<AwgfFile>,
    queue: Arc<ReadQueue>,
    shared: Arc<SharedState>,
    cv: Arc<Condvar>,
    cv_guard: Arc<Mutex<u64>>,
    /// Flight recorder (preload-part spans); `None` when untraced.
    trace: Option<TraceHandle>,
}

/// One planned chunk read of a part: the reap tag plus everything needed
/// to scatter the returned bytes into their slab rows.
struct PlannedRun {
    tag: u64,
    start_ch: usize,
    n_ch: usize,
    /// Byte stride between consecutive channels' sub-spans in the buffer.
    stride: usize,
    rb: usize,
    /// Total bytes of this run's read (for completion-time accounting).
    total: usize,
    /// `(layer, byte offset of that layer's row within one channel's
    /// sub-span)` for every layer this run covers.
    layer_offs: Vec<(usize, usize)>,
}

/// Outcome of planning one part, before its reads complete.
enum PartPlan {
    /// Over the governor ceiling — dropped before any I/O was staged.
    Throttled,
    /// Planning failed (malformed request); nothing was submitted.
    Failed(anyhow::Error),
    /// Reads submitted; `reserved` bytes are already counted against
    /// `slab_bytes` (released on every path that does not publish).
    Loading {
        slab: PartSlab,
        reserved: u64,
        runs: Vec<PlannedRun>,
    },
}

impl LoaderWorker {
    fn run(self, rx: Receiver<Msg>) {
        while let Ok(msg) = rx.recv() {
            match msg {
                Msg::Stop => break,
                Msg::Batch(batch) => self.handle_batch(batch),
            }
        }
    }

    /// Plan every part of the batch, submit ALL reads as one atomic group,
    /// then reap: chunk runs of one part — and across sibling parts — are
    /// in flight together, so the device amortizes its fixed latency
    /// across them instead of paying it once per chunk.
    fn handle_batch(&self, batch: PreloadBatch) {
        self.shared.stats.lock().unwrap().batch_msgs += 1;
        // flight recorder: each part's span runs batch receipt → its own
        // publish (enabled check only while tracing is off)
        let t0_us = self
            .trace
            .as_ref()
            .filter(|t| t.enabled())
            .map(|t| t.now_us());
        // phase 1: plan (cap admission + run layout); no I/O yet
        let mut reqs: Vec<(u64, usize)> = Vec::new();
        let mut plans: Vec<PartPlan> = batch
            .parts
            .iter()
            .map(|part| self.plan_part(&batch.layers, part, &mut reqs))
            .collect();
        // phase 2: one submission for the whole batch (tags in req
        // order), carrying the requesting step's causal context
        let tags = self.queue.submit_many_ctx(&reqs, batch.ctx);
        for plan in &mut plans {
            if let PartPlan::Loading { runs, .. } = plan {
                for run in runs {
                    run.tag = tags[run.tag as usize];
                }
            }
        }
        // phase 3: reap + dequantize + publish, part by part — a part is
        // published the moment its own runs are in, while later parts'
        // reads are still streaming
        for (part, plan) in batch.parts.iter().zip(plans) {
            self.complete_part(batch.seq, part.op, plan);
            if let (Some(t0), Some(trace)) = (t0_us, self.trace.as_ref()) {
                trace.push_one(SpanEvent {
                    kind: SpanKind::PreloadPart,
                    t0_us: t0,
                    dur_us: trace.now_us().saturating_sub(t0),
                    tid: TID_LOADER,
                    ctx: batch.ctx,
                    a: batch.seq,
                    b: part.op as u64,
                });
            }
        }
    }

    /// Admission + run planning for one part. Stages the part's reads
    /// into `reqs` (tags are indices into it until `handle_batch` swaps
    /// in the real queue tags).
    fn plan_part(
        &self,
        layers: &Arc<[usize]>,
        part: &PartRequest,
        reqs: &mut Vec<(u64, usize)>,
    ) -> PartPlan {
        let cap = self.shared.slab_cap.load(Ordering::Relaxed);
        // The slab's size is fully determined before any I/O; a part that
        // would overflow the governor's ceiling is dropped *before*
        // reading flash — paying the reads and then discarding the slab
        // would make preload strictly worse than disabled under a tight
        // cap. The union is normalized once here and handed to the slab
        // allocation.
        let mut union: Vec<usize> = part
            .spans
            .iter()
            .flat_map(|s| s.channels.iter().copied())
            .collect();
        union.sort_unstable();
        union.dedup();
        // Per-span normalized lists. When a straddling group's partitions
        // filtered to different lists, the slab is split into per-span
        // sub-slabs sized exactly Σ span_channels × span_layers instead
        // of the union allocation — the avoided bytes are counted below.
        let span_chs: Vec<Vec<usize>> = part
            .spans
            .iter()
            .map(|s| {
                let mut c = s.channels.to_vec();
                c.sort_unstable();
                c.dedup();
                c
            })
            .collect();
        let diverged = span_chs.len() > 1
            && span_chs.windows(2).any(|w| w[0] != w[1]);
        let union_rows = union.len() * layers.len();
        let rows = if diverged {
            part.spans
                .iter()
                .zip(&span_chs)
                .map(|(s, c)| {
                    let hi = s.hi.min(layers.len());
                    c.len() * (hi - s.lo.min(hi))
                })
                .sum()
        } else {
            union_rows
        };
        let d_out = self.awgf.op(part.op).d_out;
        let prospective = (rows * d_out * 4) as u64;
        let waste_avoided = ((union_rows - rows) * d_out * 4) as u64;
        {
            // One guard covers the issuer skip accounting (channel lists
            // arrive pre-filtered), the throttle check, AND the byte
            // reservation: parts of a batch load concurrently now, so an
            // admitted part must reserve its bytes at check time — two
            // in-flight parts checking against unreserved `slab_bytes`
            // would both pass and jointly overshoot the ceiling. The
            // ceiling covers live + POOLED bytes; expendable pooled
            // slabs are evicted before real work is throttled.
            let mut st = self.shared.stats.lock().unwrap();
            st.channels_skipped_cached += part.skipped_cached;
            let mut held =
                st.slab_bytes.saturating_add(st.slab_pool_bytes);
            if held.saturating_add(prospective) > cap
                && st.slab_pool_bytes > 0
            {
                let mut pool = self.shared.slab_pool.lock().unwrap();
                pool.clear();
                st.slab_pool_bytes = 0;
                held = st.slab_bytes;
            }
            if held.saturating_add(prospective) > cap {
                return PartPlan::Throttled;
            }
            st.slab_bytes += prospective;
            st.slab_bytes_peak = st.slab_bytes_peak.max(st.slab_bytes);
            // counted at admission: this part WILL allocate `rows` rows
            // where the union layout would have allocated `union_rows`
            st.subslab_waste_bytes += waste_avoided;
        }
        match self.plan_runs(layers, part, union, span_chs, diverged) {
            Ok((slab, mut runs, part_reqs)) => {
                let base = reqs.len() as u64;
                for run in &mut runs {
                    run.tag += base;
                }
                reqs.extend(part_reqs);
                PartPlan::Loading {
                    slab,
                    reserved: prospective,
                    runs,
                }
            }
            Err(e) => {
                // nothing was staged for a failed plan — release the
                // reservation immediately
                let mut st = self.shared.stats.lock().unwrap();
                st.slab_bytes = st.slab_bytes.saturating_sub(prospective);
                PartPlan::Failed(e)
            }
        }
    }

    /// Pure planning: allocate the part's slab and lay out its coalesced
    /// chunk runs. Returns the staged read list alongside (tags are local
    /// indices into it); nothing touches the device here.
    #[allow(clippy::type_complexity)]
    fn plan_runs(
        &self,
        layers: &Arc<[usize]>,
        part: &PartRequest,
        union: Vec<usize>,
        span_chs: Vec<Vec<usize>>,
        diverged: bool,
    ) -> Result<(PartSlab, Vec<PlannedRun>, Vec<(u64, usize)>)> {
        let info = self.awgf.op(part.op);
        let dout = info.d_out;
        let rb = info.row_bytes;

        // The part's slab, allocated once; every completion dequantizes
        // straight into its final slot (no per-row scratch, no per-row
        // Vec). A (layer, channel) row outside its layer's span stays
        // unfilled — the engine finds those channels in the cache (that
        // is why they were filtered). When span channel lists diverge
        // (straddling group AND residency differing per partition) the
        // slab is **split** into per-span sub-slabs sized exactly to
        // their own channels × layers — the union layout would have
        // carried the cross-partition rows as permanently unfilled DRAM
        // (`LoaderStats::subslab_waste_bytes`). Single-span or identical
        // lists keep the classic union layout.
        //
        // A retired same-op slab from the reuse pool is reset in place
        // when one is available — steady-state preload traffic cycles
        // the same buffers instead of allocating per part.
        let recycled = {
            // stats → slab_pool, like pool_slab: the take moves the
            // slab's bytes from the pool's account to the part's live
            // reservation (already made at admission) atomically
            let mut st = self.shared.stats.lock().unwrap();
            let mut pool = self.shared.slab_pool.lock().unwrap();
            match pool.iter().position(|s| s.op == part.op) {
                Some(i) => {
                    let s = pool.swap_remove(i);
                    st.slab_pool_bytes = st
                        .slab_pool_bytes
                        .saturating_sub(s.capacity_bytes());
                    st.slabs_recycled += 1;
                    Some(s)
                }
                None => None,
            }
        };
        let slab = match (recycled, diverged) {
            (Some(mut s), false) => {
                s.reset(layers.clone(), union);
                s
            }
            (Some(mut s), true) => {
                s.reset_spans(
                    layers.clone(),
                    &part.spans,
                    span_chs.clone(),
                    union,
                );
                s
            }
            (None, false) => {
                PartSlab::from_sorted(part.op, layers.clone(), union, dout)
            }
            (None, true) => PartSlab::from_spans(
                part.op,
                layers.clone(),
                &part.spans,
                span_chs.clone(),
                union,
                dout,
            ),
        };
        let mut runs: Vec<PlannedRun> = Vec::new();
        let mut reqs: Vec<(u64, usize)> = Vec::new();

        for (span, chs) in part.spans.iter().zip(&span_chs) {
            let hi = span.hi.min(layers.len());
            let span_layers = &layers[span.lo.min(hi)..hi];
            if span_layers.is_empty() || chs.is_empty() {
                continue;
            }

            // Partition by on-flash layout group; within a layout group
            // the requested layers occupy consecutive row slots of every
            // chunk, so each (layout-group, channel) is one contiguous
            // sub-span read. (An engine-built span is exactly one layout
            // group; stay robust to hand-built requests.)
            let mut by_group: Vec<(usize, Vec<usize>)> = Vec::new();
            for &l in span_layers {
                let g = info
                    .groups
                    .iter()
                    .position(|grp| grp.layers.contains(&l))
                    .ok_or_else(|| {
                        anyhow::anyhow!("layer {l} not in layout")
                    })?;
                match by_group.last_mut() {
                    Some((gg, ls)) if *gg == g => ls.push(l),
                    _ => by_group.push((g, vec![l])),
                }
            }

            for (g, glayers) in by_group {
                let grp = &info.groups[g];
                let j_of =
                    |l: usize| grp.layers.iter().position(|&x| x == l).unwrap();
                let j_min = glayers.iter().map(|&l| j_of(l)).min().unwrap();
                let j_max = glayers.iter().map(|&l| j_of(l)).max().unwrap();
                let sub = (j_max - j_min + 1) * rb;
                let full_chunk = sub == grp.layers.len() * rb;
                let layer_offs: Vec<(usize, usize)> = glayers
                    .iter()
                    .map(|&l| (l, (j_of(l) - j_min) * rb))
                    .collect();

                // Coalesce adjacent channels into single I/Os — only
                // valid when the sub-span is the whole chunk (otherwise
                // reads have gaps).
                let mut ch_runs: Vec<(usize, usize)> = Vec::new();
                for &ch in chs {
                    match ch_runs.last_mut() {
                        Some((s, l)) if full_chunk && *s + *l == ch => {
                            *l += 1
                        }
                        _ => ch_runs.push((ch, 1)),
                    }
                }

                for (start_ch, len) in ch_runs {
                    let (chunk_off, chunk_len) =
                        self.awgf.chunk_span(part.op, g, start_ch);
                    let (off, stride) = if full_chunk {
                        (chunk_off, chunk_len)
                    } else {
                        (chunk_off + (j_min * rb) as u64, sub)
                    };
                    let total =
                        if full_chunk { chunk_len * len } else { sub };
                    runs.push(PlannedRun {
                        tag: reqs.len() as u64,
                        start_ch,
                        n_ch: len,
                        stride,
                        rb,
                        total,
                        layer_offs: layer_offs.clone(),
                    });
                    reqs.push((off, total));
                }
            }
        }

        Ok((slab, runs, reqs))
    }

    /// Reap one part's completions, dequantize into its slab, publish,
    /// and signal — also on the throttled/failed/retired paths, so a
    /// racing `wait_part` re-checks instead of sleeping on.
    fn complete_part(&self, seq: u64, op: OpKind, plan: PartPlan) {
        match plan {
            PartPlan::Throttled => {
                // pressure valve: waiters fall back to on-demand loading
                let retired = self.shared.retired.lock().unwrap();
                if !retired.is_retired(seq) {
                    self.shared.stats.lock().unwrap().slabs_dropped_budget +=
                        1;
                    self.shared.done.lock().unwrap().insert((seq, op));
                }
            }
            PartPlan::Failed(e) => {
                eprintln!("[loader] preload failed: {e:#}");
                let retired = self.shared.retired.lock().unwrap();
                self.shared.stats.lock().unwrap().parts_failed += 1;
                if !retired.is_retired(seq) {
                    self.shared.done.lock().unwrap().insert((seq, op));
                }
            }
            PartPlan::Loading {
                mut slab,
                reserved,
                runs,
            } => {
                let quant = self.awgf.quant;
                let mut busy_ns = 0u64;
                let mut chunks = 0u64;
                let mut bytes = 0u64;
                let mut channels = 0u64;
                let mut failed: Option<anyhow::Error> = None;
                for run in &runs {
                    // after a failure the rest of the part is useless:
                    // abandon the remaining tags (non-blocking — also
                    // cancels reads still pending) instead of draining
                    // them one timeout at a time
                    if failed.is_some() {
                        self.queue.abandon(run.tag);
                        continue;
                    }
                    match self.queue.wait_as(run.tag, IoClass::Loader) {
                        // typed IoError: transients were already retried
                        // inside the queue, so anything surfacing here
                        // (permanent, exhausted, wedged) fails the part —
                        // waiters fall back to on-demand loading
                        Err(e) => failed = Some(e.into()),
                        Ok(c) => {
                            // loaded-I/O accounting happens here, per
                            // landed read — a failed part must not count
                            // bytes that never reached a slab
                            busy_ns += c.modeled_ns;
                            chunks += 1;
                            bytes += run.total as u64;
                            channels +=
                                (run.n_ch * run.layer_offs.len()) as u64;
                            for ci in 0..run.n_ch {
                                let ch = run.start_ch + ci;
                                for &(layer, loff) in &run.layer_offs {
                                    let base = ci * run.stride + loff;
                                    let row = slab
                                        .row_mut(layer, ch)
                                        .expect("slab covers all span channels");
                                    quant::dequantize_row(
                                        &c.data[base..base + run.rb],
                                        quant,
                                        row,
                                    );
                                }
                            }
                            // fully consumed: the read buffer goes back
                            // to the queue's recycle pool
                            self.queue.recycle(c.data);
                        }
                    }
                }
                if chunks > 0 {
                    let mut st = self.shared.stats.lock().unwrap();
                    st.busy += Duration::from_nanos(busy_ns);
                    st.chunks_read += chunks;
                    st.bytes_read += bytes;
                    st.channels_loaded += channels;
                    // every landed (layer, channel) row went through the
                    // vectorized block-kernel dequant into its slab slot
                    st.rows_dequantized += channels;
                }
                // Publish + mark done under the `retired` guard: if the
                // engine retired this group while we were loading (its
                // fetch never needed to wait), the slab is dropped here
                // instead of leaking in the store forever. The bytes were
                // reserved at admission — publishing adds nothing, every
                // non-publish path releases. (Lock order everywhere:
                // retired → slabs → stats → done, same as retire_group.)
                // Unpublished slabs are sole-owned here, so they feed the
                // reuse pool directly.
                let mut slab_opt = Some(slab);
                let retired = self.shared.retired.lock().unwrap();
                match failed {
                    Some(e) => {
                        eprintln!("[loader] preload failed: {e:#}");
                        let mut st = self.shared.stats.lock().unwrap();
                        st.parts_failed += 1;
                        st.slab_bytes =
                            st.slab_bytes.saturating_sub(reserved);
                        if !retired.is_retired(seq) {
                            self.shared.done.lock().unwrap().insert((seq, op));
                        }
                    }
                    None if !retired.is_retired(seq) => {
                        self.shared.slabs.lock().unwrap().insert(
                            (seq, op),
                            Arc::new(slab_opt.take().expect("unpublished")),
                        );
                        self.shared.stats.lock().unwrap().parts_loaded += 1;
                        self.shared.done.lock().unwrap().insert((seq, op));
                    }
                    None => {
                        // group already retired: drop the late slab and
                        // give its reservation back
                        let mut st = self.shared.stats.lock().unwrap();
                        st.slab_bytes =
                            st.slab_bytes.saturating_sub(reserved);
                    }
                }
                drop(retired);
                if let Some(slab) = slab_opt {
                    self.shared.pool_slab(slab);
                }
            }
        }
        let mut gen = self.cv_guard.lock().unwrap();
        *gen += 1;
        drop(gen);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    // Pipeline tests need a real AWGF file; they live in
    // rust/tests/pipeline_integration.rs (built from artifacts/model.awgf)
    // and in the in-memory harness below using a synthetic file.
    use super::*;
    use crate::config::ModelConfig;
    use crate::device::PIXEL6;
    use crate::flash::ClockMode;
    use crate::layout::TensorId;
    use crate::util::prop::{check, GenExt};

    /// Build a tiny synthetic AWGF file on disk via the python-compatible
    /// writer logic (re-implemented in the test for independence). Two
    /// ops (wq 128→128, wk 128→64) across four layers in two layout
    /// groups `[0,1]` / `[2,3]` — enough to exercise multi-part batches
    /// and runtime groups that straddle layout groups.
    fn synth_awgf(dir: &std::path::Path) -> std::path::PathBuf {
        use crate::layout::quant::{quantize_row, Quant};
        let cfg = ModelConfig {
            n_layers: 4,
            ..ModelConfig::tiny()
        };
        let path = dir.join("synth.awgf");
        let mut payload: Vec<u8> = Vec::new();
        // dense: embed [vocab,d] zeros
        let embed_len = cfg.vocab_size * cfg.d_model * 4;
        let embed_off = payload.len();
        payload.extend(std::iter::repeat(0u8).take(embed_len));
        // wq: d_in=128 rows of d_out=128; rows encode (c*2+l) in elem 0
        let rb = crate::layout::row_bytes(Quant::Q8_0, cfg.d_model);
        let mut wq_offs = [0usize; 2];
        for (g, offs) in wq_offs.iter_mut().enumerate() {
            *offs = payload.len();
            for c in 0..cfg.d_model {
                for l in (g * 2)..(g * 2 + 2) {
                    let row: Vec<f32> = (0..cfg.d_model)
                        .map(|j| (c * 2 + l) as f32 + j as f32 * 1e-3)
                        .collect();
                    payload.extend(quantize_row(&row, Quant::Q8_0));
                }
            }
        }
        // wk: d_in=128 rows of d_out=64; rows encode (c*3+l) in elem 0
        let dk = 64usize;
        let rbk = crate::layout::row_bytes(Quant::Q8_0, dk);
        let mut wk_offs = [0usize; 2];
        for (g, offs) in wk_offs.iter_mut().enumerate() {
            *offs = payload.len();
            for c in 0..cfg.d_model {
                for l in (g * 2)..(g * 2 + 2) {
                    let row: Vec<f32> = (0..dk)
                        .map(|j| (c * 3 + l) as f32 + j as f32 * 1e-3)
                        .collect();
                    payload.extend(quantize_row(&row, Quant::Q8_0));
                }
            }
        }
        let hdr = format!(
            r#"{{"model":{{"name":"synth","vocab_size":{v},"d_model":{d},
"n_layers":4,"n_heads":4,"n_kv_heads":2,"head_dim":32,"d_ff":384,
"max_seq":16,"rope_theta":10000.0,"norm_eps":1e-5}},
"quant":"q8_0","group_size":2,
"dense":{{"embed":{{"offset":{eo},"len":{el},"shape":[{v},{d}]}}}},
"ops":{{"wq":{{"d_in":{d},"d_out":{d},"row_bytes":{rb},
"groups":[{{"layers":[0,1],"offset":{wo0}}},
{{"layers":[2,3],"offset":{wo1}}}]}},
"wk":{{"d_in":{d},"d_out":{dk},"row_bytes":{rbk},
"groups":[{{"layers":[0,1],"offset":{ko0}}},
{{"layers":[2,3],"offset":{ko1}}}]}}}}}}"#,
            v = cfg.vocab_size,
            d = cfg.d_model,
            dk = dk,
            eo = embed_off,
            el = embed_len,
            rb = rb,
            rbk = rbk,
            wo0 = wq_offs[0],
            wo1 = wq_offs[1],
            ko0 = wk_offs[0],
            ko1 = wk_offs[1],
        );
        let mut file = Vec::new();
        file.extend(b"AWGF");
        file.extend(1u32.to_le_bytes());
        file.extend((hdr.len() as u32).to_le_bytes());
        file.extend(hdr.as_bytes());
        while file.len() % 4096 != 0 {
            file.push(0);
        }
        file.extend(&payload);
        std::fs::write(&path, file).unwrap();
        path
    }

    fn setup() -> (Arc<AwgfFile>, Arc<FlashDevice>, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("awf_pipe_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = synth_awgf(&dir);
        let awgf = Arc::new(AwgfFile::open(&path).unwrap());
        let flash =
            FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 1.0).unwrap();
        (awgf, flash, path)
    }

    fn job(seq: u64, layers: &[usize], channels: &[usize]) -> PreloadBatch {
        PreloadBatch::single(
            seq,
            Arc::from(layers),
            OpKind::Wq,
            Arc::from(channels),
            0,
        )
    }

    #[test]
    fn preload_roundtrip_values_match_layout() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(1, &[0, 1], &[3, 4, 5, 100]));
        pipe.wait_part((1, OpKind::Wq));
        let slab = pipe.part((1, OpKind::Wq)).expect("slab published");
        for l in 0..2usize {
            for ch in [3usize, 4, 5, 100] {
                let row = slab
                    .row(l, ch)
                    .unwrap_or_else(|| panic!("missing row l{l} ch{ch}"));
                // synth rows encode (c*2+l) in element 0 (q8_0 tolerance)
                let want = (ch * 2 + l) as f32;
                assert!(
                    (row[0] - want).abs() <= want.abs() / 127.0 + 1e-2,
                    "l{l} ch{ch}: {} != {want}",
                    row[0]
                );
            }
        }
        // rows are borrowed, not consumed — a second read sees them too
        assert!(slab.row(0, 3).is_some());
        // unrequested channels are store misses
        assert!(slab.row(0, 7).is_none());
    }

    #[test]
    fn adjacent_channels_coalesce_into_one_chunk() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        let chs: Vec<usize> = (10..20).collect(); // one contiguous run
        pipe.request(job(7, &[0, 1], &chs));
        pipe.wait_part((7, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.chunks_read, 1, "10 adjacent channels = 1 I/O");
        assert_eq!(st.channels_loaded, 20);
        assert!(st.slab_bytes_peak > 0);
    }

    #[test]
    fn issuer_filtered_channels_stay_out_of_the_slab() {
        // The engine filters cache-resident channels *before* sending the
        // job (the loader never touches the cache — PERF.md): a job whose
        // channel list had ch42 filtered out must not load it, and the
        // skip count it carries lands in the historical stat.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(PreloadBatch::single(
            2,
            Arc::from(&[0usize, 1][..]),
            OpKind::Wq,
            Arc::from(&[41usize, 43][..]), // 42 filtered out
            2,                             // ch42 × 2 layers
        ));
        pipe.wait_part((2, OpKind::Wq));
        let st = pipe.loader_stats();
        assert_eq!(st.channels_skipped_cached, 2);
        assert_eq!(st.channels_loaded, 4); // 2 channels × 2 layers
        let slab = pipe.part((2, OpKind::Wq)).unwrap();
        assert!(slab.row(0, 42).is_none(), "filtered row stays unfilled");
        assert!(slab.row(0, 41).is_some());
        assert!(slab.row(1, 43).is_some());
    }

    #[test]
    fn one_message_carries_every_part_of_a_site() {
        // ROADMAP: the per-site sends are batched — sibling ops arrive in
        // ONE loader message but keep per-part slabs and completion marks.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        let layers: Arc<[usize]> = Arc::from(&[0usize, 1][..]);
        let chans: Arc<[usize]> = Arc::from(&[3usize, 9][..]);
        pipe.request(PreloadBatch {
            seq: 1,
            layers: layers.clone(),
            parts: vec![
                PartRequest {
                    op: OpKind::Wq,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
                PartRequest {
                    op: OpKind::Wk,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
            ],
            ctx: SpanCtx::NONE,
        });
        assert!(pipe.wait_part((1, OpKind::Wq)));
        assert!(pipe.wait_part((1, OpKind::Wk)));
        let st = pipe.loader_stats();
        assert_eq!(st.batch_msgs, 1, "both parts rode one message");
        assert_eq!(st.parts_loaded, 2);
        let wq = pipe.part((1, OpKind::Wq)).unwrap();
        let wk = pipe.part((1, OpKind::Wk)).unwrap();
        assert_eq!(wq.d_out(), 128);
        assert_eq!(wk.d_out(), 64);
        // synth encodes (c*2+l) in wq rows and (c*3+l) in wk rows
        let q = wq.row(1, 9).unwrap()[0];
        assert!((q - 19.0).abs() <= 19.0 / 127.0 + 1e-2, "wq {q}");
        let k = wk.row(1, 9).unwrap()[0];
        assert!((k - 28.0).abs() <= 28.0 / 127.0 + 1e-2, "wk {k}");
    }

    #[test]
    fn straddling_group_filters_each_partition_separately() {
        // A runtime group [1, 2] straddles the on-flash layout groups
        // [0,1] / [2,3]. Per-partition spans mean channel 5 (resident for
        // layer 2's partition, say) is read only for layer 1, and channel
        // 7 only for layer 2 — the old whole-group filter would have read
        // both channels for both layers.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        let layers: Arc<[usize]> = Arc::from(&[1usize, 2][..]);
        pipe.request(PreloadBatch {
            seq: 4,
            layers,
            parts: vec![PartRequest {
                op: OpKind::Wq,
                spans: vec![
                    PartSpan {
                        lo: 0,
                        hi: 1,
                        channels: Arc::from(&[5usize][..]),
                    },
                    PartSpan {
                        lo: 1,
                        hi: 2,
                        channels: Arc::from(&[7usize][..]),
                    },
                ],
                skipped_cached: 2, // ch7@layer1 + ch5@layer2 filtered
            }],
            ctx: SpanCtx::NONE,
        });
        assert!(pipe.wait_part((4, OpKind::Wq)));
        let st = pipe.loader_stats();
        assert_eq!(st.channels_loaded, 2, "one row per partition");
        assert_eq!(st.channels_skipped_cached, 2);
        let slab = pipe.part((4, OpKind::Wq)).unwrap();
        let r15 = slab.row(1, 5).expect("ch5 loaded for layer 1")[0];
        assert!((r15 - 11.0).abs() <= 11.0 / 127.0 + 1e-2, "got {r15}");
        let r27 = slab.row(2, 7).expect("ch7 loaded for layer 2")[0];
        assert!((r27 - 16.0).abs() <= 16.0 / 127.0 + 1e-2, "got {r27}");
        // the filtered (layer, channel) combinations stay store misses
        assert!(slab.row(2, 5).is_none(), "ch5 not read for layer 2");
        assert!(slab.row(1, 7).is_none(), "ch7 not read for layer 1");
        // diverged partitions allocate per-span sub-slabs: one row per
        // partition, not the 2ch × 2-layer union
        assert_eq!(slab.bytes(), (2 * 128 * 4) as u64);
        assert_eq!(st.subslab_waste_bytes, (2 * 128 * 4) as u64);
    }

    #[test]
    fn split_slab_is_bit_identical_and_counts_avoided_waste() {
        // Per-span sub-slabs must change ONLY the allocation: every
        // loaded row equals the per-row reference read+dequant exactly,
        // out-of-span rows stay misses, the avoided union bytes are
        // counted, and a retired split slab recycles like a union one.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf.clone(), flash.clone());
        let layers: Arc<[usize]> = Arc::from(&[1usize, 2][..]);
        let mk = |seq| PreloadBatch {
            seq,
            layers: layers.clone(),
            parts: vec![PartRequest {
                op: OpKind::Wq,
                spans: vec![
                    PartSpan {
                        lo: 0,
                        hi: 1,
                        channels: Arc::from(&[3usize, 4, 9][..]),
                    },
                    PartSpan {
                        lo: 1,
                        hi: 2,
                        channels: Arc::from(&[4usize, 7][..]),
                    },
                ],
                skipped_cached: 0,
            }],
            ctx: SpanCtx::NONE,
        };
        pipe.request(mk(1));
        assert!(pipe.wait_part((1, OpKind::Wq)));
        let slab = pipe.part((1, OpKind::Wq)).unwrap();
        // 3 + 2 rows allocated; the union layout held 4ch × 2 layers
        assert_eq!(slab.bytes(), (5 * 128 * 4) as u64);
        assert_eq!(
            pipe.loader_stats().subslab_waste_bytes,
            (3 * 128 * 4) as u64
        );
        for (l, chs) in [(1usize, &[3usize, 4, 9][..]), (2, &[4, 7][..])] {
            for &ch in chs {
                let (off, len) = awgf.row_span(OpKind::Wq, l, ch);
                let buf = flash.read(off, len).unwrap();
                let mut want = vec![0f32; 128];
                quant::dequantize_row(&buf, awgf.quant, &mut want);
                assert_eq!(
                    slab.row(l, ch).unwrap(),
                    want.as_slice(),
                    "split row l{l} ch{ch} must be bit-identical"
                );
            }
        }
        assert!(slab.row(1, 7).is_none() && slab.row(2, 3).is_none());
        drop(slab);
        pipe.retire_group(1);
        pipe.request(mk(2));
        assert!(pipe.wait_part((2, OpKind::Wq)));
        let st = pipe.loader_stats();
        assert_eq!(st.slabs_recycled, 1, "split slabs recycle too");
        assert_eq!(st.subslab_waste_bytes, (6 * 128 * 4) as u64);
        let slab2 = pipe.part((2, OpKind::Wq)).unwrap();
        assert!(slab2.row(2, 7).is_some() && slab2.row(1, 9).is_some());
        assert!(slab2.row(1, 7).is_none(), "reset clears old segments");
    }

    #[test]
    fn queued_runs_amortize_fixed_latency() {
        // The whole point of the async queue: the four non-adjacent
        // channel runs of this part are submitted together and share one
        // device wave, so the modeled flash busy time pays ONE fixed
        // latency — strictly below four sequential single reads.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf.clone(), flash.clone());
        pipe.request(job(1, &[0, 1], &[0, 2, 4, 6])); // 4 runs of 1
        assert!(pipe.wait_part((1, OpKind::Wq)));
        let st = pipe.loader_stats();
        assert_eq!(st.chunks_read, 4);
        let (_, chunk_len) = awgf.chunk_span(OpKind::Wq, 0, 0);
        let sequential = 4 * flash.model_read_ns(chunk_len as u64);
        assert!(
            (st.busy.as_nanos() as u64) < sequential,
            "queued busy {:?} !< sequential {}ns",
            st.busy,
            sequential
        );
        // values still land in the right rows
        let slab = pipe.part((1, OpKind::Wq)).unwrap();
        let r = slab.row(1, 4).unwrap()[0];
        assert!((r - 9.0).abs() <= 9.0 / 127.0 + 1e-2, "got {r}");
    }

    #[test]
    fn concurrent_parts_reserve_bytes_against_the_cap() {
        // Both parts of this batch are planned (and their reads put in
        // flight) before either publishes. Without reserving bytes at
        // admission both would pass the cap check and jointly overshoot
        // the governor's ceiling; with the reservation the second part is
        // throttled and the peak stays under the cap.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        let layers: Arc<[usize]> = Arc::from(&[0usize, 1][..]);
        let chans: Arc<[usize]> = Arc::from(&[3usize, 9][..]);
        // Wq slab: 2ch × 2 layers × 128 × 4 = 4096 B;
        // Wk slab: 2ch × 2 layers ×  64 × 4 = 2048 B — cap fits only one
        let cap = 5000u64;
        pipe.set_slab_cap(cap);
        pipe.request(PreloadBatch {
            seq: 1,
            layers: layers.clone(),
            parts: vec![
                PartRequest {
                    op: OpKind::Wq,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
                PartRequest {
                    op: OpKind::Wk,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
            ],
            ctx: SpanCtx::NONE,
        });
        assert!(pipe.wait_part((1, OpKind::Wq)));
        assert!(pipe.wait_part((1, OpKind::Wk)), "throttled part marks done");
        assert!(pipe.part((1, OpKind::Wq)).is_some(), "first part fits");
        assert!(pipe.part((1, OpKind::Wk)).is_none(), "second part dropped");
        let st = pipe.loader_stats();
        assert_eq!(st.slabs_dropped_budget, 1);
        assert_eq!(st.slab_bytes, 4096);
        assert!(
            st.slab_bytes_peak <= cap,
            "in-flight reservations overshot the cap: peak {} > {cap}",
            st.slab_bytes_peak
        );
    }

    #[test]
    fn failed_reads_count_parts_failed_and_release_reservation() {
        // Channel 100000 is far outside the weights file: the part's
        // reads fail at the device. The failure must be *visible* (the
        // old loader only eprintln'd), the reservation must come back,
        // and the done mark must still arrive so waiters fall back.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(1, &[0, 1], &[0, 100000]));
        assert!(pipe.wait_part((1, OpKind::Wq)), "done mark must arrive");
        assert!(pipe.part((1, OpKind::Wq)).is_none(), "no slab published");
        let st = pipe.loader_stats();
        assert_eq!(st.parts_failed, 1);
        assert_eq!(st.slab_bytes, 0, "reservation released on failure");
        assert_eq!(st.parts_loaded, 0);
        // the loader (and the shared queue) keep working afterwards
        pipe.request(job(2, &[0, 1], &[5]));
        assert!(pipe.wait_part((2, OpKind::Wq)));
        assert!(pipe.part((2, OpKind::Wq)).is_some());
        assert_eq!(pipe.loader_stats().parts_failed, 1);
    }

    #[test]
    fn slab_cap_drops_parts_but_still_marks_done() {
        // Governor pressure valve: past the slab-store ceiling the loader
        // publishes nothing (waiters fall back to on-demand) but the
        // completion mark must still arrive — a wedged wait would hang
        // the decode.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf.clone(), flash.clone());
        pipe.set_slab_cap(1); // nothing fits
        pipe.request(job(1, &[0, 1], &[2, 3]));
        assert!(pipe.wait_part((1, OpKind::Wq)), "done mark must arrive");
        assert!(pipe.part((1, OpKind::Wq)).is_none(), "slab dropped");
        assert_eq!(pipe.stored_bytes(), 0);
        let st = pipe.loader_stats();
        assert!(st.slabs_dropped_budget >= 1);
        assert_eq!(st.parts_loaded, 0,
                   "a budget-dropped part must not count as loaded");
        assert_eq!(st.chunks_read, 0,
                   "over-cap part must be dropped BEFORE any flash read");
        // raising the cap restores normal publishing
        pipe.set_slab_cap(u64::MAX);
        pipe.request(job(2, &[0, 1], &[2, 3]));
        assert!(pipe.wait_part((2, OpKind::Wq)));
        assert!(pipe.part((2, OpKind::Wq)).is_some());
    }

    #[test]
    fn retired_slabs_are_recycled_for_same_op_parts() {
        // ROADMAP "slab reuse pool": a retired part's slab is reset for
        // the next same-op part instead of being reallocated — and the
        // reset must not leak the old request's rows into the new one.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(1, &[0, 1], &[1, 2]));
        assert!(pipe.wait_part((1, OpKind::Wq)));
        {
            let slab = pipe.part((1, OpKind::Wq)).unwrap();
            assert!(slab.row(0, 1).is_some());
        } // fetch-time Arc dropped — the store holds the sole reference
        pipe.retire_group(1);
        let parked = pipe.loader_stats();
        assert_eq!(parked.slabs_recycled, 0);
        assert!(parked.slab_pool_bytes > 0, "retired slab parked in the pool");
        pipe.request(job(2, &[2, 3], &[5, 6, 7]));
        assert!(pipe.wait_part((2, OpKind::Wq)));
        let st = pipe.loader_stats();
        assert_eq!(
            st.slabs_recycled, 1,
            "retired Wq slab must be reset in place, not reallocated"
        );
        assert_eq!(st.slab_pool_bytes, 0,
                   "the take moved the pooled bytes back to a live part");
        let slab = pipe.part((2, OpKind::Wq)).unwrap();
        assert_eq!(slab.channels(), &[5, 6, 7]);
        assert_eq!(slab.layers(), &[2, 3]);
        let r = slab.row(2, 5).expect("new row loaded")[0];
        let want = (5 * 2 + 2) as f32; // synth encodes (c*2+l)
        assert!((r - want).abs() <= want / 127.0 + 1e-2, "got {r}");
        assert!(
            slab.row(0, 1).is_none() && slab.row(2, 1).is_none(),
            "old request's rows must not survive the reset"
        );
    }

    #[test]
    fn retire_group_frees_live_bytes_and_parks_the_slab() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(3, &[0, 1], &[0, 1]));
        pipe.wait_part((3, OpKind::Wq));
        let before = pipe.stored_bytes();
        assert!(before > 0);
        pipe.retire_group(3);
        // the part is gone from the live store; its allocation parks in
        // the reuse pool and STAYS on the M_cl ledger (real DRAM)
        let st = pipe.loader_stats();
        assert_eq!(st.slab_bytes, 0, "live reservation released");
        assert_eq!(st.slab_pool_bytes, before, "allocation parked, not hidden");
        assert_eq!(pipe.stored_bytes(), before,
                   "ledger keeps seeing the pooled bytes");
        assert!(!pipe.part_ready((3, OpKind::Wq)));
        assert!(pipe.part((3, OpKind::Wq)).is_none());
    }

    #[test]
    fn slab_finishing_after_retire_is_dropped_not_leaked() {
        // The engine retires a group as soon as it finishes consuming it —
        // possibly while the loader is still reading that group's parts
        // (a fully cache-served fetch never waits). With the overlapped
        // loader EVERY part of the batch is in flight (and has reserved
        // its slab bytes) when the retirement lands: all the late slabs
        // must be dropped, every reservation released, and the byte
        // accounting must not drift.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.retire_group(5); // group 5 already consumed and retired
        let layers: Arc<[usize]> = Arc::from(&[0usize, 1][..]);
        let chans: Arc<[usize]> = Arc::from(&[1usize, 2][..]);
        // two sibling parts complete concurrently against the retirement
        pipe.request(PreloadBatch {
            seq: 5,
            layers: layers.clone(),
            parts: vec![
                PartRequest {
                    op: OpKind::Wq,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
                PartRequest {
                    op: OpKind::Wk,
                    spans: vec![PartSpan {
                        lo: 0,
                        hi: 2,
                        channels: chans.clone(),
                    }],
                    skipped_cached: 0,
                },
            ],
            ctx: SpanCtx::NONE,
        });
        pipe.request(job(6, &[0, 1], &[3]));
        assert!(pipe.wait_part((6, OpKind::Wq))); // FIFO: 5 processed first
        for op in [OpKind::Wq, OpKind::Wk] {
            assert!(!pipe.part_ready((5, op)));
            assert!(pipe.part((5, op)).is_none(), "late {op:?} slab dropped");
        }
        let bytes6 = pipe.part((6, OpKind::Wq)).unwrap().bytes();
        let st = pipe.loader_stats();
        assert_eq!(st.slab_bytes, bytes6,
                   "live accounting excludes the dropped slabs' reservations");
        assert_eq!(pipe.stored_bytes(), bytes6 + st.slab_pool_bytes,
                   "late slabs moved to the reuse pool, still on the ledger");
        assert_eq!(st.parts_loaded, 1,
                   "late parts must not count as loaded");
    }

    #[test]
    fn out_of_order_retire_keeps_older_live_groups() {
        // Interleaved sequences retire out of order: sequence B retiring
        // its newer group (seq 2) must NOT drop sequence A's older,
        // still-unconsumed chain (seq 1). The old high-water-mark
        // retirement dropped everything ≤ the retired seq.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.request(job(1, &[0, 1], &[4, 5]));
        pipe.request(job(2, &[2, 3], &[6, 7]));
        assert!(pipe.wait_part((1, OpKind::Wq)));
        assert!(pipe.wait_part((2, OpKind::Wq)));
        pipe.retire_group(2); // B retires first
        assert!(
            pipe.part((1, OpKind::Wq)).is_some(),
            "older unretired chain must survive a newer group's retirement"
        );
        assert!(pipe.part((2, OpKind::Wq)).is_none());
        pipe.retire_group(1);
        assert!(pipe.part((1, OpKind::Wq)).is_none());
        let st = pipe.loader_stats();
        assert_eq!(st.slab_bytes, 0, "no live parts remain");
        assert_eq!(pipe.stored_bytes(), st.slab_pool_bytes,
                   "only reuse-pool allocations remain on the ledger");
    }

    #[test]
    fn retire_floor_compacts_and_stays_idempotent() {
        let mut r = RetiredState::default();
        r.retire(2);
        r.retire(4);
        assert_eq!(r.floor, 0);
        assert!(r.is_retired(2) && r.is_retired(4));
        assert!(!r.is_retired(1) && !r.is_retired(3));
        r.retire(1); // contiguous prefix 1..=2 compacts
        assert_eq!(r.floor, 2);
        assert!(r.above.contains(&4) && !r.above.contains(&2));
        r.retire(3); // 3 then 4 compact
        assert_eq!(r.floor, 4);
        assert!(r.above.is_empty(), "compacted set must drain");
        r.retire(3); // idempotent below the floor
        r.retire(4);
        assert_eq!(r.floor, 4);
        assert!(r.above.is_empty());
        assert!(r.is_retired(4) && !r.is_retired(5));
    }

    #[test]
    fn late_publish_for_exactly_retired_seq_is_dropped() {
        // retire seq 2 BEFORE its batch is handled while seq 3 stays
        // live: the late seq-2 slab must be dropped (reservation
        // released), the seq-3 slab published.
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        pipe.retire_group(2);
        pipe.request(job(2, &[0, 1], &[1]));
        pipe.request(job(3, &[0, 1], &[2]));
        assert!(pipe.wait_part((3, OpKind::Wq)));
        assert!(!pipe.part_ready((2, OpKind::Wq)));
        assert!(pipe.part((2, OpKind::Wq)).is_none(), "late slab dropped");
        let b3 = pipe.part((3, OpKind::Wq)).unwrap().bytes();
        assert_eq!(pipe.loader_stats().slab_bytes, b3,
                   "dropped slab's reservation must be released");
    }

    #[test]
    fn pipeline_shutdown_clean() {
        let (awgf, flash, _p) = setup();
        let pipe = Pipeline::spawn(awgf, flash);
        drop(pipe); // must join without deadlock
    }

    #[test]
    fn slab_rows_hold_no_per_row_allocations() {
        // the whole part is exactly one contiguous buffer: channels×layers
        // rows of d_out floats, regardless of access order
        let layers: Arc<[usize]> = Arc::from(&[0usize, 1][..]);
        let mut slab = PartSlab::new(OpKind::Wq, layers, &[9, 4, 4, 2], 8);
        assert_eq!(slab.channels(), &[2, 4, 9]); // sorted + deduped
        assert_eq!(slab.bytes(), (3 * 2 * 8 * 4) as u64);
        assert!(slab.row(0, 4).is_none(), "unfilled row is a miss");
        slab.row_mut(0, 4).unwrap().fill(7.0);
        assert_eq!(slab.row(0, 4).unwrap(), &[7.0f32; 8][..]);
        assert!(slab.row(1, 4).is_none(), "per-(layer,channel) fill");
        assert!(slab.row(0, 3).is_none(), "unknown channel");
        assert!(slab.row_mut(2, 4).is_none(), "unknown layer");
    }

    /// The slab store must be bit-identical to the old per-row HashMap
    /// store: both dequantize the same flash bytes with the same codec, so
    /// for every random (layers, channels, cache-filter state) each loaded
    /// row must equal an independently read+dequantized reference row
    /// exactly, and filtered channels must stay store misses.
    #[test]
    fn slab_store_bit_identical_to_per_row_reference() {
        let (awgf, flash, _p) = setup();
        check("slab-vs-hashmap", |g| {
            let n_layers = g.usize_in(1, 2);
            let layers: Vec<usize> = if n_layers == 2 {
                vec![0, 1]
            } else {
                vec![g.usize_in(0, 1)]
            };
            let k = g.usize_in(1, 24);
            let requested = g.subset(128, k);
            // random cache state: the issuer filters a random subset of
            // the requested channels out of the job (as the engine does
            // for fully cache-resident channels)
            let pre = g.subset(128, g.usize_in(0, 16));
            let channels: Vec<usize> = requested
                .iter()
                .copied()
                .filter(|ch| !pre.contains(ch))
                .collect();
            let pipe = Pipeline::spawn(awgf.clone(), flash.clone());
            pipe.request(PreloadBatch::single(
                1,
                Arc::from(&layers[..]),
                OpKind::Wq,
                Arc::from(&channels[..]),
                ((requested.len() - channels.len()) * layers.len()) as u64,
            ));
            if !pipe.wait_part((1, OpKind::Wq)) {
                return Err("loader timed out".into());
            }
            let slab = pipe.part((1, OpKind::Wq)).unwrap();
            // reference: the old per-row path — read each (layer, channel)
            // row span individually and dequantize into its own Vec
            let mut reference: HashMap<(TensorId, u32), Vec<f32>> =
                HashMap::new();
            for &l in &layers {
                for &ch in &channels {
                    let (off, len) = awgf.row_span(OpKind::Wq, l, ch);
                    let buf = flash.read(off, len).map_err(|e| e.to_string())?;
                    let mut row = vec![0f32; 128];
                    quant::dequantize_row(&buf, awgf.quant, &mut row);
                    reference.insert((TensorId::new(l, OpKind::Wq), ch as u32), row);
                }
            }
            for &l in &layers {
                for &ch in &requested {
                    match slab.row(l, ch) {
                        Some(got) => {
                            if pre.contains(&ch) {
                                return Err(format!(
                                    "filtered ch{ch} must stay a store miss"
                                ));
                            }
                            let want = &reference
                                [&(TensorId::new(l, OpKind::Wq), ch as u32)];
                            if got != want.as_slice() {
                                return Err(format!(
                                    "row l{l} ch{ch} differs from reference"
                                ));
                            }
                        }
                        None => {
                            if !pre.contains(&ch) {
                                return Err(format!(
                                    "row l{l} ch{ch} missing from slab"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
