//! Runtime DRAM governor (paper §4.1 made *live*): re-budgets a running
//! [`SwapEngine`] when the available DRAM changes, without restarting it.
//!
//! The paper's third technique "orchestrates the DRAM space allocation
//! among the hot weight cache, preloaded active weights, and
//! computation-involved weights based on available memory". Before this
//! module that orchestration was a one-shot startup search; a phone's free
//! DRAM moves while the app runs, so the governor owns a **ledger** of the
//! three pools and replays the §4.1 search online:
//!
//! ```text
//!   pools (Eq 8):   M = M_cl (preload slabs) + M_cache + M_compute
//!                   M_compute's KV term = blocks-in-use × block bytes
//!                   (planned as expected-occupancy blocks × seqs)
//!   event           {"cmd":"set_budget"} | PressureSchedule step
//!                   | --pressure-file poll (available-DRAM change)
//!        │
//!        ▼
//!   hysteresis gate ── small relative change → record + skip
//!        │
//!        ▼
//!   plan(M_max'): for seqs = max_seqs..1, search(M_max', kv·seqs)
//!        │           → most concurrency that stays servable
//!        ▼
//!   SwapEngine::apply_plan + scheduler admission ceiling:
//!     · WeightCache::resize — evict down to the new cache target
//!     · preload slab cap    — loader drops parts past the M_cl ceiling
//!     · group size N        — preload look-ahead depth
//!     · sparsity level      — switch the active AWGF artifact set
//!     · max_seqs            — scheduler sheds/queues sequences past it
//!     · kv pool blocks      — paged-KV ceiling (OOM preemption past it)
//! ```
//!
//! Every decision (old→new pools, trigger, settle time) is recorded and
//! surfaced through [`DecodeMetrics`](crate::metrics::DecodeMetrics) and
//! the server `stats` command.

use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::costmodel::{self, Geometry};
use crate::device::DeviceProfile;
use crate::engine::{RebudgetPlan, SwapEngine};

/// Snapshot of the three DRAM pools the governor arbitrates (paper Eq 8).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolLedger {
    /// Hot weight cache: `WeightCache` allocated bytes (M_cache).
    pub cache_bytes: u64,
    /// In-flight preloaded active weights: live part-slab bytes (M_cl).
    pub preload_bytes: u64,
    /// Computation-involved bytes: dense tensors + KV state + engine
    /// scratch (packed matrices, activations, row buffers).
    pub compute_bytes: u64,
}

impl PoolLedger {
    pub fn total(&self) -> u64 {
        self.cache_bytes + self.preload_bytes + self.compute_bytes
    }
}

/// What caused a re-budget attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebudgetTrigger {
    /// Server `{"cmd":"set_budget"}`.
    Command,
    /// A [`PressureSchedule`] step fired.
    Schedule,
    /// The polled available-DRAM file changed (`--pressure-file`, the OS
    /// memory-pressure source next to `command`/`schedule`).
    Pressure,
    /// Direct library call (examples, tests).
    Manual,
}

impl RebudgetTrigger {
    pub fn name(&self) -> &'static str {
        match self {
            RebudgetTrigger::Command => "command",
            RebudgetTrigger::Schedule => "schedule",
            RebudgetTrigger::Pressure => "pressure",
            RebudgetTrigger::Manual => "manual",
        }
    }
}

/// One re-budget decision, applied or not. The full history is kept by the
/// governor; the newest entry backs the server `stats` fields.
#[derive(Debug, Clone)]
pub struct RebudgetDecision {
    pub trigger: RebudgetTrigger,
    pub old_budget: u64,
    pub new_budget: u64,
    pub old_pools: PoolLedger,
    pub new_pools: PoolLedger,
    pub old_sp: f64,
    pub new_sp: f64,
    pub old_group: usize,
    pub new_group: usize,
    /// Cache byte target the search assigned (M_cache').
    pub cache_target: u64,
    /// The search's per-group preload bytes (Eq 9 M_cl; 0 when the
    /// decision was not applied).
    pub m_cl: u64,
    /// Preload slab ceiling handed to the loader (M_cl × headroom).
    pub slab_cap: u64,
    /// Rows evicted by the cache shrink.
    pub evicted_rows: u64,
    /// Concurrent-sequence ceiling under the new budget: the planner
    /// prices `M_kv` as `kv_per_seq × seqs` where `kv_per_seq` is the
    /// **expected** per-sequence occupancy in whole KV blocks (p90 ended
    /// -sequence length, block-rounded — `max_seq` before any traffic),
    /// and admits as many sequences as the budget fits (≤ the configured
    /// `max_seqs`, ≥ 1). The scheduler's block-headroom admission and
    /// OOM preemption enforce the realized occupancy.
    pub max_seqs: usize,
    /// Paged-KV pool ceiling handed to the engine: the budgeted `M_kv`
    /// in blocks (`kv_per_seq × max_seqs / block_bytes`).
    pub kv_pool_blocks: usize,
    /// Wall time to apply the plan (artifact switch + cache resize).
    pub settle: Duration,
    /// False when the hysteresis gate or an infeasible budget stopped the
    /// re-budget; the engine keeps its previous configuration.
    pub applied: bool,
    /// "applied" | "hysteresis" | "infeasible".
    pub note: &'static str,
}

/// Governor knobs. Defaults follow the paper's search inputs.
#[derive(Debug, Clone)]
pub struct GovernorConfig {
    /// Fallback cross-layer similarity for the search before the engine
    /// has measured any (paper uses ~0.85 for 7B-class models).
    pub similarity: f64,
    /// Sparsity grid the search snaps to (must match compiled artifacts).
    pub sp_grid: Vec<f64>,
    /// Hysteresis: relative budget change below which a re-budget is
    /// skipped (avoids thrashing the cache on noisy pressure signals).
    pub hysteresis: f64,
    /// Preload-slab ceiling as a multiple of the searched M_cl (current
    /// group + the next one in flight).
    pub slab_headroom: f64,
    /// Upper bound on concurrently decoding sequences the planner may
    /// admit (the scheduler's `--max-seqs`); the budget shrinks the
    /// *effective* ceiling below this when `kv_per_seq × max_seqs` no
    /// longer fits next to a servable configuration.
    pub max_seqs: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            similarity: 0.85,
            sp_grid: vec![0.5, 0.6, 0.7, 0.8, 0.9],
            hysteresis: 0.05,
            slab_headroom: 2.0,
            max_seqs: 4,
        }
    }
}

impl GovernorConfig {
    pub fn from_runtime(rc: &crate::config::RuntimeConfig) -> GovernorConfig {
        GovernorConfig {
            hysteresis: rc.rebudget_hysteresis,
            max_seqs: rc.max_seqs,
            ..GovernorConfig::default()
        }
    }
}

/// The live re-budgeting control loop around one [`SwapEngine`].
pub struct DramGovernor {
    cfg: GovernorConfig,
    geo: Geometry,
    device: &'static DeviceProfile,
    bw_scale: f64,
    /// Expected KV bytes of one sequence in whole blocks (the KV pool
    /// term is `kv_per_seq × seqs`). Refreshed from the engine's
    /// observed traffic on every `set_budget`, so `max_seqs` tracks
    /// *expected* occupancy — short-request workloads admit more.
    kv_per_seq: u64,
    /// Last budget a decision was *applied* for (M_max).
    budget: u64,
    /// Current concurrent-sequence ceiling (≤ `cfg.max_seqs`; shrinks
    /// under a falling budget, grows back when it recovers).
    max_seqs: usize,
    applied_once: bool,
    decisions: Vec<RebudgetDecision>,
}

impl DramGovernor {
    /// Build a governor for `engine`, assuming `initial_budget` bytes of
    /// DRAM (typically the device's physical DRAM until the first
    /// `set_budget` arrives).
    pub fn new(
        engine: &SwapEngine,
        cfg: GovernorConfig,
        initial_budget: u64,
    ) -> DramGovernor {
        Self::from_parts(
            cfg,
            engine.geometry(),
            engine.opts.device,
            engine.opts.bw_scale,
            engine.kv_expected_seq_bytes(),
            initial_budget,
        )
    }

    /// Engine-free constructor (unit tests, synthetic geometries).
    pub fn from_parts(
        cfg: GovernorConfig,
        geo: Geometry,
        device: &'static DeviceProfile,
        bw_scale: f64,
        kv_per_seq: u64,
        initial_budget: u64,
    ) -> DramGovernor {
        let max_seqs = cfg.max_seqs.max(1);
        DramGovernor {
            cfg,
            geo,
            device,
            bw_scale,
            kv_per_seq,
            budget: initial_budget,
            max_seqs,
            applied_once: false,
            decisions: Vec::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Current concurrent-sequence ceiling the KV pool affords.
    pub fn max_seqs(&self) -> usize {
        self.max_seqs
    }

    pub fn kv_per_seq(&self) -> u64 {
        self.kv_per_seq
    }

    /// Pure §4.1 planning under `bytes` of DRAM with the KV pool term
    /// folded into Eq 8: the fixed M_kv becomes `kv_per_seq × seqs`, and
    /// the planner admits the **most** concurrent sequences (≤ the
    /// configured `max_seqs`) that still leave a servable configuration —
    /// concurrency first, then the search splits what remains between
    /// preload depth and cache as before. Returns `None` when even one
    /// sequence does not fit (infeasible budget).
    pub fn plan(
        &self,
        bytes: u64,
        similarity: f64,
    ) -> Option<(costmodel::SearchResult, usize)> {
        let target = self.cfg.max_seqs.max(1);
        for seqs in (1..=target).rev() {
            let geo = Geometry {
                kv_bytes: self.kv_per_seq * seqs as u64,
                ..self.geo
            };
            if let Some(r) = costmodel::search(
                self.device,
                &geo,
                bytes,
                similarity,
                self.bw_scale,
                &self.cfg.sp_grid,
            ) {
                return Some((r, seqs));
            }
        }
        None
    }

    pub fn decisions(&self) -> &[RebudgetDecision] {
        &self.decisions
    }

    pub fn last_decision(&self) -> Option<&RebudgetDecision> {
        self.decisions.last()
    }

    /// Pool targets of the newest **applied** decision — the governor's
    /// side of the per-wave DRAM ledger sample (all-zero before the
    /// first applied re-budget).
    pub fn current_pools(&self) -> PoolLedger {
        self.decisions
            .iter()
            .rev()
            .find(|d| d.applied)
            .map(|d| d.new_pools)
            .unwrap_or_default()
    }

    /// Handle a budget-change event: gate on hysteresis, re-run the §4.1
    /// search under the new `M_max`, and apply `(sp, N, cache)` to the
    /// running engine. Must be called between requests (it takes the
    /// engine mutably; a decode is never in flight). Returns the recorded
    /// decision — `applied == false` means the engine was left untouched.
    pub fn set_budget(
        &mut self,
        engine: &mut SwapEngine,
        bytes: u64,
        trigger: RebudgetTrigger,
    ) -> Result<RebudgetDecision> {
        // expected per-sequence occupancy under observed traffic (block-
        // rounded): re-sampled at every budget event so the Eq 8 KV term
        // tracks what sequences actually use, not the max_seq worst case
        self.kv_per_seq = engine.kv_expected_seq_bytes().max(1);
        let old_pools = engine.pool_ledger();
        let old_sp = engine.opts.sparsity;
        let old_group = engine.opts.group_size;
        let mut d = RebudgetDecision {
            trigger,
            old_budget: self.budget,
            new_budget: bytes,
            old_pools,
            new_pools: old_pools,
            old_sp,
            new_sp: old_sp,
            old_group,
            new_group: old_group,
            cache_target: engine.opts.cache_bytes,
            m_cl: 0,
            // skipped decisions report the engine's *current* ceilings,
            // not sentinels
            slab_cap: engine.slab_cap(),
            evicted_rows: 0,
            max_seqs: self.max_seqs,
            kv_pool_blocks: engine.kv_capacity_blocks(),
            settle: Duration::ZERO,
            applied: false,
            note: "applied",
        };

        // Hysteresis: once a configuration is in place, ignore wiggle.
        // The reference point is the last *applied* budget, so repeated
        // small steps in one direction accumulate and eventually pass.
        let rel = (bytes as f64 - self.budget as f64).abs()
            / self.budget.max(1) as f64;
        if self.applied_once && rel < self.cfg.hysteresis {
            d.note = "hysteresis";
            engine.metrics.rebudgets_skipped += 1;
            engine.trace_rebudget(&d);
            self.decisions.push(d.clone());
            return Ok(d);
        }

        // Online §4.1 search under the new M_max. Similarity comes from
        // the engine's own tracker once it has observed real activations.
        let measured_si = engine.tracker.avg_precision();
        let si = if measured_si > 0.0 {
            measured_si
        } else {
            self.cfg.similarity
        };
        let Some((r, seqs)) = self.plan(bytes, si) else {
            // Below the sparsest servable one-sequence configuration:
            // keep running the old parameters (we cannot do better than
            // max sparsity) and record the refusal.
            d.note = "infeasible";
            engine.metrics.rebudgets_skipped += 1;
            engine.trace_rebudget(&d);
            self.decisions.push(d.clone());
            return Ok(d);
        };

        let slab_cap =
            (r.cost.m_cl as f64 * self.cfg.slab_headroom).ceil() as u64;
        // The budgeted M_kv, expressed as the pool's block ceiling: the
        // scheduler grows block tables freely inside it and sheds load
        // (OOM preemption) past it. Floored at ONE full max_seq window
        // so a legal long prompt is never permanently unservable after
        // short-request traffic shrinks the expected occupancy — blocks
        // are materialized lazily, so the floor costs nothing until a
        // long request actually arrives (and then OOM preemption sheds
        // its peers rather than the scheduler rejecting it outright).
        let blk = engine.kv_block_bytes().max(1);
        let window_blocks = (engine.kv_per_seq_bytes() / blk).max(1) as usize;
        let kv_pool_blocks = (((self.kv_per_seq * seqs as u64) / blk)
            .max(1) as usize)
            .max(window_blocks);
        let plan = RebudgetPlan {
            sparsity: r.params.sp,
            group_size: r.params.n_group,
            cache_bytes: r.params.cache_bytes,
            slab_cap_bytes: slab_cap.max(1),
            kv_capacity_blocks: kv_pool_blocks,
        };
        let outcome = engine.apply_plan(&plan)?;

        d.new_sp = r.params.sp;
        d.new_group = r.params.n_group;
        d.cache_target = r.params.cache_bytes;
        d.m_cl = r.cost.m_cl;
        d.slab_cap = plan.slab_cap_bytes;
        d.evicted_rows = outcome.evicted_rows;
        d.max_seqs = seqs;
        d.kv_pool_blocks = kv_pool_blocks;
        d.settle = outcome.settle;
        d.new_pools = engine.pool_ledger();
        d.applied = true;
        self.budget = bytes;
        self.max_seqs = seqs;
        self.applied_once = true;
        engine.metrics.rebudgets_applied += 1;
        engine.metrics.rebudget_settle += outcome.settle;
        engine.trace_rebudget(&d);
        self.decisions.push(d.clone());
        Ok(d)
    }
}

// ===================================================== pressure schedule

/// One step of a scripted memory-pressure trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PressureStep {
    /// Fire once the engine has decoded at least this many tokens.
    pub at_token: u64,
    /// New DRAM budget in bytes.
    pub budget: u64,
}

/// Scriptable pressure schedule for benches, examples, and `serve
/// --pressure`: a list of `(budget, token)` steps parsed from
/// `"<size>@<token>[,...]"`, e.g. `"48mb@0,24mb@32,12mb@64"`. Sizes
/// accept `b`/`kb`/`mb`/`gb` suffixes (binary: 1kb = 1024,
/// case-insensitive) or raw byte counts.
#[derive(Debug, Clone, Default)]
pub struct PressureSchedule {
    steps: Vec<PressureStep>,
    next: usize,
}

impl PressureSchedule {
    pub fn new(mut steps: Vec<PressureStep>) -> PressureSchedule {
        steps.sort_by_key(|s| s.at_token);
        PressureSchedule { steps, next: 0 }
    }

    pub fn parse(spec: &str) -> Result<PressureSchedule> {
        let mut steps = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (size, at) = part
                .split_once('@')
                .ok_or_else(|| anyhow!("bad pressure step '{part}' \
                                        (want <size>@<token>)"))?;
            steps.push(PressureStep {
                at_token: at.trim().parse::<u64>().map_err(|_| {
                    anyhow!("bad token index '{at}' in '{part}'")
                })?,
                budget: parse_bytes(size.trim())?,
            });
        }
        if steps.is_empty() {
            return Err(anyhow!("empty pressure schedule '{spec}'"));
        }
        Ok(PressureSchedule::new(steps))
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn steps(&self) -> &[PressureStep] {
        &self.steps
    }

    /// The next budget whose step time has passed, if any. Consuming:
    /// each step fires once. When several steps are overdue the *latest*
    /// wins (the intermediate budgets were never observed).
    pub fn due(&mut self, tokens_decoded: u64) -> Option<u64> {
        let mut fired = None;
        while self.next < self.steps.len()
            && self.steps[self.next].at_token <= tokens_decoded
        {
            fired = Some(self.steps[self.next].budget);
            self.next += 1;
        }
        fired
    }
}

/// Read an available-DRAM figure from a memory-pressure file (the
/// `--pressure-file` source, polled on the server worker between waves
/// and fed to [`DramGovernor::set_budget`] as the third trigger next to
/// `command`/`schedule`). Two formats:
///
/// * `/proc/meminfo` style — the `MemAvailable:` line wins
///   (`MemAvailable:  123456 kB`); `MemFree:` is the fallback when
///   `MemAvailable` is absent (old kernels).
/// * a plain byte figure (`"1536mb"`, `"402653184"`) — mock files in
///   tests and cgroup-style single-value limits.
pub fn read_pressure_file(path: &std::path::Path) -> Result<u64> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading pressure file {}: {e}", path.display()))?;
    let mut fallback = None;
    for line in text.lines() {
        let Some((key, rest)) = line.split_once(':') else { continue };
        let key = key.trim();
        if key != "MemAvailable" && key != "MemFree" {
            continue;
        }
        let rest = rest.trim();
        let (num, mult) = match rest.strip_suffix("kB") {
            Some(n) => (n.trim(), 1024u64),
            None => (rest, 1),
        };
        let v: u64 = num
            .parse()
            .map_err(|_| anyhow!("bad {key} value '{rest}'"))?;
        if key == "MemAvailable" {
            return Ok(v * mult);
        }
        fallback = Some(v * mult);
    }
    if let Some(v) = fallback {
        return Ok(v);
    }
    parse_bytes(text.trim())
        .map_err(|_| anyhow!("pressure file {} holds neither a MemAvailable \
                              line nor a byte figure", path.display()))
}

/// Parse `"123"`, `"64kb"`, `"1536mb"`, `"2gb"` into bytes (binary
/// suffixes — 1kb = 1024, 1mb = 2^20 — case-insensitive, fractional
/// values allowed: `"1.5gb"`).
pub fn parse_bytes(s: &str) -> Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(n) = t.strip_suffix("gb") {
        (n, 1u64 << 30)
    } else if let Some(n) = t.strip_suffix("mb") {
        (n, 1u64 << 20)
    } else if let Some(n) = t.strip_suffix("kb") {
        (n, 1u64 << 10)
    } else if let Some(n) = t.strip_suffix('b') {
        (n, 1)
    } else {
        (t.as_str(), 1)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad byte size '{s}'"))?;
    if v < 0.0 {
        return Err(anyhow!("negative byte size '{s}'"));
    }
    Ok((v * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PIXEL6;

    #[test]
    fn kv_pool_planning_caps_seqs_at_the_budget_boundary() {
        // Acceptance boundary: the ledger charges KV as kv_per_seq × seqs,
        // so a budget that fits exactly two sequences' KV next to the
        // sparsest servable model must admit two — not three.
        let geo = Geometry::llama7b_q4();
        let kv = 256u64 << 20;
        let cfg = GovernorConfig {
            max_seqs: 4,
            ..GovernorConfig::default()
        };
        let gov =
            DramGovernor::from_parts(cfg, geo, &PIXEL6, 1.0, kv, 4 << 30);
        assert_eq!(gov.max_seqs(), 4, "starts at the configured ceiling");
        // sparsest grid level is sp=0.9 → the model needs ≥10% of S_m
        let min_model = (geo.model_bytes as f64 * 0.1) as u64;
        let b2 = 2 * kv + min_model + (1 << 20);
        let (r, seqs) = gov.plan(b2, 0.85).expect("two sequences fit");
        assert_eq!(seqs, 2, "a third sequence's KV would overshoot");
        assert!(
            r.cost.mem_bytes <= b2,
            "planned memory {} over budget {b2}",
            r.cost.mem_bytes
        );
        // one more KV's worth of budget admits the third
        let (_, seqs) = gov.plan(b2 + kv, 0.85).unwrap();
        assert_eq!(seqs, 3);
        // plenty of budget: capped at the configured ceiling
        let (_, seqs) = gov.plan(16 << 30, 0.85).unwrap();
        assert_eq!(seqs, 4);
        // below even one sequence: infeasible
        assert!(gov.plan(kv + min_model / 2, 0.85).is_none());
    }

    #[test]
    fn planner_prefers_concurrency_over_cache() {
        // Doubling the budget beyond the 4-seq ceiling goes to cache, not
        // more sequences; halving below it sheds sequences first.
        let geo = Geometry::llama7b_q4();
        let kv = 256u64 << 20;
        let cfg = GovernorConfig {
            max_seqs: 2,
            ..GovernorConfig::default()
        };
        let gov =
            DramGovernor::from_parts(cfg, geo, &PIXEL6, 1.0, kv, 4 << 30);
        let (_, seqs) = gov.plan(8 << 30, 0.85).unwrap();
        assert_eq!(seqs, 2, "ceiling binds, extra budget goes to cache");
        let min_model = (geo.model_bytes as f64 * 0.1) as u64;
        let (_, seqs) = gov.plan(kv + min_model + (1 << 20), 0.85).unwrap();
        assert_eq!(seqs, 1, "tight budget sheds concurrency to stay live");
    }

    #[test]
    fn ledger_totals() {
        let l = PoolLedger {
            cache_bytes: 100,
            preload_bytes: 20,
            compute_bytes: 3,
        };
        assert_eq!(l.total(), 123);
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("123").unwrap(), 123);
        assert_eq!(parse_bytes("123b").unwrap(), 123);
        assert_eq!(parse_bytes("64kb").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("2MB").unwrap(), 2 << 20);
        assert_eq!(parse_bytes("1gb").unwrap(), 1 << 30);
        assert_eq!(parse_bytes("1.5gb").unwrap(), 3 << 29);
        assert!(parse_bytes("x").is_err());
        assert!(parse_bytes("-4kb").is_err());
    }

    #[test]
    fn schedule_parse_and_order() {
        let mut s =
            PressureSchedule::parse("24mb@32, 48mb@0 ,12mb@64").unwrap();
        assert_eq!(s.len(), 3);
        // sorted by token regardless of spec order
        assert_eq!(s.steps()[0], PressureStep {
            at_token: 0,
            budget: 48 << 20
        });
        assert_eq!(s.due(0), Some(48 << 20));
        assert_eq!(s.due(10), None, "no step due between 0 and 32");
        assert_eq!(s.due(40), Some(24 << 20));
        assert_eq!(s.due(64), Some(12 << 20));
        assert_eq!(s.due(1000), None, "steps fire once");
    }

    #[test]
    fn schedule_overdue_steps_collapse_to_latest() {
        let mut s = PressureSchedule::parse("48mb@0,24mb@8,12mb@16").unwrap();
        // the engine decoded straight past two steps: only the newest
        // budget matters
        assert_eq!(s.due(100), Some(12 << 20));
        assert_eq!(s.due(101), None);
    }

    #[test]
    fn pressure_file_reads_meminfo_and_plain_bytes() {
        let dir = std::env::temp_dir()
            .join(format!("awf_pressure_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("meminfo");
        std::fs::write(
            &p,
            "MemTotal:       8000000 kB\nMemFree:         100000 kB\n\
             MemAvailable:    200000 kB\nBuffers:          50000 kB\n",
        )
        .unwrap();
        assert_eq!(read_pressure_file(&p).unwrap(), 200_000 * 1024);
        // MemFree fallback when MemAvailable is absent
        std::fs::write(&p, "MemTotal: 8000000 kB\nMemFree: 100000 kB\n")
            .unwrap();
        assert_eq!(read_pressure_file(&p).unwrap(), 100_000 * 1024);
        // plain byte figures (mock files, cgroup-style limits)
        std::fs::write(&p, "1536mb\n").unwrap();
        assert_eq!(read_pressure_file(&p).unwrap(), 1536 << 20);
        std::fs::write(&p, "402653184").unwrap();
        assert_eq!(read_pressure_file(&p).unwrap(), 402653184);
        // garbage and missing files error instead of panicking the worker
        std::fs::write(&p, "not a size").unwrap();
        assert!(read_pressure_file(&p).is_err());
        assert!(read_pressure_file(&dir.join("missing")).is_err());
    }

    #[test]
    fn schedule_rejects_garbage() {
        assert!(PressureSchedule::parse("").is_err());
        assert!(PressureSchedule::parse("12mb").is_err());
        assert!(PressureSchedule::parse("@12").is_err());
        assert!(PressureSchedule::parse("12mb@x").is_err());
    }
}
