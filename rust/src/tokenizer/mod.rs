//! Byte-level tokenizer + the deterministic multi-domain corpus generator —
//! exact mirror of `python/compile/corpus.py` so both sides stream identical
//! tokens (the cache/locality experiments depend on this).

use crate::util::rng::Xorshift;

pub const VOCAB_SIZE: usize = 256;

pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| b as u32).collect()
}

pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t % 256) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

// --------------------------------------------------------------- corpus

pub const DOMAIN_NAMES: [&str; 4] = ["wiki", "code", "qa", "chat"];

struct Domain {
    det: &'static [&'static str],
    nouns: &'static [&'static str],
    verbs: &'static [&'static str],
    adjs: &'static [&'static str],
}

fn domain(name: &str) -> Domain {
    match name {
        "wiki" => Domain {
            det: &["the", "a", "an", "this", "that"],
            nouns: &["system", "language", "model", "device", "memory",
                     "history", "city", "river", "theory", "century",
                     "network", "protocol"],
            verbs: &["is", "was", "describes", "contains", "supports",
                     "denotes"],
            adjs: &["large", "small", "early", "modern", "common", "formal"],
        },
        "code" => Domain {
            det: &["fn", "let", "pub", "use", "impl", "return"],
            nouns: &["buffer", "index", "cache", "layer", "weight",
                     "channel", "tensor", "queue", "thread", "handle"],
            verbs: &["loads", "stores", "maps", "returns", "computes",
                     "updates"],
            adjs: &["mutable", "static", "atomic", "sparse", "dense",
                    "packed"],
        },
        "qa" => Domain {
            det: &["does", "is", "can", "will", "should"],
            nouns: &["question", "answer", "passage", "statement", "claim",
                     "fact"],
            verbs: &["imply", "confirm", "support", "contradict", "mention"],
            adjs: &["true", "false", "yes", "no", "maybe"],
        },
        "chat" => Domain {
            det: &["please", "could", "thanks", "okay", "sure"],
            nouns: &["assistant", "user", "message", "request", "reply",
                     "summary"],
            verbs: &["write", "explain", "translate", "summarize", "list"],
            adjs: &["helpful", "short", "detailed", "polite", "clear"],
        },
        other => panic!("unknown domain {other}"),
    }
}

fn gen_sentence(rng: &mut Xorshift, name: &str) -> String {
    let d = domain(name);
    let mut words = vec![
        *rng.choice(d.det),
        *rng.choice(d.adjs),
        *rng.choice(d.nouns),
        *rng.choice(d.verbs),
        *rng.choice(d.det),
        *rng.choice(d.adjs),
        *rng.choice(d.nouns),
    ];
    if rng.below(3) == 0 {
        words.push("and");
        words.push(*rng.choice(d.nouns));
    }
    format!("{}. ", words.join(" "))
}

/// Mixed-domain text (domain chosen per sentence), matching python
/// `gen_text(seed, n, None)`.
pub fn gen_text(seed: u64, n_sentences: usize, dom: Option<&str>) -> String {
    let mut rng = Xorshift::new(seed);
    let mut out = String::new();
    for _ in 0..n_sentences {
        let name = match dom {
            Some(d) => d,
            None => DOMAIN_NAMES[rng.below(DOMAIN_NAMES.len() as u64) as usize],
        };
        out.push_str(&gen_sentence(&mut rng, name));
    }
    out
}

pub fn eval_corpus() -> Vec<u32> {
    encode(&gen_text(1337, 800, None))
}

pub fn task_corpus(dom: &str, seed: u64, n: usize) -> Vec<u32> {
    encode(&gen_text(seed, n, Some(dom)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "active weights swap between dram and flash.";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn corpus_deterministic() {
        assert_eq!(gen_text(7, 5, None), gen_text(7, 5, None));
        assert_ne!(gen_text(7, 5, None), gen_text(8, 5, None));
    }

    #[test]
    fn domains_have_distinct_vocab() {
        let wiki = gen_text(1, 50, Some("wiki"));
        let code = gen_text(1, 50, Some("code"));
        assert!(wiki.contains("the"));
        assert!(code.contains("fn") || code.contains("let"));
        assert!(!code.contains("century"));
    }

    #[test]
    fn matches_python_generator() {
        // Pinned prefix of python corpus.gen_text(42, 2):
        // regenerated via python/tests/test_parity.py — both must agree.
        let text = gen_text(42, 2, None);
        assert!(text.ends_with(". "));
        assert!(text.split(' ').count() >= 14);
    }
}
