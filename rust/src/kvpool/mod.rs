//! Paged KV pool: block-granular KV allocation (vLLM-style) shared by
//! every live sequence of one engine.
//!
//! The monolithic KV path allocated one `[max_seq, d_kv]` K and V buffer
//! per layer per sequence up front, so a sequence that decoded 12 tokens
//! still stranded a full `max_seq` window of DRAM — memory the governor
//! could have handed to the weight cache or the preload slabs. The pool
//! replaces that with fixed-size **blocks** of `block_tokens` tokens'
//! worth of KV across *all* layers:
//!
//! ```text
//!   block bytes = block_tokens × kv_bytes_per_token
//!   kv_bytes_per_token = 2 (K+V) × n_layers × d_kv × 4
//!
//!   block data layout (one contiguous Vec<f32>):
//!     [layer 0 | K rows 0..bt | V rows 0..bt] [layer 1 | ...] ...
//! ```
//!
//! A sequence owns a [`SeqKv`]: a block table (`Vec` of block ids) plus
//! its token position. The table grows **on demand** as decode advances —
//! one block every `block_tokens` tokens — and releases every block back
//! to the free list when the sequence ends. Occupancy (`in_use_bytes`,
//! blocks held by live sequences) drives admission; the governor's
//! compute-pool ledger charges `resident_bytes` — occupancy plus freed
//! blocks parked for reuse, i.e. the DRAM the pool physically holds —
//! and a governor capacity shrink trims the parked storage so the
//! budget really comes back (ISSUE / ROADMAP "paged/partial KV").
//!
//! **Bit-safety.** The `attn_core` artifact takes a contiguous
//! `[max_seq, d_kv]` window, so the engine materializes one layer's K/V
//! from the block table into a reusable scratch buffer before the call
//! ([`SeqKv::gather_layer`]: written rows copied block-by-block, the tail
//! zero-filled exactly like the monolithic zero-initialized buffer) and
//! scatters the one newly written row back after it
//! ([`SeqKv::scatter_row`] — rows `0..pos` pass through the artifact
//! unchanged, so they never need re-writing).
//! Rows round-trip bit-identically — `tests/sched_bitsafety.rs` proves a
//! small-block decode token-identical to a whole-window-block decode, and
//! the property test below proves gather/scatter equal to a plain-buffer
//! reference for random traffic. Recycled blocks are *not* re-zeroed:
//! gather only reads rows the owning sequence has scattered, and
//! zero-fills the rest of the scratch itself.
//!
//! The pool is single-threaded by construction: the engine owns it and
//! decode is serialized through `&mut SwapEngine` — no locks.

/// Live/peak usage snapshot of the pool (server `stats`, benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Admission ceiling in blocks (`usize::MAX` = unbounded).
    pub capacity_blocks: usize,
    /// Blocks currently held by live sequences.
    pub in_use_blocks: usize,
    /// Blocks free for allocation (`capacity - in_use`).
    pub free_blocks: usize,
    /// High-water mark of `in_use_blocks`.
    pub peak_blocks: usize,
    /// Allocation attempts refused because the pool was at capacity.
    pub alloc_failures: u64,
}

/// The shared block store: a free list over lazily allocated fixed-size
/// blocks, bounded by a governor-set capacity.
pub struct KvPool {
    block_tokens: usize,
    n_layers: usize,
    d_kv: usize,
    /// Block storage; index = block id. Grows lazily up to the capacity
    /// high-water mark and never shrinks (freed blocks are recycled via
    /// `free` — shrinking the *ceiling* is `set_capacity_blocks`).
    blocks: Vec<Vec<f32>>,
    free: Vec<u32>,
    capacity_blocks: usize,
    peak_in_use: usize,
    alloc_failures: u64,
}

impl KvPool {
    /// Unbounded pool (legacy single-sequence paths allocate whatever a
    /// full window needs); the governor sets a finite capacity via
    /// [`KvPool::set_capacity_blocks`].
    pub fn new(block_tokens: usize, n_layers: usize, d_kv: usize) -> KvPool {
        KvPool {
            block_tokens: block_tokens.max(1),
            n_layers,
            d_kv,
            blocks: Vec::new(),
            free: Vec::new(),
            capacity_blocks: usize::MAX,
            peak_in_use: 0,
            alloc_failures: 0,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Floats one block holds: all layers × (K+V) × block_tokens × d_kv.
    fn block_floats(&self) -> usize {
        self.n_layers * 2 * self.block_tokens * self.d_kv
    }

    /// Bytes one block costs (the pool's accounting unit).
    pub fn block_bytes(&self) -> u64 {
        (self.block_floats() * 4) as u64
    }

    /// Blocks a sequence of `tokens` tokens occupies.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Set the admission ceiling. Shrinking below the in-use count does
    /// not reclaim held blocks — allocations simply fail until sequences
    /// release (the scheduler's preemption paths drive that). Free-listed
    /// **storage** above the new ceiling is dropped though: the ledger
    /// charges resident bytes, so a governor shrink must genuinely hand
    /// the DRAM back, not just stop future growth.
    pub fn set_capacity_blocks(&mut self, n: usize) {
        self.capacity_blocks = n.max(1);
        let mut resident = self.resident_blocks();
        if resident > self.capacity_blocks {
            for i in 0..self.free.len() {
                if resident <= self.capacity_blocks {
                    break;
                }
                let b = &mut self.blocks[self.free[i] as usize];
                if !b.is_empty() {
                    *b = Vec::new();
                    resident -= 1;
                }
            }
        }
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn in_use_blocks(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    /// Blocks still allocatable under the ceiling.
    pub fn free_blocks(&self) -> usize {
        self.capacity_blocks.saturating_sub(self.in_use_blocks())
    }

    /// Bytes held by live block tables (occupancy — what sequences have
    /// actually written).
    pub fn in_use_bytes(&self) -> u64 {
        self.in_use_blocks() as u64 * self.block_bytes()
    }

    /// Blocks whose storage is physically allocated: in-use blocks plus
    /// free-listed blocks parked for reuse (released storage is emptied
    /// lazily by [`KvPool::set_capacity_blocks`] shrinks).
    fn resident_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| !b.is_empty()).count()
    }

    /// Resident DRAM of the pool — the **ledger's** KV term. Freed
    /// blocks stay resident for recycling (within the capacity ceiling),
    /// so this only snaps down when the governor shrinks the ceiling;
    /// charging mere occupancy here would let the governor re-budget
    /// DRAM the pool still physically holds.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_blocks() as u64 * self.block_bytes()
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            capacity_blocks: self.capacity_blocks,
            in_use_blocks: self.in_use_blocks(),
            free_blocks: self.free_blocks(),
            peak_blocks: self.peak_in_use,
            alloc_failures: self.alloc_failures,
        }
    }

    /// Allocate one block (recycled first, fresh storage otherwise).
    /// `None` = pool dry — the ceiling binds recycled and fresh blocks
    /// alike, so a governor shrink below the in-use count really does
    /// stop growth until sequences release. The caller decides between
    /// queueing, preemption and truncation.
    pub fn alloc(&mut self) -> Option<u32> {
        if self.in_use_blocks() >= self.capacity_blocks {
            self.alloc_failures += 1;
            return None;
        }
        let id = match self.free.pop() {
            Some(id) => {
                // storage may have been dropped by a capacity shrink —
                // re-materialize (zeroed, like any fresh block)
                if self.blocks[id as usize].is_empty() {
                    self.blocks[id as usize] =
                        vec![0.0; self.block_floats()];
                }
                id
            }
            None => {
                self.blocks.push(vec![0.0; self.block_floats()]);
                (self.blocks.len() - 1) as u32
            }
        };
        self.peak_in_use = self.peak_in_use.max(self.in_use_blocks());
        Some(id)
    }

    /// Return a block to the free list. Contents are left stale on
    /// purpose (see module docs — gather never reads unwritten rows).
    pub fn release(&mut self, id: u32) {
        debug_assert!((id as usize) < self.blocks.len());
        debug_assert!(!self.free.contains(&id), "double release of block {id}");
        self.free.push(id);
    }

    /// One layer's K rows `[r0, r1)` of a block, contiguous.
    fn k_rows(&self, id: u32, layer: usize, r0: usize, r1: usize) -> &[f32] {
        let base = (layer * 2) * self.block_tokens * self.d_kv;
        &self.blocks[id as usize][base + r0 * self.d_kv..base + r1 * self.d_kv]
    }

    fn v_rows(&self, id: u32, layer: usize, r0: usize, r1: usize) -> &[f32] {
        let base = (layer * 2 + 1) * self.block_tokens * self.d_kv;
        &self.blocks[id as usize][base + r0 * self.d_kv..base + r1 * self.d_kv]
    }

    fn k_rows_mut(
        &mut self,
        id: u32,
        layer: usize,
        r0: usize,
        r1: usize,
    ) -> &mut [f32] {
        let base = (layer * 2) * self.block_tokens * self.d_kv;
        &mut self.blocks[id as usize]
            [base + r0 * self.d_kv..base + r1 * self.d_kv]
    }

    fn v_rows_mut(
        &mut self,
        id: u32,
        layer: usize,
        r0: usize,
        r1: usize,
    ) -> &mut [f32] {
        let base = (layer * 2 + 1) * self.block_tokens * self.d_kv;
        &mut self.blocks[id as usize]
            [base + r0 * self.d_kv..base + r1 * self.d_kv]
    }
}

/// One sequence's KV: the block table plus its token position. Created
/// empty (zero blocks — nothing is reserved that isn't written yet),
/// grown via [`SeqKv::ensure_tokens`], released via [`SeqKv::release`].
#[derive(Default)]
pub struct SeqKv {
    table: Vec<u32>,
    /// Tokens decoded so far (the KV position).
    pub pos: usize,
}

impl SeqKv {
    pub fn new() -> SeqKv {
        SeqKv::default()
    }

    pub fn blocks_held(&self) -> usize {
        self.table.len()
    }

    /// Bytes this sequence's table holds (blocks-held × block size — the
    /// per-sequence share of the ledger's KV term).
    pub fn bytes(&self, pool: &KvPool) -> u64 {
        self.table.len() as u64 * pool.block_bytes()
    }

    /// Grow the table so it can hold `tokens` tokens. False = the pool
    /// ran dry; blocks already acquired stay held (the table is still
    /// consistent, the caller retries after preemption or gives up).
    pub fn ensure_tokens(&mut self, pool: &mut KvPool, tokens: usize) -> bool {
        let need = pool.blocks_for(tokens);
        while self.table.len() < need {
            match pool.alloc() {
                Some(id) => self.table.push(id),
                None => return false,
            }
        }
        true
    }

    /// Would [`SeqKv::ensure_tokens`]`(pos + 1)` need a fresh block?
    pub fn needs_block_for_next(&self, pool: &KvPool) -> bool {
        pool.blocks_for(self.pos + 1) > self.table.len()
    }

    /// Release every block back to the pool and reset the position (end
    /// of sequence, or the legacy solo-sequence reset).
    pub fn release(&mut self, pool: &mut KvPool) {
        for id in self.table.drain(..) {
            pool.release(id);
        }
        self.pos = 0;
    }

    /// Materialize one layer's contiguous `[max_seq, d_kv]` K/V window
    /// for the attention artifact: rows `0..pos` copied out of the block
    /// table (block-contiguous runs, one `copy_from_slice` per block per
    /// side), the tail zero-filled — bit-identical to the monolithic
    /// zero-initialized buffer the artifact used to receive.
    // pallas-lint: hot-path
    pub fn gather_layer(
        &self,
        pool: &KvPool,
        layer: usize,
        pos: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let d = pool.d_kv;
        let bt = pool.block_tokens;
        debug_assert!(self.table.len() >= pool.blocks_for(pos));
        let mut t = 0usize;
        for &id in &self.table {
            if t >= pos {
                break;
            }
            let n = bt.min(pos - t);
            k_out[t * d..(t + n) * d]
                .copy_from_slice(pool.k_rows(id, layer, 0, n));
            v_out[t * d..(t + n) * d]
                .copy_from_slice(pool.v_rows(id, layer, 0, n));
            t += n;
        }
        k_out[pos * d..].fill(0.0);
        v_out[pos * d..].fill(0.0);
    }

    /// Prefix-only gather for the length-bucketed attention path: copy
    /// rows `0..pos` of one layer out of the block table and touch
    /// **nothing else** — no zero tail. The caller (the engine's bucketed
    /// attention) owns tail hygiene via its scratch high-water mark, so
    /// the O(max_seq·d_kv) per-step memset [`SeqKv::gather_layer`] pays
    /// becomes a once-per-bucket-growth cost. Blocks are zeroed by
    /// [`KvPool::alloc`] on (re)materialization, so rows `0..pos` can
    /// never read another sequence's stale data (property-tested below).
    // pallas-lint: hot-path
    pub fn gather_layer_prefix(
        &self,
        pool: &KvPool,
        layer: usize,
        pos: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let d = pool.d_kv;
        let bt = pool.block_tokens;
        debug_assert!(self.table.len() >= pool.blocks_for(pos));
        debug_assert!(k_out.len() >= pos * d && v_out.len() >= pos * d);
        let mut t = 0usize;
        for &id in &self.table {
            if t >= pos {
                break;
            }
            let n = bt.min(pos - t);
            k_out[t * d..(t + n) * d]
                .copy_from_slice(pool.k_rows(id, layer, 0, n));
            v_out[t * d..(t + n) * d]
                .copy_from_slice(pool.v_rows(id, layer, 0, n));
            t += n;
        }
    }

    /// Scatter the single row the attention artifact wrote — position
    /// `pos` of one layer — back into its owning block. Rows `0..pos`
    /// were *sourced from the table* by the preceding gather and pass
    /// through `attn_core` unchanged, so writing only the new row keeps
    /// the table bit-identical to the old store-the-whole-buffer path at
    /// O(d_kv) per layer instead of O(pos · d_kv). The table must
    /// already cover `pos + 1` tokens (`ensure_tokens`).
    pub fn scatter_row(
        &self,
        pool: &mut KvPool,
        layer: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let d = pool.d_kv;
        let bt = pool.block_tokens;
        debug_assert!(self.table.len() >= pool.blocks_for(pos + 1));
        let id = self.table[pos / bt];
        let r = pos % bt;
        pool.k_rows_mut(id, layer, r, r + 1)
            .copy_from_slice(&k[pos * d..(pos + 1) * d]);
        pool.v_rows_mut(id, layer, r, r + 1)
            .copy_from_slice(&v[pos * d..(pos + 1) * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, GenExt};

    fn pool() -> KvPool {
        // 2 layers, d_kv 4, 3 tokens per block
        KvPool::new(3, 2, 4)
    }

    #[test]
    fn block_geometry() {
        let p = pool();
        assert_eq!(p.block_bytes(), (2 * 2 * 3 * 4 * 4) as u64);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(3), 1);
        assert_eq!(p.blocks_for(4), 2);
        assert_eq!(p.blocks_for(6), 2);
        assert_eq!(p.blocks_for(7), 3);
    }

    #[test]
    fn alloc_release_recycles_and_respects_capacity() {
        let mut p = pool();
        p.set_capacity_blocks(2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.free_blocks(), 0);
        assert!(p.alloc().is_none(), "ceiling must refuse the third block");
        assert_eq!(p.stats().alloc_failures, 1);
        p.release(a);
        assert_eq!(p.free_blocks(), 1);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block is recycled, not re-allocated");
        assert_eq!(p.in_use_blocks(), 2);
        assert_eq!(p.stats().peak_blocks, 2);
        assert_eq!(p.in_use_bytes(), 2 * p.block_bytes());
    }

    #[test]
    fn capacity_shrink_below_in_use_blocks_allocs_only() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        p.set_capacity_blocks(1);
        assert_eq!(p.in_use_blocks(), 2, "held blocks survive the shrink");
        assert_eq!(p.free_blocks(), 0);
        assert!(p.alloc().is_none());
        p.release(a);
        // still AT the shrunk ceiling (1 in use = capacity 1): recycled
        // storage must not sneak past the governor's new budget
        assert_eq!(p.in_use_blocks(), 1);
        assert!(p.alloc().is_none(), "ceiling binds recycled blocks too");
        p.release(b);
        let c = p.alloc().expect("under the ceiling again");
        assert!(c == a || c == b, "served from the free list");
        assert_eq!(p.in_use_blocks(), 1);
    }

    #[test]
    fn ledger_counts_resident_storage_and_shrink_releases_it() {
        // Freed blocks stay resident (recycling) and the ledger must say
        // so; a governor capacity shrink is what actually returns DRAM.
        let mut p = pool();
        let mut s = SeqKv::new();
        assert!(s.ensure_tokens(&mut p, 9)); // 3 blocks
        assert_eq!(p.resident_bytes(), 3 * p.block_bytes());
        s.release(&mut p);
        assert_eq!(p.in_use_blocks(), 0);
        assert_eq!(
            p.resident_bytes(),
            3 * p.block_bytes(),
            "freed storage parks for reuse — still resident DRAM"
        );
        p.set_capacity_blocks(1); // governor shrink
        assert_eq!(
            p.resident_bytes(),
            p.block_bytes(),
            "shrink trims parked storage down to the new ceiling"
        );
        // the surviving parked block still serves, the ceiling holds,
        // and growing the ceiling back re-materializes storage lazily
        let mut s2 = SeqKv::new();
        assert!(s2.ensure_tokens(&mut p, 3));
        assert!(!s2.ensure_tokens(&mut p, 4), "ceiling holds at 1 block");
        p.set_capacity_blocks(3);
        assert!(s2.ensure_tokens(&mut p, 9), "emptied blocks re-materialize");
        assert_eq!(p.resident_bytes(), 3 * p.block_bytes());
        // a re-materialized block reads as zeros through gather
        let mut k = vec![1f32; 12 * 4];
        let mut v = vec![1f32; 12 * 4];
        s2.gather_layer(&p, 0, 0, &mut k, &mut v);
        assert!(k.iter().all(|&x| x == 0.0));
        s2.release(&mut p);
    }

    #[test]
    fn seq_grows_on_demand_and_releases_everything() {
        let mut p = pool();
        p.set_capacity_blocks(3);
        let mut s = SeqKv::new();
        assert_eq!(s.blocks_held(), 0, "nothing reserved up front");
        assert!(s.ensure_tokens(&mut p, 1));
        assert_eq!(s.blocks_held(), 1);
        assert!(s.ensure_tokens(&mut p, 3), "same block covers 3 tokens");
        assert_eq!(s.blocks_held(), 1);
        assert!(!s.needs_block_for_next(&p), "block 1 covers token 1");
        s.pos = 3;
        assert!(s.needs_block_for_next(&p), "token 4 needs block 2");
        assert!(s.ensure_tokens(&mut p, 9));
        assert_eq!(s.blocks_held(), 3);
        assert_eq!(s.bytes(&p), 3 * p.block_bytes());
        assert!(!s.ensure_tokens(&mut p, 10), "pool dry at the ceiling");
        assert_eq!(s.blocks_held(), 3, "failed grow keeps the table intact");
        s.release(&mut p);
        assert_eq!(s.blocks_held(), 0);
        assert_eq!(s.pos, 0);
        assert_eq!(p.in_use_blocks(), 0, "free-count invariant");
    }

    #[test]
    fn gather_scatter_roundtrip_matches_plain_buffer_reference() {
        // The bit-safety core: for random step traffic, the block-table
        // materialization must equal a plain monolithic [max_seq, d_kv]
        // buffer driven by the same writes.
        check("kvpool-gather-scatter", |g| {
            let bt = g.usize_in(1, 5);
            let n_layers = g.usize_in(1, 3);
            let d = g.usize_in(1, 6);
            let max_seq = g.usize_in(4, 12);
            let mut pool = KvPool::new(bt, n_layers, d);
            let mut seq = SeqKv::new();
            // reference per layer: K and V monolithic buffers
            let mut ref_k = vec![vec![0f32; max_seq * d]; n_layers];
            let mut ref_v = vec![vec![0f32; max_seq * d]; n_layers];
            let mut k_scr = vec![0f32; max_seq * d];
            let mut v_scr = vec![0f32; max_seq * d];
            let steps = g.usize_in(1, max_seq);
            for pos in 0..steps {
                if !seq.ensure_tokens(&mut pool, pos + 1) {
                    return Err("unbounded pool refused a block".into());
                }
                for l in 0..n_layers {
                    seq.gather_layer(&pool, l, pos, &mut k_scr, &mut v_scr);
                    if k_scr != ref_k[l] || v_scr != ref_v[l] {
                        return Err(format!(
                            "gather diverged at pos {pos} layer {l}"
                        ));
                    }
                    // the "artifact": write row pos with fresh values (and
                    // leave earlier rows as-is, like attn_core)
                    for j in 0..d {
                        let kv = (pos * 131 + l * 17 + j) as f32;
                        k_scr[pos * d + j] = kv;
                        v_scr[pos * d + j] = -kv;
                    }
                    ref_k[l][..(pos + 1) * d]
                        .copy_from_slice(&k_scr[..(pos + 1) * d]);
                    ref_v[l][..(pos + 1) * d]
                        .copy_from_slice(&v_scr[..(pos + 1) * d]);
                    seq.scatter_row(&mut pool, l, pos, &k_scr, &v_scr);
                }
                seq.pos = pos + 1;
            }
            // a second sequence reusing released blocks must not see
            // stale data through its own gather
            let held = seq.blocks_held();
            seq.release(&mut pool);
            if pool.in_use_blocks() != 0 {
                return Err("release leaked blocks".into());
            }
            let mut s2 = SeqKv::new();
            if !s2.ensure_tokens(&mut pool, held.max(1) * bt) {
                return Err("re-alloc failed".into());
            }
            s2.gather_layer(&pool, 0, 0, &mut k_scr, &mut v_scr);
            if k_scr.iter().any(|&x| x != 0.0) {
                return Err("gather of an unwritten seq must be zeros".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prefix_gather_with_highwater_tail_never_leaks_stale_rows() {
        // Satellite: the bucketed attention path replaces gather_layer's
        // per-step O(max_seq·d) zero tail with caller-side hygiene — the
        // engine keeps a scratch high-water mark `dirty` (rows >= dirty
        // are zero) and zeroes only `pos..dirty` before a step. Drive
        // random decode traffic through that exact discipline, across
        // `release`/re-`alloc` of the same blocks by later sequences,
        // and require every [cap, d] window handed to the "artifact" to
        // be bit-identical to the monolithic zero-tailed gather.
        check("kvpool-prefix-gather", |g| {
            let bt = g.usize_in(1, 5);
            let d = g.usize_in(1, 6);
            let max_seq = g.usize_in(4, 16);
            let mut pool = KvPool::new(bt, 1, d);
            // shared engine scratch + its high-water mark, persisting
            // across sequences (that is where stale data would leak from)
            let mut k_scr: Vec<f32> = Vec::new();
            let mut v_scr: Vec<f32> = Vec::new();
            let mut dirty = 0usize;
            let mut ref_k = vec![0f32; max_seq * d];
            let mut ref_v = vec![0f32; max_seq * d];
            for s in 0..g.usize_in(2, 4) {
                let mut seq = SeqKv::new();
                let steps = g.usize_in(1, max_seq);
                for pos in 0..steps {
                    if !seq.ensure_tokens(&mut pool, pos + 1) {
                        return Err("unbounded pool refused a block".into());
                    }
                    let cap = (pos + 1).next_power_of_two().min(max_seq);
                    // engine discipline: grow scratch zero-filled, prefix
                    // gather, zero only the pos..dirty stale band
                    if k_scr.len() < cap * d {
                        k_scr.resize(cap * d, 0.0);
                        v_scr.resize(cap * d, 0.0);
                    }
                    seq.gather_layer_prefix(
                        &pool, 0, pos, &mut k_scr, &mut v_scr,
                    );
                    if dirty > pos {
                        let hi = (dirty * d).min(k_scr.len());
                        k_scr[pos * d..hi].fill(0.0);
                        v_scr[pos * d..hi].fill(0.0);
                    }
                    // reference: the monolithic zero-tailed gather
                    seq.gather_layer(&pool, 0, pos, &mut ref_k, &mut ref_v);
                    if k_scr[..cap * d] != ref_k[..cap * d]
                        || v_scr[..cap * d] != ref_v[..cap * d]
                    {
                        return Err(format!(
                            "seq {s} pos {pos} cap {cap}: bucketed window \
                             diverged from monolithic gather"
                        ));
                    }
                    // "artifact" writes row pos; everything past cap is
                    // dropped (lit_to_f32 resizes scratch to the window)
                    for j in 0..d {
                        let kv = (s * 977 + pos * 131 + j) as f32 + 1.0;
                        k_scr[pos * d + j] = kv;
                        v_scr[pos * d + j] = -kv;
                    }
                    k_scr.truncate(cap * d);
                    v_scr.truncate(cap * d);
                    dirty = pos + 1;
                    seq.scatter_row(&mut pool, 0, pos, &k_scr, &v_scr);
                    seq.pos = pos + 1;
                }
                // release -> the next sequence re-allocs the same blocks
                seq.release(&mut pool);
                if pool.in_use_blocks() != 0 {
                    return Err("release leaked blocks".into());
                }
            }
            Ok(())
        });
    }
}
