//! Metrics: decode counters, latency tracking, and the activity-based
//! energy model that substitutes for the paper's on-device power rails
//! (Fig 19 — see DESIGN.md §1).

use std::time::Duration;

use crate::device::DeviceProfile;
use crate::trace::Histo;

/// Per-decode aggregate counters, filled by the engine.
#[derive(Debug, Default, Clone)]
pub struct DecodeMetrics {
    pub tokens: u64,
    pub wall: Duration,
    /// Modeled/actual time the CPU spent computing.
    pub compute_busy: Duration,
    /// Modeled time the flash channel was busy.
    pub flash_busy: Duration,
    /// Bytes loaded from flash (on-demand + preload).
    pub flash_bytes: u64,
    /// Bytes served from the weight cache.
    pub cache_bytes: u64,
    /// DRAM traffic of the compute kernels (≈ active weight bytes touched).
    pub dram_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Channels correctly preloaded / total needed (preload precision).
    pub preload_hits: u64,
    pub preload_total: u64,
    // ---- hot-path bookkeeping counters (slab-store fetch path, PERF.md)
    /// WeightCache mutex acquisitions by the fetch path (one per op-family
    /// fetch is the invariant — lookups, slab copies, batched inserts, and
    /// on-demand fills all share a single guard).
    pub cache_lock_acquires: u64,
    /// Acquisitions the old per-row path would have taken minus what the
    /// batched path took (per-op lookup locks + one lock per row offered).
    pub cache_locks_avoided: u64,
    /// `insert_rows` batches issued (each replaces N per-row lock+insert).
    pub batched_inserts: u64,
    /// Rows filled by on-demand flash reads (preload/cache misses).
    pub ondemand_rows: u64,
    /// On-demand reads that bundled ≥2 adjacent channels into one I/O.
    pub ondemand_coalesced_runs: u64,
    /// High-water mark of the preload slab store (M_cl peak, bytes).
    pub slab_bytes_peak: u64,
    // ---- async flash read path (shared ReadQueue, PERF.md)
    /// Read-queue submission waves issued (each amortizes the device's
    /// fixed latency across up to queue-depth reads).
    pub io_batches: u64,
    /// Peak reads in flight through the queue (≤ the queue depth).
    pub io_inflight_peak: u64,
    /// Time the preload **loader** spent blocked reaping queue
    /// completions — background wait, usually hidden behind compute.
    pub io_wait_loader: Duration,
    /// Time the **engine**'s on-demand fetches spent blocked reaping —
    /// always on the decoded token's critical path. The old single
    /// `io_wait` counter was the sum of both and could not tell preload
    /// reaping from miss stalls (ROADMAP).
    pub io_wait_engine: Duration,
    /// Read buffers served from the queue's recycle pool instead of a
    /// fresh allocation.
    pub io_buffers_recycled: u64,
    // ---- fault-injection / recovery-ladder counters (flash + engine)
    /// Faults the injection layer fired (transient + permanent + spikes
    /// + stalls), from the flash device's counter.
    pub faults_injected: u64,
    /// Transient read errors retried inside the ReadQueue (invisible to
    /// callers unless the retry budget is exhausted).
    pub io_retries: u64,
    /// Wedged ReadQueue workers detected and replaced by the watchdog.
    pub wedged_recoveries: u64,
    /// Rows the engine expected from a preload slab but had to fetch
    /// via urgent on-demand reads instead (the degraded-mode row count).
    pub fallback_rows: u64,
    /// Op-family fetches where a preload part completed but published no
    /// slab at all (failed/throttled part) — each is one degraded-mode
    /// event, coarser than `fallback_rows`.
    pub degraded_fallbacks: u64,
    // ---- runtime DRAM governor counters (governor module)
    /// Re-budget decisions applied to the live engine.
    pub rebudgets_applied: u64,
    /// Re-budget events gated off (hysteresis) or infeasible.
    pub rebudgets_skipped: u64,
    /// Rows evicted by governor-driven cache shrinks.
    pub rebudget_rows_evicted: u64,
    /// Active-sparsity-level artifact switches.
    pub level_switches: u64,
    /// Total wall time spent applying re-budget plans.
    pub rebudget_settle: Duration,
    // ---- continuous-batching scheduler counters (sched module)
    /// Scheduler waves run (one token per live sequence per wave).
    pub sched_waves: u64,
    /// Total wall time inside scheduler waves (per-wave latency =
    /// `sched_wave_time / sched_waves`).
    pub sched_wave_time: Duration,
    /// Sequences admitted to the run queue (fresh admissions; a resumed
    /// preemption re-admission counts again).
    pub seqs_admitted: u64,
    /// Sequences that spent time in the wait queue (admission control
    /// deferred them at least once).
    pub seqs_queued: u64,
    /// Sequences rejected outright (wait queue full / bad request).
    pub seqs_rejected: u64,
    /// Sequences preempted by a shrinking KV budget (KV freed; resumed
    /// later by recompute).
    pub seqs_preempted: u64,
    /// Sequences retired complete (EOS / token limit / KV limit).
    pub seqs_completed: u64,
    /// Cross-token group-0 preload chains issued at inter-token
    /// boundaries (interleaved decode keeps the flash queue saturated
    /// with these).
    pub cross_token_preloads: u64,
    // ---- paged KV pool counters (kvpool module)
    /// High-water mark of KV blocks in use across all live sequences
    /// (the realized M_kv peak in blocks).
    pub kv_blocks_peak: u64,
    /// Sequences preempted because the KV block pool ran dry mid-wave
    /// (newest-first; distinct from budget-ceiling preemptions, which
    /// count only under `seqs_preempted`).
    pub kv_preemptions_oom: u64,
    // ---- kernel hot-path counters (bucketed attention + block-kernel
    //      dequant, PERF.md "Kernel hot paths")
    /// Host-side bytes moved per attention window: gathered prefix rows,
    /// stale-band/tail zeroing, literal upload + download of both cache
    /// sides, and the one-row scatter-back. Bucketing exists to shrink
    /// this — the monolithic path pays the full `[max_seq, d_kv]` window
    /// every step.
    pub host_copy_bytes: u64,
    /// Largest attention window cap executed (`attn_core_<cap>` bucket,
    /// or `max_seq` on the monolithic path). A peak, merged as a max.
    pub attn_bucket_cap: u64,
    /// Rows dequantized through the vectorized block kernels
    /// (`layout::quant::dequantize_row`): loader slab fills + on-demand
    /// engine fetches.
    pub dequant_rows_vectorized: u64,
    /// Union-allocation bytes avoided by the loader's per-span sub-slab
    /// split on straddling layout partitions (delta-folded from
    /// `LoaderStats::subslab_waste_bytes`).
    pub subslab_waste_bytes: u64,
    // ---- latency histograms (trace module; always on — fixed-size,
    //      allocation-free, so the hot path records unconditionally)
    /// Inter-token latency in µs: per-step wall time on the solo path,
    /// per-sequence inter-token gaps on the scheduler path.
    pub h_itl_us: Histo,
    /// Scheduler wave wall time in µs.
    pub h_wave_us: Histo,
    /// Admission queue wait in µs (recorded when a sequence activates).
    pub h_admission_wait_us: Histo,
    /// On-demand flash fill latency in µs (the miss path inside a
    /// family fetch — always on the token's critical path).
    pub h_ondemand_us: Histo,
}

impl DecodeMetrics {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.tokens as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn cache_hit_rate(&self) -> f64 {
        let t = self.cache_hits + self.cache_misses;
        if t == 0 {
            0.0
        } else {
            self.cache_hits as f64 / t as f64
        }
    }

    pub fn preload_precision(&self) -> f64 {
        if self.preload_total == 0 {
            0.0
        } else {
            self.preload_hits as f64 / self.preload_total as f64
        }
    }

    pub fn merge(&mut self, other: &DecodeMetrics) {
        self.tokens += other.tokens;
        self.wall += other.wall;
        self.compute_busy += other.compute_busy;
        self.flash_busy += other.flash_busy;
        self.flash_bytes += other.flash_bytes;
        self.cache_bytes += other.cache_bytes;
        self.dram_bytes += other.dram_bytes;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.preload_hits += other.preload_hits;
        self.preload_total += other.preload_total;
        self.cache_lock_acquires += other.cache_lock_acquires;
        self.cache_locks_avoided += other.cache_locks_avoided;
        self.batched_inserts += other.batched_inserts;
        self.ondemand_rows += other.ondemand_rows;
        self.ondemand_coalesced_runs += other.ondemand_coalesced_runs;
        // a peak merges as a max, not a sum
        self.slab_bytes_peak = self.slab_bytes_peak.max(other.slab_bytes_peak);
        self.io_batches += other.io_batches;
        self.io_inflight_peak =
            self.io_inflight_peak.max(other.io_inflight_peak);
        self.io_wait_loader += other.io_wait_loader;
        self.io_wait_engine += other.io_wait_engine;
        self.io_buffers_recycled += other.io_buffers_recycled;
        self.faults_injected += other.faults_injected;
        self.io_retries += other.io_retries;
        self.wedged_recoveries += other.wedged_recoveries;
        self.fallback_rows += other.fallback_rows;
        self.degraded_fallbacks += other.degraded_fallbacks;
        self.rebudgets_applied += other.rebudgets_applied;
        self.rebudgets_skipped += other.rebudgets_skipped;
        self.rebudget_rows_evicted += other.rebudget_rows_evicted;
        self.level_switches += other.level_switches;
        self.rebudget_settle += other.rebudget_settle;
        self.sched_waves += other.sched_waves;
        self.sched_wave_time += other.sched_wave_time;
        self.seqs_admitted += other.seqs_admitted;
        self.seqs_queued += other.seqs_queued;
        self.seqs_rejected += other.seqs_rejected;
        self.seqs_preempted += other.seqs_preempted;
        self.seqs_completed += other.seqs_completed;
        self.cross_token_preloads += other.cross_token_preloads;
        self.kv_blocks_peak = self.kv_blocks_peak.max(other.kv_blocks_peak);
        self.kv_preemptions_oom += other.kv_preemptions_oom;
        self.host_copy_bytes += other.host_copy_bytes;
        self.attn_bucket_cap = self.attn_bucket_cap.max(other.attn_bucket_cap);
        self.dequant_rows_vectorized += other.dequant_rows_vectorized;
        self.subslab_waste_bytes += other.subslab_waste_bytes;
        self.h_itl_us.merge(&other.h_itl_us);
        self.h_wave_us.merge(&other.h_wave_us);
        self.h_admission_wait_us.merge(&other.h_admission_wait_us);
        self.h_ondemand_us.merge(&other.h_ondemand_us);
    }

    /// Total reaper wait (both classes) — the old single `io_wait`.
    pub fn io_wait_total(&self) -> Duration {
        self.io_wait_loader + self.io_wait_engine
    }
}

/// Activity-based energy model (paper §7.4 substitution): integrate the
/// device's power rails over the busy fractions of a decode.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReport {
    /// Average power over the decode (W).
    pub avg_power_w: f64,
    /// Energy per token (J/token).
    pub energy_per_token_j: f64,
    pub compute_fraction: f64,
    pub flash_fraction: f64,
}

pub fn energy(dev: &DeviceProfile, m: &DecodeMetrics) -> EnergyReport {
    let wall = m.wall.as_secs_f64().max(1e-9);
    let fc = (m.compute_busy.as_secs_f64() / wall).min(1.0);
    let ff = (m.flash_busy.as_secs_f64() / wall).min(1.0);
    // DRAM rail scales with achieved bandwidth fraction.
    let fd = (m.dram_bytes as f64 / wall / dev.mem_bw).min(1.0);
    let p = dev.power;
    let avg = p.idle_w + fc * p.compute_w + ff * p.flash_w + fd * p.dram_w;
    EnergyReport {
        avg_power_w: avg,
        energy_per_token_j: if m.tokens == 0 {
            0.0
        } else {
            avg * wall / m.tokens as f64
        },
        compute_fraction: fc,
        flash_fraction: ff,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PIXEL6;

    fn m(tokens: u64, wall_ms: u64, comp_ms: u64, flash_ms: u64) -> DecodeMetrics {
        DecodeMetrics {
            tokens,
            wall: Duration::from_millis(wall_ms),
            compute_busy: Duration::from_millis(comp_ms),
            flash_busy: Duration::from_millis(flash_ms),
            ..Default::default()
        }
    }

    #[test]
    fn tokens_per_sec() {
        assert_eq!(m(10, 1000, 0, 0).tokens_per_sec(), 10.0);
    }

    #[test]
    fn idle_decode_draws_idle_power() {
        let r = energy(&PIXEL6, &m(1, 1000, 0, 0));
        assert!((r.avg_power_w - PIXEL6.power.idle_w).abs() < 1e-9);
    }

    #[test]
    fn busy_decode_draws_more() {
        let idle = energy(&PIXEL6, &m(1, 1000, 0, 0));
        let busy = energy(&PIXEL6, &m(1, 1000, 1000, 1000));
        assert!(busy.avg_power_w > idle.avg_power_w + 2.0);
    }

    #[test]
    fn overlap_reduces_power_vs_serial() {
        // Same work, overlapped (shorter wall) vs serial: the paper's Fig 19
        // point is average power drops ~27% because compute waits less.
        let serial = m(1, 2000, 1000, 1000);
        let overlap = m(1, 1100, 1000, 1000);
        let es = energy(&PIXEL6, &serial);
        let eo = energy(&PIXEL6, &overlap);
        // overlapped run has higher avg power but lower energy/token
        assert!(eo.energy_per_token_j < es.energy_per_token_j);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = m(5, 100, 50, 20);
        a.merge(&m(5, 100, 50, 20));
        assert_eq!(a.tokens, 10);
        assert_eq!(a.wall, Duration::from_millis(200));
    }

    #[test]
    fn merge_sums_lock_counters_and_maxes_slab_peak() {
        let mut a = m(1, 100, 0, 0);
        a.cache_lock_acquires = 4;
        a.cache_locks_avoided = 10;
        a.batched_inserts = 2;
        a.ondemand_rows = 3;
        a.ondemand_coalesced_runs = 1;
        a.slab_bytes_peak = 4096;
        let mut b = m(1, 100, 0, 0);
        b.cache_lock_acquires = 6;
        b.cache_locks_avoided = 5;
        b.batched_inserts = 1;
        b.ondemand_rows = 2;
        b.ondemand_coalesced_runs = 2;
        b.slab_bytes_peak = 1024;
        a.io_batches = 3;
        a.io_inflight_peak = 4;
        a.io_wait_loader = Duration::from_millis(2);
        a.io_wait_engine = Duration::from_millis(4);
        a.io_buffers_recycled = 5;
        b.io_batches = 2;
        b.io_inflight_peak = 9;
        b.io_wait_loader = Duration::from_millis(1);
        b.io_wait_engine = Duration::from_millis(2);
        b.io_buffers_recycled = 3;
        a.faults_injected = 2;
        a.io_retries = 1;
        a.fallback_rows = 4;
        b.faults_injected = 3;
        b.io_retries = 2;
        b.wedged_recoveries = 1;
        b.fallback_rows = 2;
        b.degraded_fallbacks = 1;
        b.sched_waves = 4;
        b.sched_wave_time = Duration::from_millis(8);
        b.seqs_admitted = 3;
        b.seqs_queued = 2;
        b.seqs_preempted = 1;
        b.seqs_completed = 3;
        b.cross_token_preloads = 6;
        b.rebudgets_applied = 2;
        b.rebudgets_skipped = 1;
        b.rebudget_rows_evicted = 7;
        b.level_switches = 1;
        b.rebudget_settle = Duration::from_millis(3);
        a.kv_blocks_peak = 7;
        b.kv_blocks_peak = 5;
        b.kv_preemptions_oom = 2;
        a.host_copy_bytes = 1000;
        a.attn_bucket_cap = 64;
        a.dequant_rows_vectorized = 11;
        b.host_copy_bytes = 500;
        b.attn_bucket_cap = 32;
        b.dequant_rows_vectorized = 4;
        b.subslab_waste_bytes = 2048;
        a.merge(&b);
        assert_eq!(a.cache_lock_acquires, 10);
        assert_eq!(a.cache_locks_avoided, 15);
        assert_eq!(a.batched_inserts, 3);
        assert_eq!(a.ondemand_rows, 5);
        assert_eq!(a.ondemand_coalesced_runs, 3);
        assert_eq!(a.slab_bytes_peak, 4096, "peak is a max, not a sum");
        assert_eq!(a.io_batches, 5);
        assert_eq!(a.io_inflight_peak, 9, "inflight peak is a max");
        assert_eq!(a.io_wait_loader, Duration::from_millis(3));
        assert_eq!(a.io_wait_engine, Duration::from_millis(6));
        assert_eq!(a.io_wait_total(), Duration::from_millis(9));
        assert_eq!(a.io_buffers_recycled, 8);
        assert_eq!(a.faults_injected, 5);
        assert_eq!(a.io_retries, 3);
        assert_eq!(a.wedged_recoveries, 1);
        assert_eq!(a.fallback_rows, 6);
        assert_eq!(a.degraded_fallbacks, 1);
        assert_eq!(a.sched_waves, 4);
        assert_eq!(a.sched_wave_time, Duration::from_millis(8));
        assert_eq!(a.seqs_admitted, 3);
        assert_eq!(a.seqs_queued, 2);
        assert_eq!(a.seqs_preempted, 1);
        assert_eq!(a.seqs_completed, 3);
        assert_eq!(a.cross_token_preloads, 6);
        assert_eq!(a.rebudgets_applied, 2);
        assert_eq!(a.rebudgets_skipped, 1);
        assert_eq!(a.rebudget_rows_evicted, 7);
        assert_eq!(a.level_switches, 1);
        assert_eq!(a.rebudget_settle, Duration::from_millis(3));
        assert_eq!(a.kv_blocks_peak, 7, "block peak is a max, not a sum");
        assert_eq!(a.kv_preemptions_oom, 2);
        assert_eq!(a.host_copy_bytes, 1500);
        assert_eq!(a.attn_bucket_cap, 64, "bucket cap is a max, not a sum");
        assert_eq!(a.dequant_rows_vectorized, 15);
        assert_eq!(a.subslab_waste_bytes, 2048);
    }

    #[test]
    fn merge_accumulates_histograms() {
        let mut a = m(1, 100, 0, 0);
        a.h_itl_us.record(100);
        a.h_itl_us.record(200);
        a.h_wave_us.record(50);
        let mut b = m(1, 100, 0, 0);
        b.h_itl_us.record(4000);
        b.h_admission_wait_us.record(7);
        a.merge(&b);
        assert_eq!(a.h_itl_us.count(), 3);
        assert_eq!(a.h_itl_us.max(), 4000);
        assert_eq!(a.h_wave_us.count(), 1);
        assert_eq!(a.h_admission_wait_us.count(), 1);
        assert!(a.h_itl_us.p50() <= a.h_itl_us.p99());
    }

    #[test]
    fn hit_rate_and_precision() {
        let mut d = DecodeMetrics::default();
        d.cache_hits = 3;
        d.cache_misses = 1;
        d.preload_hits = 9;
        d.preload_total = 10;
        assert_eq!(d.cache_hit_rate(), 0.75);
        assert_eq!(d.preload_precision(), 0.9);
    }
}
