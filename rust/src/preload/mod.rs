//! Cross-layer active-weight prediction (paper §3, Fig 8).
//!
//! Exploits the residual-stream similarity observation (§2.2): the Top-K
//! index set computed from the *current* layer's activation predicts the
//! active channels of the next N layers' corresponding operators. Per-op
//! mapping of predictor activation → predicted weights:
//!
//!   attn input (post-norm)  → Wq / Wk / Wv of the next group
//!   attn output             → Wo
//!   mlp input (post-norm)   → Wg / Wu
//!   ffn intermediate        → Wd
//!
//! Channels missed by prediction are fetched by on-demand loading once the
//! actual activation is known (engine), which the paper measures at ~5%.

use crate::layout::OpKind;
use crate::sparsity;

/// A preload request for one op family of one upcoming layer group: load
/// `channels` (ascending) of `op` for every layer in group `group`.
#[derive(Debug, Clone)]
pub struct OpPrediction {
    pub op: OpKind,
    pub channels: Vec<usize>,
}

/// Which ops are predicted from which activation site (shared index sets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActSite {
    AttnInput,  // predicts wq, wk, wv
    AttnOutput, // predicts wo
    MlpInput,   // predicts wg, wu
    FfnInter,   // predicts wd
}

impl ActSite {
    pub fn ops(&self) -> &'static [OpKind] {
        match self {
            ActSite::AttnInput => &[OpKind::Wq, OpKind::Wk, OpKind::Wv],
            ActSite::AttnOutput => &[OpKind::Wo],
            ActSite::MlpInput => &[OpKind::Wg, OpKind::Wu],
            ActSite::FfnInter => &[OpKind::Wd],
        }
    }

    pub const ALL: [ActSite; 4] = [
        ActSite::AttnInput,
        ActSite::AttnOutput,
        ActSite::MlpInput,
        ActSite::FfnInter,
    ];
}

/// Build the per-op predictions for a site from its activation.
pub fn predict(site: ActSite, activation: &[f32], k: usize) -> Vec<OpPrediction> {
    let idx = sparsity::topk_indices(activation, k);
    site.ops()
        .iter()
        .map(|&op| OpPrediction {
            op,
            channels: idx.clone(),
        })
        .collect()
}

/// Rolling tracker of prediction quality + cross-layer similarity — feeds
/// the cost model's `si` parameter and the Fig 4 / Fig 16a benches.
#[derive(Debug, Default, Clone)]
pub struct SimilarityTracker {
    /// Per-site (predicted ∩ actual)/k accumulators.
    hits: [u64; 4],
    total: [u64; 4],
    cos_sum: [f64; 4],
    cos_n: [u64; 4],
    prev: [Option<Vec<f32>>; 4],
}

impl SimilarityTracker {
    fn site_idx(site: ActSite) -> usize {
        ActSite::ALL.iter().position(|s| *s == site).unwrap()
    }

    /// Record the actual activation of `site` at some layer; compares with
    /// the previous layer's activation at the same site.
    pub fn observe(&mut self, site: ActSite, activation: &[f32], k: usize) {
        let i = Self::site_idx(site);
        if let Some(prev) = &self.prev[i] {
            if prev.len() == activation.len() {
                self.cos_sum[i] += sparsity::cosine(prev, activation);
                self.cos_n[i] += 1;
                let pred = sparsity::topk_indices(prev, k);
                let act = sparsity::topk_indices(activation, k);
                let inter = (sparsity::index_overlap(&act, &pred)
                    * act.len() as f64)
                    .round() as u64;
                self.hits[i] += inter;
                self.total[i] += act.len() as u64;
            }
        }
        self.prev[i] = Some(activation.to_vec());
    }

    /// Layer-group boundary in a new sequence: forget the previous layer.
    pub fn reset_layer_chain(&mut self) {
        self.prev = [None, None, None, None];
    }

    /// Average top-k prediction precision across sites (the paper's si).
    pub fn avg_precision(&self) -> f64 {
        let h: u64 = self.hits.iter().sum();
        let t: u64 = self.total.iter().sum();
        if t == 0 {
            0.0
        } else {
            h as f64 / t as f64
        }
    }

    pub fn site_precision(&self, site: ActSite) -> f64 {
        let i = Self::site_idx(site);
        if self.total[i] == 0 {
            0.0
        } else {
            self.hits[i] as f64 / self.total[i] as f64
        }
    }

    pub fn site_cosine(&self, site: ActSite) -> f64 {
        let i = Self::site_idx(site);
        if self.cos_n[i] == 0 {
            0.0
        } else {
            self.cos_sum[i] / self.cos_n[i] as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sites_cover_all_seven_ops() {
        let mut ops: Vec<OpKind> = ActSite::ALL
            .iter()
            .flat_map(|s| s.ops().iter().copied())
            .collect();
        ops.sort();
        ops.dedup();
        assert_eq!(ops.len(), 7);
    }

    #[test]
    fn predict_shares_index_set_across_qkv() {
        let a = [0.1f32, -2.0, 0.5, 3.0, -0.2, 0.05, 1.0, -0.9];
        let preds = predict(ActSite::AttnInput, &a, 3);
        assert_eq!(preds.len(), 3);
        assert_eq!(preds[0].channels, preds[1].channels);
        assert_eq!(preds[0].channels, vec![1, 3, 6]);
    }

    #[test]
    fn tracker_perfect_similarity() {
        let mut t = SimilarityTracker::default();
        let a = [1.0f32, -3.0, 0.2, 2.0];
        t.observe(ActSite::AttnInput, &a, 2);
        t.observe(ActSite::AttnInput, &a, 2); // identical -> precision 1
        assert!((t.avg_precision() - 1.0).abs() < 1e-9);
        assert!((t.site_cosine(ActSite::AttnInput) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tracker_orthogonal_activations() {
        let mut t = SimilarityTracker::default();
        t.observe(ActSite::MlpInput, &[5.0, 5.0, 0.0, 0.0], 2);
        t.observe(ActSite::MlpInput, &[0.0, 0.0, 5.0, 5.0], 2);
        assert_eq!(t.avg_precision(), 0.0);
        assert!(t.site_cosine(ActSite::MlpInput).abs() < 1e-9);
    }

    #[test]
    fn tracker_reset_breaks_chain() {
        let mut t = SimilarityTracker::default();
        t.observe(ActSite::FfnInter, &[1.0, 0.0], 1);
        t.reset_layer_chain();
        t.observe(ActSite::FfnInter, &[1.0, 0.0], 1);
        // only pairs within a chain count
        assert_eq!(t.avg_precision(), 0.0);
    }

    #[test]
    fn first_observation_records_nothing() {
        let mut t = SimilarityTracker::default();
        t.observe(ActSite::AttnOutput, &[1.0, 2.0], 1);
        assert_eq!(t.avg_precision(), 0.0);
        assert_eq!(t.site_cosine(ActSite::AttnOutput), 0.0);
    }
}
