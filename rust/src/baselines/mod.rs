//! Comparison systems (paper §7.1 baselines), all running on the same PJRT
//! substrate so speed/memory comparisons are apples-to-apples:
//!
//! * [`DenseInMemory`] — llama.cpp-like: every weight resident in DRAM,
//!   dense compute via the fused `dense_layer` artifact. The memory
//!   ceiling ActiveFlow exists to break.
//! * `teal_options` — TEAL-like contextual sparsity: Top-K on-demand loads
//!   *after* each activation is known; no prediction, no cross-layer I/O.
//! * `llm_in_flash_options` — LLM-in-a-flash/Ripple-like: co-active
//!   channels clustered within a **single layer** (group_size = 1), load
//!   overlapped with compute.
//! * `activeflow_options` — the full system (cross-layer group N,
//!   contextual cache).
//! * `serial_options` — Fig 15's "serial computation and memory reads"
//!   ablation floor (on-demand, no cache).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cache::{CachePolicy, SharedCache, WeightCache};
use crate::config::ArtifactConfig;
use crate::device::DeviceProfile;
use crate::engine::{EngineOptions, PreloadTrigger, SwapMode};
use crate::flash::ClockMode;
use crate::layout::{quant, AwgfFile, OpKind, TensorId, SPARSE_OPS};
use crate::metrics::DecodeMetrics;
use crate::model::{self, DenseTensors, KvState};
use crate::runtime::{lit_f32, lit_i32_scalar, lit_to_f32, Runtime};

// ------------------------------------------------ named option presets

pub fn activeflow_options(
    sp: f64,
    group_size: usize,
    cache_bytes: u64,
    device: &'static DeviceProfile,
    clock: ClockMode,
    bw_scale: f64,
) -> EngineOptions {
    EngineOptions {
        sparsity: sp,
        group_size,
        swap_mode: SwapMode::Preload,
        cache_bytes,
        cache_policy: CachePolicy::Contextual,
        device,
        clock,
        bw_scale,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

/// TEAL-like: identify-after-activation, no preloading.
pub fn teal_options(
    sp: f64,
    cache_bytes: u64,
    device: &'static DeviceProfile,
    clock: ClockMode,
    bw_scale: f64,
) -> EngineOptions {
    EngineOptions {
        sparsity: sp,
        group_size: 1,
        swap_mode: SwapMode::OnDemand,
        cache_bytes,
        cache_policy: CachePolicy::Contextual,
        device,
        clock,
        bw_scale,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

/// LLM-in-a-flash-like: within-layer clustering = cross-layer machinery
/// with N=1.
pub fn llm_in_flash_options(
    sp: f64,
    cache_bytes: u64,
    device: &'static DeviceProfile,
    clock: ClockMode,
    bw_scale: f64,
) -> EngineOptions {
    EngineOptions {
        sparsity: sp,
        group_size: 1,
        swap_mode: SwapMode::Preload,
        cache_bytes,
        cache_policy: CachePolicy::Contextual,
        device,
        clock,
        bw_scale,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

/// Fig 15 ablation floor: strictly serial compute + loads, no cache.
pub fn serial_options(
    sp: f64,
    device: &'static DeviceProfile,
    clock: ClockMode,
    bw_scale: f64,
) -> EngineOptions {
    EngineOptions {
        sparsity: sp,
        group_size: 1,
        swap_mode: SwapMode::OnDemand,
        cache_bytes: 0,
        cache_policy: CachePolicy::Contextual,
        device,
        clock,
        bw_scale,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

// --------------------------------------------------- dense in-memory

/// llama.cpp-like baseline: the whole (dequantized) model lives in DRAM;
/// decode runs the fused `dense_layer` artifact per layer.
///
/// The resident weights live in the same [`WeightCache`] the swap engine
/// uses (every tensor at full capacity, task-static so nothing ever
/// evicts), and decode fetches them under the **same single-lock
/// op-family discipline** as `SwapEngine::fetch_packed` — one counted
/// `SharedCache` acquisition per family, batched bulk inserts at load.
/// That makes the baseline's `cache_lock_acquires` / `cache_hits` /
/// `cache_bytes` counters directly comparable to ActiveFlow's instead of
/// reading zero (PERF.md).
pub struct DenseInMemory {
    pub cfg: ArtifactConfig,
    rt: Runtime,
    dense: DenseTensors,
    /// Full-capacity resident store: one `TensorCache` per (layer, op).
    cache: Arc<SharedCache>,
    kv: KvState,
    pub metrics: DecodeMetrics,
    pub load_seconds: f64,
    logits: Vec<f32>,
    tmp: Vec<f32>,
}

impl DenseInMemory {
    pub fn open(artifact_dir: &Path) -> Result<DenseInMemory> {
        let cfg = ArtifactConfig::load(artifact_dir)?;
        let awgf = AwgfFile::open(&cfg.weights_file)?;
        let dense = DenseTensors::load(&awgf)?;
        let t0 = Instant::now();

        // The resident store: every (layer, op) tensor at full capacity.
        let mut dims = Vec::new();
        for l in 0..awgf.model.n_layers {
            for op in SPARSE_OPS {
                let info = awgf.op(op);
                dims.push((TensorId::new(l, op), info.d_in, info.d_out));
            }
        }
        let cache = SharedCache::new(WeightCache::new(
            &dims,
            u64::MAX,
            CachePolicy::TaskStatic,
        ));

        // Bulk-load every sparse op dequantized (startup, not per-token):
        // one batched insert_rows per tensor under one lock — the same
        // batched-insert discipline as the swap engine's fetch path.
        let file = std::fs::File::open(awgf.path())?;
        use std::os::unix::fs::FileExt;
        for l in 0..awgf.model.n_layers {
            for op in SPARSE_OPS {
                let info = awgf.op(op);
                let mut w = vec![0f32; info.d_in * info.d_out];
                let mut buf = vec![0u8; info.row_bytes];
                for c in 0..info.d_in {
                    let (off, len) = awgf.row_span(op, l, c);
                    buf.resize(len, 0);
                    file.read_exact_at(&mut buf, off)?;
                    quant::dequantize_row(
                        &buf,
                        awgf.quant,
                        &mut w[c * info.d_out..(c + 1) * info.d_out],
                    );
                }
                let mut c = cache.lock();
                c.insert_rows(
                    TensorId::new(l, op),
                    (0..info.d_in).map(|ch| {
                        (ch, &w[ch * info.d_out..(ch + 1) * info.d_out])
                    }),
                );
            }
        }
        let load_seconds = t0.elapsed().as_secs_f64();

        let mut rt = Runtime::new(artifact_dir)?;
        rt.load("dense_layer")?;
        rt.load("logits")?;
        let kv = KvState::new(&awgf.model);
        Ok(DenseInMemory {
            logits: vec![0.0; cfg.model.vocab_size],
            tmp: Vec::new(),
            cfg,
            rt,
            dense,
            cache,
            kv,
            metrics: DecodeMetrics::default(),
            load_seconds,
        })
    }

    pub fn reset_sequence(&mut self) {
        self.kv.reset();
    }

    /// Fetch one op family's full matrices as literals under exactly
    /// **one** counted `WeightCache` acquisition (PERF.md single-lock
    /// fetch discipline, dense flavor: every channel is a cache hit, so
    /// the family's literals are built straight off the contiguous
    /// resident store — no copy into an intermediate packed buffer).
    fn fetch_family(
        &mut self,
        layer: usize,
        ops: &[OpKind],
    ) -> Result<Vec<xla::Literal>> {
        self.metrics.cache_lock_acquires += 1;
        self.metrics.cache_locks_avoided += ops.len() as u64 - 1;
        let mut guard = self.cache.lock();
        let mut lits = Vec::with_capacity(ops.len());
        for &op in ops {
            let tc = guard.tensor_mut(TensorId::new(layer, op));
            let (din, dout) = (tc.d_in, tc.row_len);
            tc.hits += din as u64;
            self.metrics.cache_hits += din as u64;
            let bytes = (din * dout * 4) as u64;
            self.metrics.cache_bytes += bytes;
            // DRAM traffic: the full matrix streams to the ALU
            self.metrics.dram_bytes += bytes;
            lits.push(lit_f32(
                tc.packed_rows(),
                &[din as i64, dout as i64],
            )?);
        }
        Ok(lits)
    }

    pub fn decode_token(&mut self, token: u32) -> Result<&[f32]> {
        let m = self.cfg.model.clone();
        let pos = self.kv.pos;
        if pos >= m.max_seq {
            return Err(anyhow!("sequence exceeds max_seq"));
        }
        let t0 = Instant::now();
        let busy0 = self.rt.total_busy();
        let mut x = self.dense.embedding(&m, token).to_vec();
        let (d, dkv, s) = (
            m.d_model as i64,
            m.d_kv() as i64,
            m.max_seq as i64,
        );
        for l in 0..m.n_layers {
            // the same four op-family fetches per layer as the swap
            // engine, each one lock acquisition
            let qkv =
                self.fetch_family(l, &[OpKind::Wq, OpKind::Wk, OpKind::Wv])?;
            let o = self.fetch_family(l, &[OpKind::Wo])?;
            let gu = self.fetch_family(l, &[OpKind::Wg, OpKind::Wu])?;
            let down = self.fetch_family(l, &[OpKind::Wd])?;
            let kvl = &self.kv.layers[l];
            let out = self.rt.exec(
                "dense_layer",
                &[
                    lit_f32(&x, &[1, d])?,
                    qkv[0].clone(),
                    qkv[1].clone(),
                    qkv[2].clone(),
                    o[0].clone(),
                    gu[0].clone(),
                    gu[1].clone(),
                    down[0].clone(),
                    lit_f32(&self.dense.g_attn[l], &[d])?,
                    lit_f32(&self.dense.g_mlp[l], &[d])?,
                    lit_f32(&kvl.k, &[s, dkv])?,
                    lit_f32(&kvl.v, &[s, dkv])?,
                    lit_i32_scalar(pos as i32),
                ],
            )?;
            lit_to_f32(&out[0], &mut self.tmp)?;
            x.copy_from_slice(&self.tmp);
            lit_to_f32(&out[1], &mut self.kv.layers[l].k)?;
            lit_to_f32(&out[2], &mut self.kv.layers[l].v)?;
        }
        self.tmp.resize(m.d_model, 0.0);
        let mut xn = std::mem::take(&mut self.tmp);
        model::rmsnorm(&x, &self.dense.g_final, m.norm_eps, &mut xn);
        let lg = self.rt.exec(
            "logits",
            &[
                lit_f32(&xn, &[1, d])?,
                lit_f32(&self.dense.lm_head, &[d, m.vocab_size as i64])?,
            ],
        )?;
        self.tmp = xn;
        lit_to_f32(&lg[0], &mut self.logits)?;
        self.kv.pos += 1;
        self.metrics.tokens += 1;
        self.metrics.wall += t0.elapsed();
        self.metrics.compute_busy += self.rt.total_busy() - busy0;
        Ok(&self.logits)
    }

    pub fn forced_logits(&mut self, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        self.reset_sequence();
        tokens
            .iter()
            .map(|&t| Ok(self.decode_token(t)?.to_vec()))
            .collect()
    }

    pub fn generate(&mut self, prompt: &[u32], n_gen: usize) -> Result<Vec<u32>> {
        self.reset_sequence();
        let mut last = *prompt.first().ok_or_else(|| anyhow!("empty"))?;
        for (i, &t) in prompt.iter().enumerate() {
            last = t;
            if i + 1 < prompt.len() {
                self.decode_token(t)?;
            }
        }
        let mut out = Vec::with_capacity(n_gen);
        for _ in 0..n_gen {
            let logits = self.decode_token(last)?;
            let next = model::argmax(logits) as u32;
            out.push(next);
            last = next;
        }
        Ok(out)
    }

    /// Resident weight bytes (the llama.cpp memory cost in Fig 14).
    pub fn weight_bytes(&self) -> u64 {
        self.cache.lock().bytes() + self.dense.bytes()
    }

    /// Total counted `WeightCache` acquisitions (single-lock discipline:
    /// 4 per layer per token plus one bulk-insert lock per tensor at
    /// load; comparable to `SwapEngine::cache_lock_acquires_total`).
    pub fn cache_lock_acquires_total(&self) -> u64 {
        self.cache.lock_acquires()
    }

    pub fn perplexity(&mut self, tokens: &[u32]) -> Result<f64> {
        let max_seq = self.cfg.model.max_seq;
        let mut nll = 0.0;
        let mut count = 0usize;
        self.reset_sequence();
        for w in tokens.windows(2) {
            if self.kv.pos >= max_seq {
                self.reset_sequence();
            }
            let logits = self.decode_token(w[0])?;
            nll -= model::log_prob(logits, w[1] as usize);
            count += 1;
        }
        Ok((nll / count as f64).exp())
    }
}
