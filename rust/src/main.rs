//! ActiveFlow CLI — the L3 leader binary.
//!
//! ```text
//! activeflow generate --prompt "..." --n 32 --sp 0.6 --group 4
//!                     [--trace-out trace.json]
//! activeflow eval     --sp 0.6 --windows 4
//! activeflow serve    --addr 127.0.0.1:7071 --sp 0.6 [--budget-mb N]
//!                     [--rebudget-hysteresis F] [--pressure SIZE@TOK,..]
//!                     [--pressure-file PATH] [--max-seqs N]
//!                     [--sched-queue-cap N] [--kv-block-tokens N]
//!                     [--faults seed=1,transient=0.01:2,bad=OFF+LEN,...]
//!                     [--trace-out trace.json] [--telemetry-interval-ms N]
//! activeflow search   --device pixel6 --budget-mb 1500 --geometry llama7b
//! activeflow inspect  devices|artifacts|weights
//! activeflow bench    <pareto|e2e|ablation|flash|preload-tradeoff|
//!                      layer-group|cache-policy|hot-weights|similarity|
//!                      energy|moe-sim|smoke>
//!
//! `bench smoke` writes the perf-trajectory point `BENCH_decode.json`
//! (also reachable as `make bench-smoke`; methodology in PERF.md).
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use activeflow::baselines::{self, DenseInMemory};
use activeflow::bench;
use activeflow::cache::CachePolicy;
use activeflow::config::RuntimeConfig;
use activeflow::costmodel;
use activeflow::device;
use activeflow::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use activeflow::flash::ClockMode;
use activeflow::governor::GovernorConfig;
use activeflow::layout::AwgfFile;
use activeflow::metrics;
use activeflow::server::{serve, ServerConfig};
use activeflow::tokenizer;
use activeflow::util::cli::Args;
use activeflow::util::human_bytes;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

pub fn artifact_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("artifacts", "artifacts"))
}

pub fn engine_options(args: &Args) -> Result<EngineOptions> {
    let sp = args.opt_f64("sp", 0.6)?;
    let device = device::by_name(&args.opt_or("device", "pixel6"))
        .ok_or_else(|| anyhow!("unknown device (oneplus12|pixel6|infinix)"))?;
    let clock = match args.opt_or("mode", "timed").as_str() {
        "timed" => ClockMode::Timed,
        "modeled" => ClockMode::Modeled,
        m => bail!("unknown clock mode '{m}'"),
    };
    let swap_mode = match args.opt_or("swap", "preload").as_str() {
        "preload" => SwapMode::Preload,
        "ondemand" => SwapMode::OnDemand,
        m => bail!("unknown swap mode '{m}'"),
    };
    let policy = match args.opt_or("cache-policy", "context").as_str() {
        "context" => CachePolicy::Contextual,
        "task" => CachePolicy::TaskStatic,
        p => bail!("unknown cache policy '{p}'"),
    };
    Ok(EngineOptions {
        sparsity: sp,
        group_size: args.opt_usize("group", 4)?,
        swap_mode,
        cache_bytes: (args.opt_usize("cache-kb", 256)? as u64) * 1024,
        cache_policy: policy,
        device,
        clock,
        bw_scale: args.opt_f64("bw-scale", 1.0)?,
        trigger: match args.opt_or("trigger", "first").as_str() {
            "first" => PreloadTrigger::FirstLayer,
            "last" => PreloadTrigger::LastLayer,
            t => bail!("unknown preload trigger '{t}' (first|last)"),
        },
        // 0 = the device profile's modeled queue depth
        io_queue_depth: args.opt_usize("io-depth", 0)?,
        // paged KV: tokens per block (a sequence holds ceil(pos/bt)
        // blocks instead of a whole max_seq window)
        kv_block_tokens: args.opt_usize("kv-block-tokens", 16)?.max(1),
        // length-bucketed attention windows; "off" forces the monolithic
        // [max_seq, d_kv] gather (bit-identical either way)
        attn_buckets: args.opt_or("attn-buckets", "on") != "off",
    })
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(args),
        Some("eval") => cmd_eval(args),
        Some("serve") => cmd_serve(args),
        Some("search") => cmd_search(args),
        Some("inspect") => cmd_inspect(args),
        Some("bench") => bench::dispatch(args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            eprintln!(
                "usage: activeflow <generate|eval|serve|search|inspect|bench> \
                 [--artifacts DIR] [--sp F] [--group N] [--cache-kb N] \
                 [--device D] [--mode timed|modeled] [--swap preload|ondemand] \
                 [--io-depth N]"
            );
            Ok(())
        }
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let opts = engine_options(args)?;
    let device = opts.device;
    let dense_baseline = args.has_flag("dense-baseline");
    let prompt = args.opt_or("prompt", "the sparse model swaps active weights. ");
    let n = args.opt_usize("n", 48)?;
    let temp = args.opt_f64("temp", 0.0)? as f32;
    let toks = tokenizer::encode(&prompt);

    if dense_baseline {
        let mut eng = DenseInMemory::open(&artifact_dir(args))?;
        let out = eng.generate(&toks, n)?;
        println!("{}", tokenizer::decode(&out));
        println!(
            "--- dense-in-memory: {:.2} tok/s, weights resident {}",
            eng.metrics.tokens_per_sec(),
            human_bytes(eng.weight_bytes())
        );
        return Ok(());
    }

    let mut eng = SwapEngine::open(&artifact_dir(args), opts)?;
    if let Some(spec) = args.opt("faults") {
        eng.inject_fault_spec(&spec)?;
        eprintln!("[generate] fault injection armed: {spec}");
    }
    // --trace-out: record the whole generation in the flight recorder and
    // dump it as Chrome trace-event JSON (Perfetto-loadable)
    let trace_out = args.opt("trace-out").map(PathBuf::from);
    if trace_out.is_some() {
        eng.trace_handle().set_enabled(true);
    }
    let out = eng.generate(&toks, n, temp)?;
    if let Some(path) = &trace_out {
        let v = activeflow::trace::chrome_trace(eng.trace_handle());
        std::fs::write(path, v.to_string())?;
        eprintln!("[generate] trace written to {}", path.display());
    }
    println!("{}", tokenizer::decode(&out));
    let mem = eng.memory_report();
    let e = metrics::energy(device, &eng.metrics);
    println!(
        "--- activeflow[{}]: {:.2} tok/s | dram {} (dense {} kv {} cache {} \
         preload {}) | cache-hit {:.1}% preload-precision {:.1}% | {:.2} W, \
         {:.3} J/tok",
        eng.sparsity_tag(),
        eng.metrics.tokens_per_sec(),
        human_bytes(mem.dram_total()),
        human_bytes(mem.dense_bytes),
        human_bytes(mem.kv_bytes),
        human_bytes(mem.cache_bytes),
        human_bytes(mem.preload_peak_bytes),
        eng.cache_hit_rate() * 100.0,
        eng.metrics.preload_precision() * 100.0,
        e.avg_power_w,
        e.energy_per_token_j,
    );
    if args.has_flag("profile") {
        println!("--- per-artifact profile (L2/L1 compute inside PJRT):");
        let mut rows = eng.runtime_profile();
        rows.sort_by_key(|(_, _, busy)| std::cmp::Reverse(*busy));
        for (name, calls, busy) in rows {
            println!(
                "    {:<14} {:>6} calls {:>10.2?} total {:>8.1} us/call",
                name,
                calls,
                busy,
                busy.as_secs_f64() * 1e6 / calls.max(1) as f64
            );
        }
        let st = eng.loader_stats();
        println!(
            "    loader: {} chunks, {} read, {:?} flash-busy, {} channels \
             ({} skipped cached)",
            st.chunks_read,
            human_bytes(st.bytes_read),
            st.busy,
            st.channels_loaded,
            st.channels_skipped_cached
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let opts = engine_options(args)?;
    let windows = args.opt_usize("windows", 2)?;
    let toks = tokenizer::eval_corpus();
    let take = (128 * windows + 1).min(toks.len());
    let mut eng = SwapEngine::open(&artifact_dir(args), opts)?;
    let ppl = eng.perplexity(&toks[..take])?;
    println!(
        "perplexity[{}] over {} tokens: {:.4} ({:.2} tok/s, hit-rate {:.1}%)",
        eng.sparsity_tag(),
        take - 1,
        ppl,
        eng.metrics.tokens_per_sec(),
        eng.cache_hit_rate() * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let opts = engine_options(args)?;
    // --budget-mb: hand the runtime DRAM governor an initial M_max; the
    // startup search overrides --sp/--group/--cache-kb with its result.
    let initial_budget = match args.opt_usize("budget-mb", 0)? {
        0 => None,
        mb => Some((mb as u64) << 20),
    };
    // governor + scheduler knobs flow through RuntimeConfig so CLI and
    // file-driven configs share one source of defaults
    let mut rc = RuntimeConfig::default();
    rc.rebudget_hysteresis =
        args.opt_f64("rebudget-hysteresis", rc.rebudget_hysteresis)?;
    rc.pressure_schedule = args.opt("pressure").map(String::from);
    rc.pressure_file = args.opt("pressure-file").map(PathBuf::from);
    rc.max_seqs = args.opt_usize("max-seqs", rc.max_seqs)?.max(1);
    rc.sched_queue_cap =
        args.opt_usize("sched-queue-cap", rc.sched_queue_cap)?;
    rc.kv_block_tokens = opts.kv_block_tokens;
    rc.attn_buckets = opts.attn_buckets;
    rc.fault_spec = args.opt("faults").map(String::from);
    if let Some(spec) = &rc.fault_spec {
        // fail fast on a bad spec — before the engine worker spawns
        activeflow::flash::FaultPlan::parse(spec)?;
    }
    let cfg = ServerConfig {
        addr: args.opt_or("addr", "127.0.0.1:7071"),
        artifact_dir: artifact_dir(args),
        opts,
        governor: GovernorConfig::from_runtime(&rc),
        initial_budget,
        pressure_schedule: rc.pressure_schedule.clone(),
        pressure_file: rc.pressure_file.clone(),
        max_seqs: rc.max_seqs,
        sched_queue_cap: rc.sched_queue_cap,
        fault_spec: rc.fault_spec.clone(),
        trace_out: args.opt("trace-out").map(PathBuf::from),
        telemetry_interval_ms: args
            .opt_usize("telemetry-interval-ms", 500)?
            .max(1) as u64,
    };
    let served = serve(cfg)?;
    println!("[server] shut down after {served} requests");
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let device = device::by_name(&args.opt_or("device", "pixel6"))
        .ok_or_else(|| anyhow!("unknown device"))?;
    let geo = match args.opt_or("geometry", "awgf").as_str() {
        "llama7b" => costmodel::Geometry::llama7b_q4(),
        "llama8b" => costmodel::Geometry::llama8b_q4(),
        "mixtral" => costmodel::Geometry::mixtral8x7b_q4(),
        "awgf" => {
            let cfg =
                activeflow::config::ArtifactConfig::load(&artifact_dir(args))?;
            costmodel::Geometry::from_awgf(&AwgfFile::open(&cfg.weights_file)?)
        }
        g => bail!("unknown geometry '{g}'"),
    };
    let budget = (args.opt_usize("budget-mb", 2048)? as u64) << 20;
    let si = args.opt_f64("similarity", 0.85)?;
    let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
    println!(
        "search: device={} budget={} S_m={} S_l={}",
        device.name,
        human_bytes(budget),
        human_bytes(geo.model_bytes),
        human_bytes(geo.layer_bytes)
    );
    match costmodel::search(device, &geo, budget, si, 1.0, &grid) {
        None => println!("  -> budget below minimum servable configuration"),
        Some(r) => {
            println!(
                "  -> sp={:.2} N={} cache={} | pred mem={} decode={:.1} ms \
                 ({:.2} tok/s)",
                r.params.sp,
                r.params.n_group,
                human_bytes(r.params.cache_bytes),
                human_bytes(r.cost.mem_bytes),
                r.cost.t_decode * 1e3,
                1.0 / r.cost.t_decode
            );
            println!(
                "     breakdown: T_load={:.2}ms T_overlap={:.2}ms \
                 T_comp={:.2}ms (per-group onload={:.2}ms preload={:.2}ms)",
                r.cost.t_load * 1e3,
                r.cost.t_overlap_total * 1e3,
                r.cost.t_comp_group * 1e3,
                r.cost.t_onload_group * 1e3,
                r.cost.t_preload_group * 1e3
            );
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("devices") => {
            println!("{:<12} {:<38} {:>9} {:>10} {:>10}", "name", "label",
                     "DRAM", "flash max", "mem BW");
            for d in device::ALL {
                println!(
                    "{:<12} {:<38} {:>9} {:>8}/s {:>8}/s",
                    d.name,
                    d.label,
                    human_bytes(d.dram_bytes),
                    human_bytes(d.flash_max_bw as u64),
                    human_bytes(d.mem_bw as u64)
                );
            }
        }
        Some("artifacts") => {
            let cfg =
                activeflow::config::ArtifactConfig::load(&artifact_dir(args))?;
            println!("model: {} (d={}, quant {})", cfg.model.name,
                     cfg.model.d_model, cfg.quant);
            println!("levels:");
            for lv in &cfg.sparsity_levels {
                println!("  sp={:.1} k_attn={} k_o={} k_ff={}", lv.sp,
                         lv.k_attn, lv.k_o, lv.k_ff);
            }
        }
        Some("weights") => {
            let cfg =
                activeflow::config::ArtifactConfig::load(&artifact_dir(args))?;
            let awgf = AwgfFile::open(&cfg.weights_file)?;
            println!(
                "AWGF {} | quant {} | group N={} | S_l={} S_m={}",
                cfg.weights_file.display(),
                awgf.quant.name(),
                awgf.group_size,
                human_bytes(awgf.layer_bytes()),
                human_bytes(awgf.sparse_bytes())
            );
            for (op, info) in &awgf.ops {
                println!(
                    "  {:<3} [{} x {}] row={}B groups={}",
                    op.name(),
                    info.d_in,
                    info.d_out,
                    info.row_bytes,
                    info.groups.len()
                );
            }
        }
        _ => bail!("inspect what? (devices|artifacts|weights)"),
    }
    Ok(())
}

// keep baseline presets referenced (exercised by examples/benches too)
#[allow(unused)]
fn _baseline_presets() {
    let _ = baselines::teal_options(
        0.6,
        0,
        &device::PIXEL6,
        ClockMode::Modeled,
        1.0,
    );
    let _ = RuntimeConfig::default();
}
