//! Block quantization codecs (paper §6 "4-bit quantization using Q4_0").
//!
//! Bit-exact mirror of `python/compile/export.py`: blocks of 32 values along
//! the output dim; q8_0 = f32 scale + 32×i8, q4_0 = f32 scale + 16 packed
//! nibbles (value = (nibble − 8) · scale).
//!
//! Decode is structured as fixed 32-lane **block kernels**
//! ([`dequant_block_q8_0`] / [`dequant_block_q4_0`]): the scale load is
//! hoisted out of the lane loop, and the loop itself runs over exact-size
//! subslices via iterator zips so rustc sees no bounds checks and
//! autovectorizes the convert-and-scale on stable (the destination is
//! contiguous f32 — the loader slab fill and the engine's on-demand fetch
//! both decode straight into their target rows). [`dequantize_row_scalar`]
//! keeps the original value-by-value formulation as the bit-exactness
//! reference for the property tests and `benches/kernels.rs`. An explicit
//! `std::simd` formulation lives behind the `portable-simd` feature
//! (nightly-only; the autovectorized kernels are the shipping path).

use anyhow::{bail, Result};

pub const QBLOCK: usize = 32;
/// Packed bytes of one q8_0 block: f32 scale + 32 i8 lanes.
pub const Q8_BLOCK_BYTES: usize = 4 + QBLOCK;
/// Packed bytes of one q4_0 block: f32 scale + 16 nibble pairs.
pub const Q4_BLOCK_BYTES: usize = 4 + QBLOCK / 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quant {
    F32,
    Q8_0,
    Q4_0,
}

impl Quant {
    pub fn parse(s: &str) -> Result<Quant> {
        Ok(match s {
            "f32" => Quant::F32,
            "q8_0" => Quant::Q8_0,
            "q4_0" => Quant::Q4_0,
            other => bail!("unknown quant kind '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::Q8_0 => "q8_0",
            Quant::Q4_0 => "q4_0",
        }
    }
}

/// Bytes per quantized row of `dout` values.
pub fn row_bytes(quant: Quant, dout: usize) -> usize {
    match quant {
        Quant::F32 => 4 * dout,
        Quant::Q8_0 => {
            assert_eq!(dout % QBLOCK, 0);
            (dout / QBLOCK) * Q8_BLOCK_BYTES
        }
        Quant::Q4_0 => {
            assert_eq!(dout % QBLOCK, 0);
            (dout / QBLOCK) * Q4_BLOCK_BYTES
        }
    }
}

/// Decode one 32-lane q8_0 block: `src` is one packed block
/// ([`Q8_BLOCK_BYTES`]), `dst` receives exactly [`QBLOCK`] values. The
/// exact-size zip over `lanes` compiles to a single widening convert +
/// splat-multiply vector loop.
// pallas-lint: hot-path
#[inline(always)]
pub fn dequant_block_q8_0(src: &[u8], dst: &mut [f32]) {
    debug_assert!(src.len() >= Q8_BLOCK_BYTES && dst.len() >= QBLOCK);
    let scale = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
    let lanes = &src[4..Q8_BLOCK_BYTES];
    for (d, &q) in dst[..QBLOCK].iter_mut().zip(lanes) {
        *d = q as i8 as f32 * scale;
    }
}

/// Decode one 32-lane q4_0 block: 16 packed nibble pairs, low nibble is
/// the even lane. Same arithmetic as the scalar reference per lane
/// (`(nibble − 8)` in i32, then one f32 convert and one multiply), so the
/// restructuring is bit-exact by construction.
// pallas-lint: hot-path
#[inline(always)]
pub fn dequant_block_q4_0(src: &[u8], dst: &mut [f32]) {
    debug_assert!(src.len() >= Q4_BLOCK_BYTES && dst.len() >= QBLOCK);
    let scale = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
    let packed = &src[4..Q4_BLOCK_BYTES];
    for (pair, &p) in dst[..QBLOCK].chunks_exact_mut(2).zip(packed) {
        pair[0] = ((p & 0xF) as i32 - 8) as f32 * scale;
        pair[1] = ((p >> 4) as i32 - 8) as f32 * scale;
    }
}

/// Dequantize one packed row into `out` (len == dout; a multiple of
/// [`QBLOCK`] for the quantized kinds). Hot path: no allocation, used by
/// both the loader slab fill and the engine's on-demand fetch; decodes
/// block-by-block through the vectorizable kernels above.
// pallas-lint: hot-path
pub fn dequantize_row(data: &[u8], quant: Quant, out: &mut [f32]) {
    let dout = out.len();
    match quant {
        Quant::F32 => {
            debug_assert_eq!(data.len(), 4 * dout);
            for (o, b) in out.iter_mut().zip(data.chunks_exact(4)) {
                *o = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
        }
        Quant::Q8_0 => {
            debug_assert_eq!(dout % QBLOCK, 0);
            debug_assert_eq!(data.len(), (dout / QBLOCK) * Q8_BLOCK_BYTES);
            for (src, dst) in data
                .chunks_exact(Q8_BLOCK_BYTES)
                .zip(out.chunks_exact_mut(QBLOCK))
            {
                #[cfg(feature = "portable-simd")]
                simd::dequant_block_q8_0(src, dst);
                #[cfg(not(feature = "portable-simd"))]
                dequant_block_q8_0(src, dst);
            }
        }
        Quant::Q4_0 => {
            debug_assert_eq!(dout % QBLOCK, 0);
            debug_assert_eq!(data.len(), (dout / QBLOCK) * Q4_BLOCK_BYTES);
            for (src, dst) in data
                .chunks_exact(Q4_BLOCK_BYTES)
                .zip(out.chunks_exact_mut(QBLOCK))
            {
                #[cfg(feature = "portable-simd")]
                simd::dequant_block_q4_0(src, dst);
                #[cfg(not(feature = "portable-simd"))]
                dequant_block_q4_0(src, dst);
            }
        }
    }
}

/// The original value-by-value decode, retained as the bit-exactness
/// reference: the block kernels must agree with this on every byte
/// pattern (property-tested below, self-asserted in `benches/kernels.rs`
/// which also times the two against each other).
pub fn dequantize_row_scalar(data: &[u8], quant: Quant, out: &mut [f32]) {
    let dout = out.len();
    match quant {
        Quant::F32 => {
            debug_assert_eq!(data.len(), 4 * dout);
            for (i, o) in out.iter_mut().enumerate() {
                *o = f32::from_le_bytes(data[4 * i..4 * i + 4].try_into().unwrap());
            }
        }
        Quant::Q8_0 => {
            let mut off = 0;
            for b in (0..dout).step_by(QBLOCK) {
                let scale =
                    f32::from_le_bytes(data[off..off + 4].try_into().unwrap());
                off += 4;
                for j in 0..QBLOCK {
                    out[b + j] = data[off + j] as i8 as f32 * scale;
                }
                off += QBLOCK;
            }
        }
        Quant::Q4_0 => {
            let mut off = 0;
            for b in (0..dout).step_by(QBLOCK) {
                let scale =
                    f32::from_le_bytes(data[off..off + 4].try_into().unwrap());
                off += 4;
                for j in 0..QBLOCK / 2 {
                    let p = data[off + j];
                    out[b + 2 * j] = ((p & 0xF) as i32 - 8) as f32 * scale;
                    out[b + 2 * j + 1] = ((p >> 4) as i32 - 8) as f32 * scale;
                }
                off += QBLOCK / 2;
            }
        }
    }
}

/// Explicit `std::simd` block kernels (nightly; `--features portable-simd`
/// plus `#![feature(portable_simd)]`, see lib.rs). Same lane arithmetic as
/// the autovectorized kernels: widen to i32, convert once to f32, one
/// splat multiply — bit-exact with the scalar reference.
#[cfg(feature = "portable-simd")]
pub mod simd {
    use super::{QBLOCK, Q4_BLOCK_BYTES, Q8_BLOCK_BYTES};
    use std::simd::prelude::*;

    // pallas-lint: hot-path
    #[inline(always)]
    pub fn dequant_block_q8_0(src: &[u8], dst: &mut [f32]) {
        debug_assert!(src.len() >= Q8_BLOCK_BYTES && dst.len() >= QBLOCK);
        let scale = Simd::<f32, QBLOCK>::splat(f32::from_le_bytes([
            src[0], src[1], src[2], src[3],
        ]));
        let lanes = Simd::<i8, QBLOCK>::from_slice(&src[4..Q8_BLOCK_BYTES]);
        let v = lanes.cast::<f32>() * scale;
        v.copy_to_slice(&mut dst[..QBLOCK]);
    }

    // pallas-lint: hot-path
    #[inline(always)]
    pub fn dequant_block_q4_0(src: &[u8], dst: &mut [f32]) {
        debug_assert!(src.len() >= Q4_BLOCK_BYTES && dst.len() >= QBLOCK);
        let scale = Simd::<f32, QBLOCK>::splat(f32::from_le_bytes([
            src[0], src[1], src[2], src[3],
        ]));
        let packed = Simd::<u8, { QBLOCK / 2 }>::from_slice(
            &src[4..Q4_BLOCK_BYTES],
        );
        let lo = (packed & Simd::splat(0xF)).cast::<i32>()
            - Simd::splat(8i32);
        let hi = (packed >> Simd::splat(4u8)).cast::<i32>()
            - Simd::splat(8i32);
        // even lanes = low nibble, odd lanes = high nibble
        let (a, b) = lo.interleave(hi);
        let mut wide = [0i32; QBLOCK];
        a.copy_to_slice(&mut wide[..QBLOCK / 2]);
        b.copy_to_slice(&mut wide[QBLOCK / 2..]);
        let v = Simd::<i32, QBLOCK>::from_array(wide).cast::<f32>() * scale;
        v.copy_to_slice(&mut dst[..QBLOCK]);
    }
}

/// Quantize one f32 row (mirror of python `quantize_row`; used by tests and
/// the `relayout` tool).
pub fn quantize_row(row: &[f32], quant: Quant) -> Vec<u8> {
    match quant {
        Quant::F32 => row.iter().flat_map(|v| v.to_le_bytes()).collect(),
        Quant::Q8_0 => {
            let mut out = Vec::with_capacity(row_bytes(quant, row.len()));
            for blk in row.chunks(QBLOCK) {
                let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                out.extend_from_slice(&scale.to_le_bytes());
                for &v in blk {
                    out.push((v / scale).round().clamp(-127.0, 127.0) as i8 as u8);
                }
            }
            out
        }
        Quant::Q4_0 => {
            let mut out = Vec::with_capacity(row_bytes(quant, row.len()));
            for blk in row.chunks(QBLOCK) {
                let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 7.0 } else { 1.0 };
                out.extend_from_slice(&scale.to_le_bytes());
                for pair in blk.chunks(2) {
                    let q = |v: f32| {
                        ((v / scale).round().clamp(-7.0, 7.0) as i32 + 8) as u8
                    };
                    out.push((q(pair[0]) & 0xF) | ((q(pair[1]) & 0xF) << 4));
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, GenExt};

    #[test]
    fn row_bytes_match_python() {
        assert_eq!(row_bytes(Quant::F32, 128), 512);
        assert_eq!(row_bytes(Quant::Q8_0, 128), 4 * 36);
        assert_eq!(row_bytes(Quant::Q4_0, 128), 4 * 20);
    }

    #[test]
    fn roundtrip_error_bounds() {
        check("quant-roundtrip", |g| {
            let dout = 32 * g.usize_in(1, 8);
            let row = g.vec_f32(dout, -3.0, 3.0);
            for (quant, denom) in
                [(Quant::Q8_0, 127.0f32), (Quant::Q4_0, 7.0f32)]
            {
                let packed = quantize_row(&row, quant);
                assert_eq!(packed.len(), row_bytes(quant, dout));
                let mut back = vec![0f32; dout];
                dequantize_row(&packed, quant, &mut back);
                for (b, (orig, got)) in
                    row.chunks(QBLOCK).zip(back.chunks(QBLOCK)).enumerate()
                {
                    let amax = orig.iter().fold(0f32, |m, v| m.max(v.abs()));
                    let tol = amax / denom / 2.0 + 1e-6;
                    for (o, g2) in orig.iter().zip(got) {
                        if (o - g2).abs() > tol {
                            return Err(format!(
                                "block {b}: |{o} - {g2}| > {tol} ({quant:?})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// The tentpole bit-safety property: the block kernels must agree
    /// with the retained scalar reference on every byte, across all
    /// quants, row lengths (1..8 blocks — covers every 32-lane tail the
    /// vectorizer can split), and raw lane patterns the quantizer never
    /// emits (full i8 range incl. -128, all 16 nibble values, denormal
    /// and huge finite scales).
    #[test]
    fn block_kernels_bit_exact_vs_scalar_reference() {
        check("dequant-vec-vs-scalar", |g| {
            let blocks = g.usize_in(1, 8);
            let dout = QBLOCK * blocks;
            // adversarial packed bytes: random lanes, finite random scale
            for (quant, body) in
                [(Quant::Q8_0, QBLOCK), (Quant::Q4_0, QBLOCK / 2)]
            {
                let mut packed = Vec::new();
                for _ in 0..blocks {
                    let scale = match g.usize_in(0, 3) {
                        0 => g.f32_range(-4.0, 4.0),
                        1 => 1.0e-38,             // near-denormal
                        2 => 3.0e38,              // near-overflow product
                        _ => -0.0,
                    };
                    packed.extend_from_slice(&scale.to_le_bytes());
                    for _ in 0..body {
                        packed.push(g.usize_in(0, 255) as u8);
                    }
                }
                let mut fast = vec![f32::NAN; dout];
                let mut refr = vec![f32::NAN; dout];
                dequantize_row(&packed, quant, &mut fast);
                dequantize_row_scalar(&packed, quant, &mut refr);
                for i in 0..dout {
                    if fast[i].to_bits() != refr[i].to_bits() {
                        return Err(format!(
                            "{quant:?} lane {i}: {} != {} (bits {:#x} vs \
                             {:#x})",
                            fast[i],
                            refr[i],
                            fast[i].to_bits(),
                            refr[i].to_bits()
                        ));
                    }
                }
            }
            // f32 passthrough at non-block lengths (1..97 values)
            let n = g.usize_in(1, 97);
            let row = g.vec_f32(n, -1e6, 1e6);
            let packed = quantize_row(&row, Quant::F32);
            let mut fast = vec![f32::NAN; n];
            let mut refr = vec![f32::NAN; n];
            dequantize_row(&packed, Quant::F32, &mut fast);
            dequantize_row_scalar(&packed, Quant::F32, &mut refr);
            for i in 0..n {
                if fast[i].to_bits() != refr[i].to_bits() {
                    return Err(format!("f32 lane {i} diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f32_roundtrip_exact() {
        let row: Vec<f32> = (0..64).map(|i| i as f32 * 0.37 - 5.0).collect();
        let packed = quantize_row(&row, Quant::F32);
        let mut back = vec![0f32; 64];
        dequantize_row(&packed, Quant::F32, &mut back);
        assert_eq!(row, back);
    }

    #[test]
    fn zero_block_stays_zero() {
        let row = vec![0f32; 32];
        for q in [Quant::Q8_0, Quant::Q4_0] {
            let mut back = vec![1f32; 32];
            dequantize_row(&quantize_row(&row, q), q, &mut back);
            assert_eq!(back, row);
        }
    }

    #[test]
    fn matches_python_quantizer_golden() {
        // python: quantize_row(linspace(-2,2,32), "q4_0") — pin a few bytes.
        let row: Vec<f32> =
            (0..32).map(|i| -2.0 + 4.0 * i as f32 / 31.0).collect();
        let packed = quantize_row(&row, Quant::Q4_0);
        // scale = 2/7
        let scale = f32::from_le_bytes(packed[..4].try_into().unwrap());
        assert!((scale - 2.0 / 7.0).abs() < 1e-6);
        // first pair: q(-2)=1, q(-1.871)=1 -> byte 0x11
        assert_eq!(packed[4], 0x11);
    }
}
