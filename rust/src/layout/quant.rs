//! Block quantization codecs (paper §6 "4-bit quantization using Q4_0").
//!
//! Bit-exact mirror of `python/compile/export.py`: blocks of 32 values along
//! the output dim; q8_0 = f32 scale + 32×i8, q4_0 = f32 scale + 16 packed
//! nibbles (value = (nibble − 8) · scale).

use anyhow::{bail, Result};

pub const QBLOCK: usize = 32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quant {
    F32,
    Q8_0,
    Q4_0,
}

impl Quant {
    pub fn parse(s: &str) -> Result<Quant> {
        Ok(match s {
            "f32" => Quant::F32,
            "q8_0" => Quant::Q8_0,
            "q4_0" => Quant::Q4_0,
            other => bail!("unknown quant kind '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Quant::F32 => "f32",
            Quant::Q8_0 => "q8_0",
            Quant::Q4_0 => "q4_0",
        }
    }
}

/// Bytes per quantized row of `dout` values.
pub fn row_bytes(quant: Quant, dout: usize) -> usize {
    match quant {
        Quant::F32 => 4 * dout,
        Quant::Q8_0 => {
            assert_eq!(dout % QBLOCK, 0);
            (dout / QBLOCK) * (4 + QBLOCK)
        }
        Quant::Q4_0 => {
            assert_eq!(dout % QBLOCK, 0);
            (dout / QBLOCK) * (4 + QBLOCK / 2)
        }
    }
}

/// Dequantize one packed row into `out` (len == dout). Hot path: no
/// allocation, used by both the cache fill and the packed-weight gather.
pub fn dequantize_row(data: &[u8], quant: Quant, out: &mut [f32]) {
    let dout = out.len();
    match quant {
        Quant::F32 => {
            debug_assert_eq!(data.len(), 4 * dout);
            for (i, o) in out.iter_mut().enumerate() {
                *o = f32::from_le_bytes(data[4 * i..4 * i + 4].try_into().unwrap());
            }
        }
        Quant::Q8_0 => {
            let mut off = 0;
            for b in (0..dout).step_by(QBLOCK) {
                let scale =
                    f32::from_le_bytes(data[off..off + 4].try_into().unwrap());
                off += 4;
                for j in 0..QBLOCK {
                    out[b + j] = data[off + j] as i8 as f32 * scale;
                }
                off += QBLOCK;
            }
        }
        Quant::Q4_0 => {
            let mut off = 0;
            for b in (0..dout).step_by(QBLOCK) {
                let scale =
                    f32::from_le_bytes(data[off..off + 4].try_into().unwrap());
                off += 4;
                for j in 0..QBLOCK / 2 {
                    let p = data[off + j];
                    out[b + 2 * j] = ((p & 0xF) as i32 - 8) as f32 * scale;
                    out[b + 2 * j + 1] = ((p >> 4) as i32 - 8) as f32 * scale;
                }
                off += QBLOCK / 2;
            }
        }
    }
}

/// Quantize one f32 row (mirror of python `quantize_row`; used by tests and
/// the `relayout` tool).
pub fn quantize_row(row: &[f32], quant: Quant) -> Vec<u8> {
    match quant {
        Quant::F32 => row.iter().flat_map(|v| v.to_le_bytes()).collect(),
        Quant::Q8_0 => {
            let mut out = Vec::with_capacity(row_bytes(quant, row.len()));
            for blk in row.chunks(QBLOCK) {
                let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                out.extend_from_slice(&scale.to_le_bytes());
                for &v in blk {
                    out.push((v / scale).round().clamp(-127.0, 127.0) as i8 as u8);
                }
            }
            out
        }
        Quant::Q4_0 => {
            let mut out = Vec::with_capacity(row_bytes(quant, row.len()));
            for blk in row.chunks(QBLOCK) {
                let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 7.0 } else { 1.0 };
                out.extend_from_slice(&scale.to_le_bytes());
                for pair in blk.chunks(2) {
                    let q = |v: f32| {
                        ((v / scale).round().clamp(-7.0, 7.0) as i32 + 8) as u8
                    };
                    out.push((q(pair[0]) & 0xF) | ((q(pair[1]) & 0xF) << 4));
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, GenExt};

    #[test]
    fn row_bytes_match_python() {
        assert_eq!(row_bytes(Quant::F32, 128), 512);
        assert_eq!(row_bytes(Quant::Q8_0, 128), 4 * 36);
        assert_eq!(row_bytes(Quant::Q4_0, 128), 4 * 20);
    }

    #[test]
    fn roundtrip_error_bounds() {
        check("quant-roundtrip", |g| {
            let dout = 32 * g.usize_in(1, 8);
            let row = g.vec_f32(dout, -3.0, 3.0);
            for (quant, denom) in
                [(Quant::Q8_0, 127.0f32), (Quant::Q4_0, 7.0f32)]
            {
                let packed = quantize_row(&row, quant);
                assert_eq!(packed.len(), row_bytes(quant, dout));
                let mut back = vec![0f32; dout];
                dequantize_row(&packed, quant, &mut back);
                for (b, (orig, got)) in
                    row.chunks(QBLOCK).zip(back.chunks(QBLOCK)).enumerate()
                {
                    let amax = orig.iter().fold(0f32, |m, v| m.max(v.abs()));
                    let tol = amax / denom / 2.0 + 1e-6;
                    for (o, g2) in orig.iter().zip(got) {
                        if (o - g2).abs() > tol {
                            return Err(format!(
                                "block {b}: |{o} - {g2}| > {tol} ({quant:?})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f32_roundtrip_exact() {
        let row: Vec<f32> = (0..64).map(|i| i as f32 * 0.37 - 5.0).collect();
        let packed = quantize_row(&row, Quant::F32);
        let mut back = vec![0f32; 64];
        dequantize_row(&packed, Quant::F32, &mut back);
        assert_eq!(row, back);
    }

    #[test]
    fn zero_block_stays_zero() {
        let row = vec![0f32; 32];
        for q in [Quant::Q8_0, Quant::Q4_0] {
            let mut back = vec![1f32; 32];
            dequantize_row(&quantize_row(&row, q), q, &mut back);
            assert_eq!(back, row);
        }
    }

    #[test]
    fn matches_python_quantizer_golden() {
        // python: quantize_row(linspace(-2,2,32), "q4_0") — pin a few bytes.
        let row: Vec<f32> =
            (0..32).map(|i| -2.0 + 4.0 * i as f32 / 31.0).collect();
        let packed = quantize_row(&row, Quant::Q4_0);
        // scale = 2/7
        let scale = f32::from_le_bytes(packed[..4].try_into().unwrap());
        assert!((scale - 2.0 / 7.0).abs() < 1e-6);
        // first pair: q(-2)=1, q(-1.871)=1 -> byte 0x11
        assert_eq!(packed[4], 0x11);
    }
}
