//! AWGF weight-file layout (paper §3 Fig 9): cross-layer-group,
//! channel-major reordering of every sparse-op weight, plus block
//! quantization. Mirror of `python/compile/export.py` — the format spec
//! lives there.

pub mod awgf;
pub mod quant;

pub use awgf::{AwgfFile, OpKind, TensorId, SPARSE_OPS};
pub use quant::{dequantize_row, row_bytes, Quant};
