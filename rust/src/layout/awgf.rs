//! AWGF file reader: header parsing + offset arithmetic for the
//! cross-layer-group channel-major layout (spec in python/compile/export.py).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json;

use super::quant::{row_bytes, Quant};

pub const ALIGN: u64 = 4096;

/// The seven flash-resident sparse ops, in canonical order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpKind {
    Wq,
    Wk,
    Wv,
    Wo,
    Wg,
    Wu,
    Wd,
}

pub const SPARSE_OPS: [OpKind; 7] = [
    OpKind::Wq,
    OpKind::Wk,
    OpKind::Wv,
    OpKind::Wo,
    OpKind::Wg,
    OpKind::Wu,
    OpKind::Wd,
];

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Wq => "wq",
            OpKind::Wk => "wk",
            OpKind::Wv => "wv",
            OpKind::Wo => "wo",
            OpKind::Wg => "wg",
            OpKind::Wu => "wu",
            OpKind::Wd => "wd",
        }
    }

    pub fn parse(s: &str) -> Result<OpKind> {
        SPARSE_OPS
            .iter()
            .copied()
            .find(|o| o.name() == s)
            .ok_or_else(|| anyhow!("unknown op '{s}'"))
    }

    pub fn index(&self) -> usize {
        SPARSE_OPS.iter().position(|o| o == self).unwrap()
    }
}

/// (layer, op) — the unit of per-tensor cache bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TensorId {
    pub layer: u16,
    pub op: OpKind,
}

impl TensorId {
    pub fn new(layer: usize, op: OpKind) -> TensorId {
        TensorId {
            layer: layer as u16,
            op,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GroupInfo {
    pub layers: Vec<usize>,
    /// Payload-relative byte offset of this group's channel-major block.
    pub offset: u64,
}

#[derive(Debug, Clone)]
pub struct OpInfo {
    pub d_in: usize,
    pub d_out: usize,
    pub row_bytes: usize,
    pub groups: Vec<GroupInfo>,
}

#[derive(Debug, Clone)]
pub struct DenseInfo {
    pub offset: u64,
    pub len: usize,
    pub shape: Vec<usize>,
}

/// Parsed AWGF header + offsets. Data itself stays in the file (flash).
pub struct AwgfFile {
    pub model: ModelConfig,
    pub quant: Quant,
    pub group_size: usize,
    pub payload_base: u64,
    pub ops: BTreeMap<OpKind, OpInfo>,
    pub dense: BTreeMap<String, DenseInfo>,
    path: std::path::PathBuf,
}

impl AwgfFile {
    pub fn open(path: &Path) -> Result<AwgfFile> {
        let mut f = File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut pre = [0u8; 12];
        f.read_exact(&mut pre)?;
        if &pre[..4] != b"AWGF" {
            bail!("{}: bad magic", path.display());
        }
        let version = u32::from_le_bytes(pre[4..8].try_into().unwrap());
        if version != 1 {
            bail!("unsupported AWGF version {version}");
        }
        let hdr_len = u32::from_le_bytes(pre[8..12].try_into().unwrap()) as usize;
        let mut hdr = vec![0u8; hdr_len];
        f.read_exact(&mut hdr)?;
        let v = json::parse(std::str::from_utf8(&hdr)?)
            .context("parsing AWGF header json")?;

        let model = ModelConfig::from_json(v.req("model")?)?;
        let quant = Quant::parse(
            v.req("quant")?.as_str().ok_or_else(|| anyhow!("quant"))?,
        )?;
        let group_size = v.req("group_size")?.as_usize().unwrap_or(4);

        let mut ops = BTreeMap::new();
        for (name, info) in v
            .req("ops")?
            .as_obj()
            .ok_or_else(|| anyhow!("ops not object"))?
        {
            let op = OpKind::parse(name)?;
            let d_in = info.req("d_in")?.as_usize().unwrap();
            let d_out = info.req("d_out")?.as_usize().unwrap();
            let rb = info.req("row_bytes")?.as_usize().unwrap();
            if rb != row_bytes(quant, d_out) {
                bail!("{name}: row_bytes mismatch ({rb})");
            }
            let mut groups = Vec::new();
            for g in info.req("groups")?.as_arr().unwrap() {
                groups.push(GroupInfo {
                    layers: g
                        .req("layers")?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|l| l.as_usize().unwrap())
                        .collect(),
                    offset: g.req("offset")?.as_f64().unwrap() as u64,
                });
            }
            ops.insert(
                op,
                OpInfo {
                    d_in,
                    d_out,
                    row_bytes: rb,
                    groups,
                },
            );
        }

        let mut dense = BTreeMap::new();
        for (name, info) in v.req("dense")?.as_obj().unwrap() {
            dense.insert(
                name.clone(),
                DenseInfo {
                    offset: info.req("offset")?.as_f64().unwrap() as u64,
                    len: info.req("len")?.as_usize().unwrap(),
                    shape: info
                        .req("shape")?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|s| s.as_usize().unwrap())
                        .collect(),
                },
            );
        }

        let payload_base = (12 + hdr_len as u64).div_ceil(ALIGN) * ALIGN;
        Ok(AwgfFile {
            model,
            quant,
            group_size,
            payload_base,
            ops,
            dense,
            path: path.to_path_buf(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn op(&self, op: OpKind) -> &OpInfo {
        &self.ops[&op]
    }

    /// Group index containing `layer` for this op.
    pub fn group_of(&self, op: OpKind, layer: usize) -> usize {
        self.ops[&op]
            .groups
            .iter()
            .position(|g| g.layers.contains(&layer))
            .expect("layer out of range")
    }

    /// Absolute file span of one **cross-layer chunk**: channel `c` of every
    /// layer in group `g` — the paper's large-I/O preload unit (Fig 9).
    pub fn chunk_span(&self, op: OpKind, group: usize, channel: usize) -> (u64, usize) {
        let info = &self.ops[&op];
        let grp = &info.groups[group];
        let n = grp.layers.len();
        let off = self.payload_base
            + grp.offset
            + (channel * n) as u64 * info.row_bytes as u64;
        (off, n * info.row_bytes)
    }

    /// Absolute file span of a single weight row (layer, channel) — the
    /// small on-demand unit.
    pub fn row_span(&self, op: OpKind, layer: usize, channel: usize) -> (u64, usize) {
        let info = &self.ops[&op];
        let g = self.group_of(op, layer);
        let grp = &info.groups[g];
        let j = grp.layers.iter().position(|&l| l == layer).unwrap();
        let n = grp.layers.len();
        let off = self.payload_base
            + grp.offset
            + ((channel * n + j) * info.row_bytes) as u64;
        (off, info.row_bytes)
    }

    /// Offset of layer `j`'s row inside a chunk returned by `chunk_span`.
    pub fn row_in_chunk(&self, op: OpKind, group: usize, layer: usize) -> usize {
        let grp = &self.ops[&op].groups[group];
        let j = grp.layers.iter().position(|&l| l == layer).unwrap();
        j * self.ops[&op].row_bytes
    }

    /// Read a dense (always-resident) tensor as f32 — done once at startup,
    /// not via the flash simulator.
    pub fn read_dense(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        use std::os::unix::fs::FileExt;
        let info = self
            .dense
            .get(name)
            .ok_or_else(|| anyhow!("dense tensor '{name}' not found"))?;
        let f = File::open(&self.path)?;
        let mut buf = vec![0u8; info.len];
        f.read_exact_at(&mut buf, self.payload_base + info.offset)?;
        let vals = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((vals, info.shape.clone()))
    }

    /// Total quantized bytes of one layer's sparse weights (cost-model S_l).
    pub fn layer_bytes(&self) -> u64 {
        self.ops
            .values()
            .map(|o| (o.d_in * o.row_bytes) as u64)
            .sum()
    }

    /// Total sparse-weight payload (cost-model S_m, excludes dense tensors).
    pub fn sparse_bytes(&self) -> u64 {
        self.layer_bytes() * self.model.n_layers as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opkind_roundtrip() {
        for op in SPARSE_OPS {
            assert_eq!(OpKind::parse(op.name()).unwrap(), op);
        }
        assert!(OpKind::parse("nope").is_err());
    }

    #[test]
    fn tensor_id_ordering() {
        let a = TensorId::new(0, OpKind::Wq);
        let b = TensorId::new(0, OpKind::Wd);
        let c = TensorId::new(1, OpKind::Wq);
        assert!(a < b && b < c);
    }

    // Full file-level tests live in rust/tests/awgf_roundtrip.rs, which
    // reads the python-written artifacts/model.awgf.
}
