//! Flight recorder: structured trace spans, log2-bucket latency
//! histograms, and the governor's decision journal (PERF.md
//! §Observability).
//!
//! Three cooperating pieces, all zero-dependency:
//!
//! 1. **[`Histo`]** — a fixed-size log2-bucket histogram (64 buckets,
//!    allocation-free, `Copy`) with `merge` and conservative p50/p95/p99.
//!    Always on: the engine, scheduler, and read queue record into these
//!    unconditionally, so `stats` has percentiles even with span tracing
//!    off.
//!
//! 2. **Span recorder** — a bounded, drop-counted ring of
//!    [`SpanEvent`]s behind one [`TraceHandle`]. Producers (engine,
//!    loader, I/O workers, scheduler, governor) each own a
//!    [`TraceBuf`]: a private `Vec` they push into without locking,
//!    drained into the shared ring at wave/step/batch boundaries.
//!    Tracing is **off by default**; disabled, `span()` is one relaxed
//!    atomic load and no allocation — the per-token hot path's
//!    single-lock invariant (`engine_golden`) is untouched.
//!    [`chrome_trace`] exports the ring as Chrome trace-event JSON
//!    (load in Perfetto / `chrome://tracing`), with balanced `B`/`E`
//!    duration events per thread track, so preload-part spans are
//!    *visible* overlapping step/layer-fetch compute spans.
//!
//! 3. **Decision journal** — every governor [`RebudgetDecision`]'s
//!    trigger, ledger snapshot, and settle time as a bounded
//!    [`JournalEntry`] ring, queryable via the server's
//!    `{"cmd":"journal"}` and rendered as counter-track (`"C"`) events
//!    in the same trace. Journaled regardless of span tracing — it is
//!    tiny and re-budgets are rare.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Value};

// ------------------------------------------------------------------ Histo

/// Log2-bucket histogram: bucket 0 holds the value 0, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i)`. 64 buckets cover the full `u64` range, so
/// `record` is branch-light (`leading_zeros` + a few adds), the struct
/// is `Copy` (no allocation, mergeable across threads by value), and a
/// percentile query walks at most 64 counters.
///
/// Percentiles are **conservative**: the reported quantile is the upper
/// edge of the bucket the target rank falls in (clamped to the observed
/// max), so `p99()` never under-reports. Bucket order makes
/// `p50 ≤ p95 ≤ p99` structural.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histo {
    counts: [u64; 64],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histo {
    fn default() -> Histo {
        Histo {
            counts: [0; 64],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo::default()
    }

    /// Bucket index of `v`: 0 for 0, else `64 - leading_zeros`, clamped
    /// to 63 (bucket 63 absorbs everything ≥ 2^62).
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(63)
        }
    }

    /// Inclusive upper edge of bucket `i` (what percentiles report).
    #[inline]
    pub fn bucket_upper_edge(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 63 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histo) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `p` in `(0, 1]` — the upper edge of the bucket
    /// holding the target rank, clamped to the observed max (so `p=1.0`
    /// reports exactly `max`). 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target =
            ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_edge(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

// ------------------------------------------------------------- span events

/// What a span measured. `name()` is the Chrome-trace event name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One scheduler wave (all live sequences stepped once).
    Wave,
    /// One engine `step` (one token of one sequence).
    Step,
    /// One transformer layer's four family fetches inside a step.
    LayerFetch,
    /// One preload part: loader receipt → slab publish.
    PreloadPart,
    /// One read-queue device wave (`read_batch` call).
    IoBatch,
    /// One on-demand flash fill inside a family fetch (miss path).
    OndemandRead,
    /// One governor re-budget settling against the live engine.
    Rebudget,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Wave => "wave",
            SpanKind::Step => "step",
            SpanKind::LayerFetch => "layer_fetch",
            SpanKind::PreloadPart => "preload_part",
            SpanKind::IoBatch => "io_batch",
            SpanKind::OndemandRead => "ondemand_read",
            SpanKind::Rebudget => "rebudget",
        }
    }
}

/// One recorded span. `a`/`b` are kind-specific labels (sequence id,
/// layer index, op, read count …) surfaced as Chrome-trace args.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Start, µs since the recorder's epoch.
    pub t0_us: u64,
    pub dur_us: u64,
    /// Thread track (the `TID_*` constants).
    pub tid: u32,
    pub a: u64,
    pub b: u64,
}

/// Thread-track ids: stable across runs so traces diff cleanly.
pub const TID_SCHED: u32 = 1;
pub const TID_ENGINE: u32 = 2;
pub const TID_LOADER: u32 = 3;
pub const TID_GOVERNOR: u32 = 9;
/// I/O workers take `TID_IO_BASE + slot`.
pub const TID_IO_BASE: u32 = 10;

fn tid_name(tid: u32) -> String {
    match tid {
        TID_SCHED => "scheduler".into(),
        TID_ENGINE => "engine".into(),
        TID_LOADER => "loader".into(),
        TID_GOVERNOR => "governor".into(),
        t if t >= TID_IO_BASE => format!("io-{}", t - TID_IO_BASE),
        t => format!("track-{t}"),
    }
}

// ---------------------------------------------------------------- journal

/// One governor re-budget, as journaled: the decision's trigger, the
/// applied ledger, and how long the engine took to settle.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    /// µs since the recorder's epoch.
    pub t_us: u64,
    /// `RebudgetTrigger::name()`.
    pub trigger: &'static str,
    /// False = gated off (hysteresis) or infeasible; ledger fields then
    /// reflect the still-standing previous plan.
    pub applied: bool,
    pub note: String,
    pub old_budget: u64,
    pub new_budget: u64,
    pub cache_bytes: u64,
    pub preload_bytes: u64,
    pub compute_bytes: u64,
    pub max_seqs: usize,
    pub settle_us: u64,
}

impl JournalEntry {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("t_us", num(self.t_us as f64)),
            ("trigger", s(self.trigger)),
            ("applied", Value::Bool(self.applied)),
            ("note", s(&self.note)),
            ("old_budget", num(self.old_budget as f64)),
            ("new_budget", num(self.new_budget as f64)),
            ("cache_bytes", num(self.cache_bytes as f64)),
            ("preload_bytes", num(self.preload_bytes as f64)),
            ("compute_bytes", num(self.compute_bytes as f64)),
            ("max_seqs", num(self.max_seqs as f64)),
            ("settle_us", num(self.settle_us as f64)),
        ])
    }
}

// ----------------------------------------------------------- the recorder

/// Default span-ring capacity (bounded DRAM: 65536 × 40 B ≈ 2.5 MiB).
pub const DEFAULT_RING_CAP: usize = 65536;
/// Journal ring capacity (re-budgets are rare; 256 is hours of history).
pub const JOURNAL_CAP: usize = 256;
/// A producer's local buffer flushes itself past this many spans even
/// between wave boundaries, bounding per-producer memory.
const LOCAL_BUF_CAP: usize = 4096;

struct TraceInner {
    ring: VecDeque<SpanEvent>,
    dropped: u64,
    journal: VecDeque<JournalEntry>,
    journal_dropped: u64,
}

/// The shared recorder. Clone the `Arc` ([`TraceHandle`]) into every
/// producer; span recording goes through per-producer [`TraceBuf`]s so
/// the one mutex here is taken only at flush boundaries (and for rare
/// directly-pushed events: waves, re-budgets).
pub struct TraceShared {
    enabled: AtomicBool,
    epoch: Instant,
    cap: usize,
    inner: Mutex<TraceInner>,
}

pub type TraceHandle = Arc<TraceShared>;

impl TraceShared {
    pub fn new(cap: usize) -> TraceHandle {
        Arc::new(TraceShared {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            cap: cap.max(16),
            inner: Mutex::new(TraceInner {
                ring: VecDeque::new(),
                dropped: 0,
                journal: VecDeque::new(),
                journal_dropped: 0,
            }),
        })
    }

    /// The disabled-path cost of every producer check: one relaxed load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// µs since the recorder's epoch (every span's clock).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Push one span directly (rare events — wave, rebudget). Producers
    /// with per-event volume use a [`TraceBuf`] instead.
    pub fn push_one(&self, ev: SpanEvent) {
        if !self.enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        Self::push_locked(&mut g, self.cap, ev);
    }

    fn push_locked(g: &mut TraceInner, cap: usize, ev: SpanEvent) {
        if g.ring.len() >= cap {
            g.ring.pop_front();
            g.dropped += 1;
        }
        g.ring.push_back(ev);
    }

    /// Drain a producer's local buffer into the ring (one lock per
    /// flush). Oldest events are dropped (and counted) past `cap` — a
    /// flight recorder keeps the most recent window.
    fn push_batch(&self, buf: &mut Vec<SpanEvent>) {
        let mut g = self.inner.lock().unwrap();
        for ev in buf.drain(..) {
            Self::push_locked(&mut g, self.cap, ev);
        }
    }

    /// Journal a governor decision (recorded even with span tracing
    /// off — bounded, rare, and `{"cmd":"journal"}` must always work).
    pub fn record_journal(&self, e: JournalEntry) {
        let mut g = self.inner.lock().unwrap();
        if g.journal.len() >= JOURNAL_CAP {
            g.journal.pop_front();
            g.journal_dropped += 1;
        }
        g.journal.push_back(e);
    }

    /// `(events_held, ring_capacity, events_dropped)` for `stats`.
    pub fn ring_stats(&self) -> (usize, usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.ring.len(), self.cap, g.dropped)
    }

    /// `(entries_held, entries_dropped)`.
    pub fn journal_stats(&self) -> (usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.journal.len(), g.journal_dropped)
    }

    pub fn snapshot_spans(&self) -> Vec<SpanEvent> {
        let g = self.inner.lock().unwrap();
        g.ring.iter().copied().collect()
    }

    pub fn snapshot_journal(&self) -> Vec<JournalEntry> {
        let g = self.inner.lock().unwrap();
        g.journal.iter().cloned().collect()
    }

    /// Zero the rings and drop counters (`stats_reset`). Leaves
    /// `enabled` as is.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.ring.clear();
        g.dropped = 0;
        g.journal.clear();
        g.journal_dropped = 0;
    }
}

/// A producer's private span buffer: push without locking, flush at the
/// producer's natural boundary (wave end, step end, batch end). With
/// tracing disabled `span()` allocates nothing — the `Vec` only ever
/// grows while enabled.
pub struct TraceBuf {
    shared: TraceHandle,
    tid: u32,
    buf: Vec<SpanEvent>,
}

impl TraceBuf {
    pub fn new(shared: TraceHandle, tid: u32) -> TraceBuf {
        TraceBuf {
            shared,
            tid,
            buf: Vec::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.shared.enabled()
    }

    /// µs since the shared epoch. Producers bracket work with
    /// `let t0 = buf.now_us(); ...; buf.span(kind, t0, a, b)`.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.shared.now_us()
    }

    pub fn handle(&self) -> &TraceHandle {
        &self.shared
    }

    /// Record a span ending now. No-op (no allocation) when disabled.
    // pallas-lint: hot-path
    #[inline]
    pub fn span(&mut self, kind: SpanKind, t0_us: u64, a: u64, b: u64) {
        if !self.enabled() {
            return;
        }
        let now = self.shared.now_us();
        self.span_at(kind, t0_us, now.saturating_sub(t0_us), a, b);
    }

    /// Record a span with an explicit duration.
    // pallas-lint: hot-path
    #[inline]
    pub fn span_at(
        &mut self,
        kind: SpanKind,
        t0_us: u64,
        dur_us: u64,
        a: u64,
        b: u64,
    ) {
        if !self.enabled() {
            return;
        }
        if self.buf.len() >= LOCAL_BUF_CAP {
            self.flush();
        }
        self.buf.push(SpanEvent {
            kind,
            t0_us,
            dur_us,
            tid: self.tid,
            a,
            b,
        });
    }

    /// Drain into the shared ring (call at wave/step/batch boundaries).
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.shared.push_batch(&mut self.buf);
    }
}

// ------------------------------------------------------------ trace export

/// Export the recorder as a Chrome trace-event JSON object
/// (`{"traceEvents": [...], "otherData": {...}}`) — loadable in Perfetto
/// or `chrome://tracing`. Spans become balanced `B`/`E` duration-event
/// pairs per thread track (per-tid sort by start, longest-first at ties,
/// children clamped into their parents so the nesting is always valid);
/// journal entries become `"C"` counter events on the governor track;
/// thread names ride as `"M"` metadata events.
pub fn chrome_trace(h: &TraceHandle) -> Value {
    let spans = h.snapshot_spans();
    let journal = h.snapshot_journal();
    let (_, cap, dropped) = h.ring_stats();

    let mut events: Vec<Value> = Vec::new();

    // thread-name metadata, one per track present
    let mut tids: Vec<u32> = spans.iter().map(|e| e.tid).collect();
    if !journal.is_empty() {
        tids.push(TID_GOVERNOR);
    }
    tids.sort_unstable();
    tids.dedup();
    for tid in &tids {
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(1.0)),
            ("tid", num(*tid as f64)),
            ("args", obj(vec![("name", s(&tid_name(*tid)))])),
        ]));
    }

    // duration events, balanced per tid
    let mut by_tid: Vec<(u32, Vec<SpanEvent>)> = Vec::new();
    for tid in &tids {
        let mut evs: Vec<SpanEvent> =
            spans.iter().filter(|e| e.tid == *tid).copied().collect();
        // start ascending; at equal starts the longest span is the parent
        evs.sort_by(|x, y| {
            x.t0_us.cmp(&y.t0_us).then(y.dur_us.cmp(&x.dur_us))
        });
        if !evs.is_empty() {
            by_tid.push((*tid, evs));
        }
    }
    for (tid, evs) in by_tid {
        // stack of (end_us, name) — emit E on pop
        let mut stack: Vec<(u64, &'static str)> = Vec::new();
        let emit_e = |events: &mut Vec<Value>, end: u64, name: &str| {
            events.push(obj(vec![
                ("ph", s("E")),
                ("name", s(name)),
                ("pid", num(1.0)),
                ("tid", num(tid as f64)),
                ("ts", num(end as f64)),
            ]));
        };
        for ev in evs {
            while let Some(&(end, name)) = stack.last() {
                if end <= ev.t0_us {
                    emit_e(&mut events, end, name);
                    stack.pop();
                } else {
                    break;
                }
            }
            // clamp into the open parent: recorded durations come off
            // concurrent clocks, so a child overrunning its parent by a
            // few µs is measurement noise, not structure
            let mut end = ev.t0_us.saturating_add(ev.dur_us);
            if let Some(&(pend, _)) = stack.last() {
                end = end.min(pend);
            }
            events.push(obj(vec![
                ("ph", s("B")),
                ("name", s(ev.kind.name())),
                ("pid", num(1.0)),
                ("tid", num(tid as f64)),
                ("ts", num(ev.t0_us as f64)),
                (
                    "args",
                    obj(vec![
                        ("a", num(ev.a as f64)),
                        ("b", num(ev.b as f64)),
                    ]),
                ),
            ]));
            stack.push((end, ev.kind.name()));
        }
        while let Some((end, name)) = stack.pop() {
            emit_e(&mut events, end, name);
        }
    }

    // governor counter track from the journal
    for e in &journal {
        events.push(obj(vec![
            ("ph", s("C")),
            ("name", s("governor_ledger")),
            ("pid", num(1.0)),
            ("tid", num(TID_GOVERNOR as f64)),
            ("ts", num(e.t_us as f64)),
            (
                "args",
                obj(vec![
                    ("budget", num(e.new_budget as f64)),
                    ("cache", num(e.cache_bytes as f64)),
                    ("preload", num(e.preload_bytes as f64)),
                    ("compute", num(e.compute_bytes as f64)),
                    ("max_seqs", num(e.max_seqs as f64)),
                ]),
            ),
        ]));
    }

    obj(vec![
        ("traceEvents", arr(events)),
        (
            "otherData",
            obj(vec![
                ("ring_capacity", num(cap as f64)),
                ("dropped", num(dropped as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // ------------------------------------------------------------ Histo

    #[test]
    fn histo_bucket_boundaries_exact() {
        // bucket 0 = {0}; bucket i ≥ 1 = [2^(i-1), 2^i)
        assert_eq!(Histo::bucket_of(0), 0);
        assert_eq!(Histo::bucket_of(1), 1);
        assert_eq!(Histo::bucket_of(2), 2);
        assert_eq!(Histo::bucket_of(3), 2);
        assert_eq!(Histo::bucket_of(4), 3);
        assert_eq!(Histo::bucket_of(7), 3);
        assert_eq!(Histo::bucket_of(8), 4);
        for i in 1..63usize {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(Histo::bucket_of(lo), i, "lower edge of bucket {i}");
            assert_eq!(Histo::bucket_of(hi), i, "upper edge of bucket {i}");
            assert_eq!(Histo::bucket_upper_edge(i), hi);
        }
        assert_eq!(Histo::bucket_of(u64::MAX), 63);
        assert_eq!(Histo::bucket_upper_edge(63), u64::MAX);
    }

    #[test]
    fn histo_records_and_reports() {
        let mut h = Histo::new();
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.min(), 0);
        for v in [3u64, 5, 9, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1117);
        assert_eq!(h.min(), 3);
        assert_eq!(h.max(), 1000);
        // p100 clamps to the exact observed max
        assert_eq!(h.percentile(1.0), 1000);
    }

    #[test]
    fn histo_merge_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let mut h = Histo::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = mk(&[1, 2, 3, 1000]);
        let b = mk(&[7, 7, 7]);
        let c = mk(&[0, 50_000, u64::MAX]);

        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut left = a;
        left.merge(&b);
        left.merge(&c);
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        assert_eq!(left, right);

        // a ⊕ b == b ⊕ a
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba);
    }

    #[test]
    fn histo_percentiles_monotone() {
        // several shapes, incl. heavily skewed ones
        let shapes: Vec<Vec<u64>> = vec![
            (1..=100u64).collect(),
            vec![1; 99].into_iter().chain([1_000_000]).collect(),
            vec![0, 0, 0, 1, 2, 4, 8, 16, 1 << 40],
            vec![42],
        ];
        for vals in shapes {
            let mut h = Histo::new();
            for &v in &vals {
                h.record(v);
            }
            assert!(
                h.p50() <= h.p95() && h.p95() <= h.p99(),
                "p50={} p95={} p99={} for {vals:?}",
                h.p50(),
                h.p95(),
                h.p99()
            );
            assert!(h.p99() <= h.max());
        }
    }

    #[test]
    fn histo_percentile_is_conservative_upper_edge() {
        let mut h = Histo::new();
        for _ in 0..100 {
            h.record(5); // bucket 3 = [4, 8)
        }
        // upper edge of bucket 3 is 7, but the observed max clamps it
        assert_eq!(h.p50(), 5);
        h.record(7);
        assert_eq!(h.p99(), 7);
    }

    // ------------------------------------------------------------- ring

    fn ev(t0: u64, dur: u64, tid: u32) -> SpanEvent {
        SpanEvent {
            kind: SpanKind::Step,
            t0_us: t0,
            dur_us: dur,
            tid,
            a: 0,
            b: 0,
        }
    }

    #[test]
    fn ring_overflow_counts_drops() {
        let h = TraceShared::new(16);
        h.set_enabled(true);
        let mut buf = TraceBuf::new(h.clone(), TID_ENGINE);
        for i in 0..24u64 {
            buf.span_at(SpanKind::Step, i * 10, 5, i, 0);
        }
        buf.flush();
        let (len, cap, dropped) = h.ring_stats();
        assert_eq!(cap, 16);
        assert_eq!(len, 16);
        assert_eq!(dropped, 8);
        // the ring kept the NEWEST window
        let spans = h.snapshot_spans();
        assert_eq!(spans.first().unwrap().t0_us, 80);
        assert_eq!(spans.last().unwrap().t0_us, 230);
    }

    #[test]
    fn disabled_recorder_stores_nothing() {
        let h = TraceShared::new(64);
        let mut buf = TraceBuf::new(h.clone(), TID_ENGINE);
        buf.span_at(SpanKind::Step, 0, 5, 0, 0);
        buf.flush();
        h.push_one(ev(0, 1, TID_SCHED));
        let (len, _, dropped) = h.ring_stats();
        assert_eq!((len, dropped), (0, 0));
    }

    #[test]
    fn clear_resets_rings_and_drop_counters() {
        let h = TraceShared::new(4);
        h.set_enabled(true);
        for i in 0..9u64 {
            h.push_one(ev(i, 1, TID_SCHED));
        }
        h.record_journal(JournalEntry {
            t_us: 1,
            trigger: "command",
            applied: true,
            note: String::new(),
            old_budget: 2,
            new_budget: 1,
            cache_bytes: 1,
            preload_bytes: 0,
            compute_bytes: 0,
            max_seqs: 4,
            settle_us: 10,
        });
        h.clear();
        assert_eq!(h.ring_stats(), (0, 4, 0));
        assert_eq!(h.journal_stats(), (0, 0));
        assert!(h.enabled(), "clear must not flip the enable switch");
    }

    // ----------------------------------------------------------- export

    /// Walk exported events checking balance + per-tid ts monotonicity
    /// (the Rust-side mirror of scripts/check_trace.py).
    fn check_exported(v: &Value) {
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        use std::collections::HashMap;
        let mut stacks: HashMap<u64, Vec<String>> = HashMap::new();
        let mut last_ts: HashMap<u64, f64> = HashMap::new();
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            if ph == "M" {
                continue;
            }
            let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            let prev = last_ts.entry(tid).or_insert(f64::MIN);
            assert!(ts >= *prev, "ts must be monotone per tid");
            *prev = ts;
            match ph {
                "B" => stacks
                    .entry(tid)
                    .or_default()
                    .push(e.get("name").unwrap().as_str().unwrap().into()),
                "E" => {
                    let name = e.get("name").unwrap().as_str().unwrap();
                    let top = stacks
                        .get_mut(&tid)
                        .and_then(|s| s.pop())
                        .expect("E without open B");
                    assert_eq!(top, name, "E name must match open B");
                }
                "C" => {}
                other => panic!("unexpected ph {other}"),
            }
        }
        for (tid, st) in stacks {
            assert!(st.is_empty(), "unclosed B events on tid {tid}");
        }
    }

    #[test]
    fn export_is_balanced_and_monotone() {
        let h = TraceShared::new(256);
        h.set_enabled(true);
        let mut eng = TraceBuf::new(h.clone(), TID_ENGINE);
        let mut load = TraceBuf::new(h.clone(), TID_LOADER);
        // nested: step containing two layer fetches, one overrunning
        eng.span_at(SpanKind::Step, 100, 100, 1, 0);
        eng.span_at(SpanKind::LayerFetch, 110, 20, 0, 0);
        eng.span_at(SpanKind::LayerFetch, 150, 80, 1, 0); // overruns parent
        // loader: preload part overlapping the step in wall time
        load.span_at(SpanKind::PreloadPart, 120, 60, 7, 2);
        eng.flush();
        load.flush();
        h.push_one(SpanEvent {
            kind: SpanKind::Wave,
            t0_us: 90,
            dur_us: 130,
            tid: TID_SCHED,
            a: 1,
            b: 0,
        });
        h.record_journal(JournalEntry {
            t_us: 210,
            trigger: "pressure",
            applied: true,
            note: "test".into(),
            old_budget: 100,
            new_budget: 80,
            cache_bytes: 40,
            preload_bytes: 20,
            compute_bytes: 20,
            max_seqs: 2,
            settle_us: 300,
        });
        let v = chrome_trace(&h);
        check_exported(&v);
        let other = v.get("otherData").unwrap();
        assert_eq!(other.get("dropped").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            other.get("ring_capacity").unwrap().as_f64().unwrap(),
            256.0
        );
        // the journal produced a counter event
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| e
            .get("ph")
            .map(|p| p.as_str() == Some("C"))
            .unwrap_or(false)));
        // and the trace round-trips through the json module
        let parsed = crate::util::json::parse(&v.to_string()).unwrap();
        check_exported(&parsed);
    }

    #[test]
    fn journal_ring_bounded() {
        let h = TraceShared::new(16);
        for i in 0..(JOURNAL_CAP + 10) {
            h.record_journal(JournalEntry {
                t_us: i as u64,
                trigger: "schedule",
                applied: false,
                note: String::new(),
                old_budget: 0,
                new_budget: 0,
                cache_bytes: 0,
                preload_bytes: 0,
                compute_bytes: 0,
                max_seqs: 1,
                settle_us: 0,
            });
        }
        let (len, dropped) = h.journal_stats();
        assert_eq!(len, JOURNAL_CAP);
        assert_eq!(dropped, 10);
    }
}
