//! Flight recorder: structured trace spans, log2-bucket latency
//! histograms, and the governor's decision journal (PERF.md
//! §Observability).
//!
//! Three cooperating pieces, all zero-dependency:
//!
//! 1. **[`Histo`]** — a fixed-size log2-bucket histogram (64 buckets,
//!    allocation-free, `Copy`) with `merge` and conservative p50/p95/p99.
//!    Always on: the engine, scheduler, and read queue record into these
//!    unconditionally, so `stats` has percentiles even with span tracing
//!    off.
//!
//! 2. **Span recorder** — a bounded, drop-counted ring of
//!    [`SpanEvent`]s behind one [`TraceHandle`]. Producers (engine,
//!    loader, I/O workers, scheduler, governor) each own a
//!    [`TraceBuf`]: a private `Vec` they push into without locking,
//!    drained into the shared ring at wave/step/batch boundaries.
//!    Tracing is **off by default**; disabled, `span()` is one relaxed
//!    atomic load and no allocation — the per-token hot path's
//!    single-lock invariant (`engine_golden`) is untouched.
//!    [`chrome_trace`] exports the ring as Chrome trace-event JSON
//!    (load in Perfetto / `chrome://tracing`), with balanced `B`/`E`
//!    duration events per thread track, so preload-part spans are
//!    *visible* overlapping step/layer-fetch compute spans.
//!
//! 3. **Decision journal** — every governor [`RebudgetDecision`]'s
//!    trigger, ledger snapshot, and settle time as a bounded
//!    [`JournalEntry`] ring, queryable via the server's
//!    `{"cmd":"journal"}` and rendered as counter-track (`"C"`) events
//!    in the same trace. Journaled regardless of span tracing — it is
//!    tiny and re-budgets are rare.
//!
//! Spans carry a [`SpanCtx`] — the request id minted at server accept
//! and the sequence id minted at scheduler admission — so every
//! `step`/`layer_fetch`/`preload_part`/`io_batch`/`ondemand_read`
//! records its causal parent. [`chrome_trace`] turns the contexts into
//! Chrome **flow events** (`ph:"s"/"f"`) linking each retired
//! `request` root span through its waves and steps down to the flash
//! I/O it paid for (PERF.md §Live telemetry). The ring additionally
//! supports cursor reads ([`TraceShared::drain_since`]) so the server's
//! streaming subscriber can tail spans without consuming the snapshot
//! commands' view, and a bounded [`LedgerSample`] ring records the
//! governor pools + KV + slab bytes per wave as a `dram_pools` counter
//! track.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Value};

// ------------------------------------------------------------------ Histo

/// Log2-bucket histogram: bucket 0 holds the value 0, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i)`. 64 buckets cover the full `u64` range, so
/// `record` is branch-light (`leading_zeros` + a few adds), the struct
/// is `Copy` (no allocation, mergeable across threads by value), and a
/// percentile query walks at most 64 counters.
///
/// Percentiles are **conservative**: the reported quantile is the upper
/// edge of the bucket the target rank falls in (clamped to the observed
/// max), so `p99()` never under-reports. Bucket order makes
/// `p50 ≤ p95 ≤ p99` structural.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histo {
    counts: [u64; 64],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histo {
    fn default() -> Histo {
        Histo {
            counts: [0; 64],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo::default()
    }

    /// Bucket index of `v`: 0 for 0, else `64 - leading_zeros`, clamped
    /// to 63 (bucket 63 absorbs everything ≥ 2^62).
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(63)
        }
    }

    /// Raw count of bucket `i` (Prometheus exposition renders these as
    /// cumulative `le` buckets).
    #[inline]
    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Inclusive upper edge of bucket `i` (what percentiles report).
    #[inline]
    pub fn bucket_upper_edge(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 63 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histo) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `p` in `(0, 1]` — the upper edge of the bucket
    /// holding the target rank, clamped to the observed max (so `p=1.0`
    /// reports exactly `max`). 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target =
            ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_edge(i).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

// ------------------------------------------------------------- span events

/// What a span measured. `name()` is the Chrome-trace event name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// One scheduler wave (all live sequences stepped once).
    Wave,
    /// One engine `step` (one token of one sequence).
    Step,
    /// One transformer layer's four family fetches inside a step.
    LayerFetch,
    /// One preload part: loader receipt → slab publish.
    PreloadPart,
    /// One read-queue device wave (`read_batch` call).
    IoBatch,
    /// One on-demand flash fill inside a family fetch (miss path).
    OndemandRead,
    /// One governor re-budget settling against the live engine.
    Rebudget,
    /// One client request, submit → retirement (the flow-graph root).
    Request,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Wave => "wave",
            SpanKind::Step => "step",
            SpanKind::LayerFetch => "layer_fetch",
            SpanKind::PreloadPart => "preload_part",
            SpanKind::IoBatch => "io_batch",
            SpanKind::OndemandRead => "ondemand_read",
            SpanKind::Rebudget => "rebudget",
            SpanKind::Request => "request",
        }
    }
}

/// The causal context a span was recorded under: the request id minted
/// at server accept (`req`) and the sequence id minted at scheduler
/// admission (`seq`). `0` means "none" on both axes — solo decode,
/// governor re-budgets, and pre-scheduler traffic record
/// [`SpanCtx::NONE`] and get no flow edges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanCtx {
    pub req: u64,
    pub seq: u64,
}

impl SpanCtx {
    pub const NONE: SpanCtx = SpanCtx { req: 0, seq: 0 };

    pub fn new(req: u64, seq: u64) -> SpanCtx {
        SpanCtx { req, seq }
    }

    #[inline]
    pub fn is_none(&self) -> bool {
        self.req == 0 && self.seq == 0
    }
}

/// One recorded span. `a`/`b` are kind-specific labels (sequence id,
/// layer index, op, read count …) surfaced as Chrome-trace args; `ctx`
/// is the causal parent (request + sequence), surfaced as `req`/`seq`
/// args and compiled into flow events by [`chrome_trace`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Start, µs since the recorder's epoch.
    pub t0_us: u64,
    pub dur_us: u64,
    /// Thread track (the `TID_*` constants).
    pub tid: u32,
    pub ctx: SpanCtx,
    pub a: u64,
    pub b: u64,
}

/// Thread-track ids: stable across runs so traces diff cleanly.
pub const TID_SCHED: u32 = 1;
pub const TID_ENGINE: u32 = 2;
pub const TID_LOADER: u32 = 3;
/// Retired `request` root spans (one fake-nested track; flows bind by
/// exact begin timestamp, so overlap on the track is cosmetic only).
pub const TID_REQUEST: u32 = 4;
/// The `dram_pools` counter track ([`LedgerSample`]s).
pub const TID_LEDGER: u32 = 8;
pub const TID_GOVERNOR: u32 = 9;
/// I/O workers take `TID_IO_BASE + slot`.
pub const TID_IO_BASE: u32 = 10;

fn tid_name(tid: u32) -> String {
    match tid {
        TID_SCHED => "scheduler".into(),
        TID_ENGINE => "engine".into(),
        TID_LOADER => "loader".into(),
        TID_REQUEST => "requests".into(),
        TID_LEDGER => "dram".into(),
        TID_GOVERNOR => "governor".into(),
        t if t >= TID_IO_BASE => format!("io-{}", t - TID_IO_BASE),
        t => format!("track-{t}"),
    }
}

// ---------------------------------------------------------------- journal

/// One governor re-budget, as journaled: the decision's trigger, the
/// applied ledger, and how long the engine took to settle.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    /// µs since the recorder's epoch.
    pub t_us: u64,
    /// `RebudgetTrigger::name()`.
    pub trigger: &'static str,
    /// False = gated off (hysteresis) or infeasible; ledger fields then
    /// reflect the still-standing previous plan.
    pub applied: bool,
    pub note: String,
    pub old_budget: u64,
    pub new_budget: u64,
    pub cache_bytes: u64,
    pub preload_bytes: u64,
    pub compute_bytes: u64,
    pub max_seqs: usize,
    pub settle_us: u64,
    /// Per-client expected-occupancy inputs at decision time: p90 ended-
    /// sequence token length by client tag (empty when no tagged traffic
    /// has finished — the governor then plans on the global histogram).
    pub client_p90s: Vec<(String, u64)>,
}

impl JournalEntry {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("t_us", num(self.t_us as f64)),
            ("trigger", s(self.trigger)),
            ("applied", Value::Bool(self.applied)),
            ("note", s(&self.note)),
            ("old_budget", num(self.old_budget as f64)),
            ("new_budget", num(self.new_budget as f64)),
            ("cache_bytes", num(self.cache_bytes as f64)),
            ("preload_bytes", num(self.preload_bytes as f64)),
            ("compute_bytes", num(self.compute_bytes as f64)),
            ("max_seqs", num(self.max_seqs as f64)),
            ("settle_us", num(self.settle_us as f64)),
            (
                "client_p90",
                obj(self
                    .client_p90s
                    .iter()
                    .map(|(c, p)| (c.as_str(), num(*p as f64)))
                    .collect()),
            ),
        ])
    }
}

/// One DRAM occupancy sample (per scheduler wave, recorded only while
/// tracing is enabled): the governor's three planned pools plus the two
/// measured consumers the plan prices — KV pool resident bytes and the
/// loader's preload slab bytes. Exported as the `dram_pools` counter
/// track so re-budget journal steps line up with the occupancy that
/// triggered them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LedgerSample {
    /// µs since the recorder's epoch.
    pub t_us: u64,
    pub cache_bytes: u64,
    pub preload_bytes: u64,
    pub compute_bytes: u64,
    pub kv_bytes: u64,
    pub slab_bytes: u64,
}

// ----------------------------------------------------------- the recorder

/// Default span-ring capacity (bounded DRAM: 65536 × 40 B ≈ 2.5 MiB).
pub const DEFAULT_RING_CAP: usize = 65536;
/// Journal ring capacity (re-budgets are rare; 256 is hours of history).
pub const JOURNAL_CAP: usize = 256;
/// A producer's local buffer flushes itself past this many spans even
/// between wave boundaries, bounding per-producer memory.
const LOCAL_BUF_CAP: usize = 4096;
/// DRAM ledger sampler capacity (one sample per wave; 4096 waves of
/// history in ~200 KiB).
pub const LEDGER_CAP: usize = 4096;

struct TraceInner {
    ring: VecDeque<SpanEvent>,
    dropped: u64,
    /// Total spans ever pushed — the subscriber cursor space. The ring
    /// holds positions `[pushed - ring.len(), pushed)`.
    pushed: u64,
    journal: VecDeque<JournalEntry>,
    journal_dropped: u64,
    ledger: VecDeque<LedgerSample>,
    ledger_dropped: u64,
}

/// The shared recorder. Clone the `Arc` ([`TraceHandle`]) into every
/// producer; span recording goes through per-producer [`TraceBuf`]s so
/// the one mutex here is taken only at flush boundaries (and for rare
/// directly-pushed events: waves, re-budgets).
pub struct TraceShared {
    enabled: AtomicBool,
    epoch: Instant,
    cap: usize,
    inner: Mutex<TraceInner>,
}

pub type TraceHandle = Arc<TraceShared>;

impl TraceShared {
    pub fn new(cap: usize) -> TraceHandle {
        Arc::new(TraceShared {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            cap: cap.max(16),
            inner: Mutex::new(TraceInner {
                ring: VecDeque::new(),
                dropped: 0,
                pushed: 0,
                journal: VecDeque::new(),
                journal_dropped: 0,
                ledger: VecDeque::new(),
                ledger_dropped: 0,
            }),
        })
    }

    /// The disabled-path cost of every producer check: one relaxed load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// µs since the recorder's epoch (every span's clock).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Push one span directly (rare events — wave, rebudget). Producers
    /// with per-event volume use a [`TraceBuf`] instead.
    pub fn push_one(&self, ev: SpanEvent) {
        if !self.enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        Self::push_locked(&mut g, self.cap, ev);
    }

    fn push_locked(g: &mut TraceInner, cap: usize, ev: SpanEvent) {
        if g.ring.len() >= cap {
            g.ring.pop_front();
            g.dropped += 1;
        }
        g.ring.push_back(ev);
        g.pushed += 1;
    }

    /// Drain a producer's local buffer into the ring (one lock per
    /// flush). Oldest events are dropped (and counted) past `cap` — a
    /// flight recorder keeps the most recent window.
    fn push_batch(&self, buf: &mut Vec<SpanEvent>) {
        let mut g = self.inner.lock().unwrap();
        for ev in buf.drain(..) {
            Self::push_locked(&mut g, self.cap, ev);
        }
    }

    /// Journal a governor decision (recorded even with span tracing
    /// off — bounded, rare, and `{"cmd":"journal"}` must always work).
    pub fn record_journal(&self, e: JournalEntry) {
        let mut g = self.inner.lock().unwrap();
        if g.journal.len() >= JOURNAL_CAP {
            g.journal.pop_front();
            g.journal_dropped += 1;
        }
        g.journal.push_back(e);
    }

    /// `(events_held, ring_capacity, events_dropped)` for `stats`.
    pub fn ring_stats(&self) -> (usize, usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.ring.len(), self.cap, g.dropped)
    }

    /// `(entries_held, entries_dropped)`.
    pub fn journal_stats(&self) -> (usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.journal.len(), g.journal_dropped)
    }

    pub fn snapshot_spans(&self) -> Vec<SpanEvent> {
        let g = self.inner.lock().unwrap();
        g.ring.iter().copied().collect()
    }

    pub fn snapshot_journal(&self) -> Vec<JournalEntry> {
        let g = self.inner.lock().unwrap();
        g.journal.iter().cloned().collect()
    }

    /// Cursor read for the streaming subscriber: every span pushed since
    /// `cursor` (a position in the all-time pushed sequence) that the
    /// ring still holds. Non-destructive — snapshot commands and other
    /// subscribers see the same ring. Returns
    /// `(spans, new_cursor, missed)` where `missed` counts spans that
    /// aged out of the bounded ring before this read (they are gone; the
    /// count is the honesty signal). Pass `new_cursor` back next time.
    pub fn drain_since(
        &self,
        cursor: u64,
    ) -> (Vec<SpanEvent>, u64, u64) {
        let g = self.inner.lock().unwrap();
        let window_lo = g.pushed - g.ring.len() as u64;
        let (start, missed) = if cursor < window_lo {
            (window_lo, window_lo - cursor)
        } else {
            (cursor.min(g.pushed), 0)
        };
        let spans = g
            .ring
            .iter()
            .skip((start - window_lo) as usize)
            .copied()
            .collect();
        (spans, g.pushed, missed)
    }

    /// Record one DRAM occupancy sample (per wave; gated on the span
    /// switch — the ledger is a trace surface, not an always-on one).
    pub fn record_ledger(&self, sample: LedgerSample) {
        if !self.enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.ledger.len() >= LEDGER_CAP {
            g.ledger.pop_front();
            g.ledger_dropped += 1;
        }
        g.ledger.push_back(sample);
    }

    /// `(samples_held, samples_dropped)`.
    pub fn ledger_stats(&self) -> (usize, u64) {
        let g = self.inner.lock().unwrap();
        (g.ledger.len(), g.ledger_dropped)
    }

    pub fn snapshot_ledger(&self) -> Vec<LedgerSample> {
        let g = self.inner.lock().unwrap();
        g.ledger.iter().copied().collect()
    }

    /// Zero the rings and drop counters (`stats_reset`). Leaves
    /// `enabled` — and the subscriber cursor space (`pushed`) — as is,
    /// so live subscribers see a clear as a quiet window, not a replay.
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.ring.clear();
        g.dropped = 0;
        g.journal.clear();
        g.journal_dropped = 0;
        g.ledger.clear();
        g.ledger_dropped = 0;
    }
}

/// A producer's private span buffer: push without locking, flush at the
/// producer's natural boundary (wave end, step end, batch end). With
/// tracing disabled `span()` allocates nothing — the `Vec` only ever
/// grows while enabled.
pub struct TraceBuf {
    shared: TraceHandle,
    tid: u32,
    buf: Vec<SpanEvent>,
}

impl TraceBuf {
    pub fn new(shared: TraceHandle, tid: u32) -> TraceBuf {
        TraceBuf {
            shared,
            tid,
            buf: Vec::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.shared.enabled()
    }

    /// µs since the shared epoch. Producers bracket work with
    /// `let t0 = buf.now_us(); ...; buf.span(kind, t0, a, b)`.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.shared.now_us()
    }

    pub fn handle(&self) -> &TraceHandle {
        &self.shared
    }

    /// Record a span ending now. No-op (no allocation) when disabled.
    // pallas-lint: hot-path
    #[inline]
    pub fn span(
        &mut self,
        kind: SpanKind,
        t0_us: u64,
        ctx: SpanCtx,
        a: u64,
        b: u64,
    ) {
        if !self.enabled() {
            return;
        }
        let now = self.shared.now_us();
        self.span_at(kind, t0_us, now.saturating_sub(t0_us), ctx, a, b);
    }

    /// Record a span with an explicit duration.
    // pallas-lint: hot-path
    #[inline]
    pub fn span_at(
        &mut self,
        kind: SpanKind,
        t0_us: u64,
        dur_us: u64,
        ctx: SpanCtx,
        a: u64,
        b: u64,
    ) {
        if !self.enabled() {
            return;
        }
        if self.buf.len() >= LOCAL_BUF_CAP {
            self.flush();
        }
        self.buf.push(SpanEvent {
            kind,
            t0_us,
            dur_us,
            tid: self.tid,
            ctx,
            a,
            b,
        });
    }

    /// Drain into the shared ring (call at wave/step/batch boundaries).
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.shared.push_batch(&mut self.buf);
    }
}

// ------------------------------------------------------------ trace export

/// Export the recorder as a Chrome trace-event JSON object
/// (`{"traceEvents": [...], "otherData": {...}}`) — loadable in Perfetto
/// or `chrome://tracing`. Spans become balanced `B`/`E` duration-event
/// pairs per thread track (per-tid sort by start, longest-first at ties,
/// children clamped into their parents so the nesting is always valid),
/// each carrying its `req`/`seq` context as args; the contexts then
/// compile into flow events (`ph:"s"/"f"`, one integer id per edge)
/// linking request → wave → step → flash I/O; journal entries become
/// `"C"` counter events on the governor track and [`LedgerSample`]s a
/// `dram_pools` counter track; thread names ride as `"M"` metadata
/// events.
///
/// Flow endpoints bind to slices by **exact begin timestamp** on the
/// endpoint's track (`s.ts` = parent's `B.ts`, `f.ts` = child's
/// `B.ts`), the contract `scripts/check_trace.py --require-flows`
/// validates. Edges are emitted only for spans whose `ctx.req` is
/// nonzero **and** whose request root span is still in the ring; an
/// I/O-class span whose parent step aged out of the ring falls back to
/// a direct request → span edge, so reachability survives ring drops.
pub fn chrome_trace(h: &TraceHandle) -> Value {
    let spans = h.snapshot_spans();
    let journal = h.snapshot_journal();
    let ledger = h.snapshot_ledger();
    let (_, cap, dropped) = h.ring_stats();

    let mut events: Vec<Value> = Vec::new();

    // thread-name metadata, one per track present
    let mut tids: Vec<u32> = spans.iter().map(|e| e.tid).collect();
    if !journal.is_empty() {
        tids.push(TID_GOVERNOR);
    }
    if !ledger.is_empty() {
        tids.push(TID_LEDGER);
    }
    tids.sort_unstable();
    tids.dedup();
    for tid in &tids {
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(1.0)),
            ("tid", num(*tid as f64)),
            ("args", obj(vec![("name", s(&tid_name(*tid)))])),
        ]));
    }

    // duration events, balanced per tid
    let mut by_tid: Vec<(u32, Vec<SpanEvent>)> = Vec::new();
    for tid in &tids {
        let mut evs: Vec<SpanEvent> =
            spans.iter().filter(|e| e.tid == *tid).copied().collect();
        // start ascending; at equal starts the longest span is the parent
        evs.sort_by(|x, y| {
            x.t0_us.cmp(&y.t0_us).then(y.dur_us.cmp(&x.dur_us))
        });
        if !evs.is_empty() {
            by_tid.push((*tid, evs));
        }
    }
    for (tid, evs) in by_tid {
        // stack of (end_us, name) — emit E on pop
        let mut stack: Vec<(u64, &'static str)> = Vec::new();
        let emit_e = |events: &mut Vec<Value>, end: u64, name: &str| {
            events.push(obj(vec![
                ("ph", s("E")),
                ("name", s(name)),
                ("pid", num(1.0)),
                ("tid", num(tid as f64)),
                ("ts", num(end as f64)),
            ]));
        };
        for ev in evs {
            while let Some(&(end, name)) = stack.last() {
                if end <= ev.t0_us {
                    emit_e(&mut events, end, name);
                    stack.pop();
                } else {
                    break;
                }
            }
            // clamp into the open parent: recorded durations come off
            // concurrent clocks, so a child overrunning its parent by a
            // few µs is measurement noise, not structure
            let mut end = ev.t0_us.saturating_add(ev.dur_us);
            if let Some(&(pend, _)) = stack.last() {
                end = end.min(pend);
            }
            events.push(obj(vec![
                ("ph", s("B")),
                ("name", s(ev.kind.name())),
                ("pid", num(1.0)),
                ("tid", num(tid as f64)),
                ("ts", num(ev.t0_us as f64)),
                (
                    "args",
                    obj(vec![
                        ("a", num(ev.a as f64)),
                        ("b", num(ev.b as f64)),
                        ("req", num(ev.ctx.req as f64)),
                        ("seq", num(ev.ctx.seq as f64)),
                    ]),
                ),
            ]));
            stack.push((end, ev.kind.name()));
        }
        while let Some((end, name)) = stack.pop() {
            emit_e(&mut events, end, name);
        }
    }

    // ---- causal flow edges, compiled from span contexts.
    // Parent resolution: a step binds into its containing wave (time
    // containment on the scheduler track) with a deduplicated
    // request → wave edge above it; an I/O-class span binds to the
    // latest step of its (req, seq) that began at or before it. Either
    // falls back to a direct request → span edge when the intermediate
    // span is missing from the ring. Edges where the clock would run
    // backwards (parent begin after child begin) are dropped rather
    // than emitted invalid — `s.ts ≤ f.ts` is structural.
    use std::collections::{HashMap, HashSet};
    let mut req_roots: HashMap<u64, (u32, u64)> = HashMap::new();
    for e in &spans {
        if e.kind == SpanKind::Request && e.ctx.req != 0 {
            req_roots.entry(e.ctx.req).or_insert((e.tid, e.t0_us));
        }
    }
    let mut waves: Vec<&SpanEvent> =
        spans.iter().filter(|e| e.kind == SpanKind::Wave).collect();
    waves.sort_by_key(|e| e.t0_us);
    let mut steps: HashMap<(u64, u64), Vec<u64>> = HashMap::new();
    for e in &spans {
        if e.kind == SpanKind::Step && e.ctx.req != 0 {
            steps
                .entry((e.ctx.req, e.ctx.seq))
                .or_default()
                .push(e.t0_us);
        }
    }
    for v in steps.values_mut() {
        v.sort_unstable();
    }
    // (parent (tid, ts), child (tid, ts)) pairs, in emission order
    let mut edges: Vec<((u32, u64), (u32, u64))> = Vec::new();
    let mut req_wave_seen: HashSet<(u64, u64)> = HashSet::new();
    for e in &spans {
        if e.ctx.req == 0 {
            continue;
        }
        let root = match req_roots.get(&e.ctx.req) {
            Some(r) => *r,
            None => continue,
        };
        let child = (e.tid, e.t0_us);
        match e.kind {
            SpanKind::Request | SpanKind::Wave | SpanKind::Rebudget => {}
            SpanKind::Step => {
                let i = waves.partition_point(|w| w.t0_us <= e.t0_us);
                let wave = i.checked_sub(1).map(|i| waves[i]).filter(|w| {
                    w.t0_us.saturating_add(w.dur_us) >= e.t0_us
                });
                if let Some(w) = wave {
                    if req_wave_seen.insert((e.ctx.req, w.t0_us))
                        && root.1 <= w.t0_us
                    {
                        edges.push((root, (w.tid, w.t0_us)));
                    }
                    edges.push(((w.tid, w.t0_us), child));
                } else if root.1 <= e.t0_us {
                    edges.push((root, child));
                }
            }
            SpanKind::LayerFetch
            | SpanKind::PreloadPart
            | SpanKind::IoBatch
            | SpanKind::OndemandRead => {
                let step_t0 =
                    steps.get(&(e.ctx.req, e.ctx.seq)).and_then(|v| {
                        let i = v.partition_point(|&t| t <= e.t0_us);
                        i.checked_sub(1).map(|i| v[i])
                    });
                if let Some(t) = step_t0 {
                    edges.push(((TID_ENGINE, t), child));
                } else if root.1 <= e.t0_us {
                    edges.push((root, child));
                }
            }
        }
    }
    for (i, ((ptid, pts), (ctid, cts))) in edges.iter().enumerate() {
        let id = num((i + 1) as f64);
        events.push(obj(vec![
            ("ph", s("s")),
            ("cat", s("causal")),
            ("name", s("causal")),
            ("id", id.clone()),
            ("pid", num(1.0)),
            ("tid", num(*ptid as f64)),
            ("ts", num(*pts as f64)),
        ]));
        events.push(obj(vec![
            ("ph", s("f")),
            ("bp", s("e")),
            ("cat", s("causal")),
            ("name", s("causal")),
            ("id", id),
            ("pid", num(1.0)),
            ("tid", num(*ctid as f64)),
            ("ts", num(*cts as f64)),
        ]));
    }

    // DRAM occupancy counter track from the ledger sampler
    for sm in &ledger {
        events.push(obj(vec![
            ("ph", s("C")),
            ("name", s("dram_pools")),
            ("pid", num(1.0)),
            ("tid", num(TID_LEDGER as f64)),
            ("ts", num(sm.t_us as f64)),
            (
                "args",
                obj(vec![
                    ("cache", num(sm.cache_bytes as f64)),
                    ("preload", num(sm.preload_bytes as f64)),
                    ("compute", num(sm.compute_bytes as f64)),
                    ("kv", num(sm.kv_bytes as f64)),
                    ("slab", num(sm.slab_bytes as f64)),
                ]),
            ),
        ]));
    }

    // governor counter track from the journal
    for e in &journal {
        events.push(obj(vec![
            ("ph", s("C")),
            ("name", s("governor_ledger")),
            ("pid", num(1.0)),
            ("tid", num(TID_GOVERNOR as f64)),
            ("ts", num(e.t_us as f64)),
            (
                "args",
                obj(vec![
                    ("budget", num(e.new_budget as f64)),
                    ("cache", num(e.cache_bytes as f64)),
                    ("preload", num(e.preload_bytes as f64)),
                    ("compute", num(e.compute_bytes as f64)),
                    ("max_seqs", num(e.max_seqs as f64)),
                ]),
            ),
        ]));
    }

    obj(vec![
        ("traceEvents", arr(events)),
        (
            "otherData",
            obj(vec![
                ("ring_capacity", num(cap as f64)),
                ("dropped", num(dropped as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // ------------------------------------------------------------ Histo

    #[test]
    fn histo_bucket_boundaries_exact() {
        // bucket 0 = {0}; bucket i ≥ 1 = [2^(i-1), 2^i)
        assert_eq!(Histo::bucket_of(0), 0);
        assert_eq!(Histo::bucket_of(1), 1);
        assert_eq!(Histo::bucket_of(2), 2);
        assert_eq!(Histo::bucket_of(3), 2);
        assert_eq!(Histo::bucket_of(4), 3);
        assert_eq!(Histo::bucket_of(7), 3);
        assert_eq!(Histo::bucket_of(8), 4);
        for i in 1..63usize {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(Histo::bucket_of(lo), i, "lower edge of bucket {i}");
            assert_eq!(Histo::bucket_of(hi), i, "upper edge of bucket {i}");
            assert_eq!(Histo::bucket_upper_edge(i), hi);
        }
        assert_eq!(Histo::bucket_of(u64::MAX), 63);
        assert_eq!(Histo::bucket_upper_edge(63), u64::MAX);
    }

    #[test]
    fn histo_records_and_reports() {
        let mut h = Histo::new();
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.min(), 0);
        for v in [3u64, 5, 9, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1117);
        assert_eq!(h.min(), 3);
        assert_eq!(h.max(), 1000);
        // p100 clamps to the exact observed max
        assert_eq!(h.percentile(1.0), 1000);
    }

    #[test]
    fn histo_merge_associative_and_commutative() {
        let mk = |vals: &[u64]| {
            let mut h = Histo::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = mk(&[1, 2, 3, 1000]);
        let b = mk(&[7, 7, 7]);
        let c = mk(&[0, 50_000, u64::MAX]);

        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut left = a;
        left.merge(&b);
        left.merge(&c);
        let mut bc = b;
        bc.merge(&c);
        let mut right = a;
        right.merge(&bc);
        assert_eq!(left, right);

        // a ⊕ b == b ⊕ a
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba);
    }

    #[test]
    fn histo_percentiles_monotone() {
        // several shapes, incl. heavily skewed ones
        let shapes: Vec<Vec<u64>> = vec![
            (1..=100u64).collect(),
            vec![1; 99].into_iter().chain([1_000_000]).collect(),
            vec![0, 0, 0, 1, 2, 4, 8, 16, 1 << 40],
            vec![42],
        ];
        for vals in shapes {
            let mut h = Histo::new();
            for &v in &vals {
                h.record(v);
            }
            assert!(
                h.p50() <= h.p95() && h.p95() <= h.p99(),
                "p50={} p95={} p99={} for {vals:?}",
                h.p50(),
                h.p95(),
                h.p99()
            );
            assert!(h.p99() <= h.max());
        }
    }

    #[test]
    fn histo_percentile_is_conservative_upper_edge() {
        let mut h = Histo::new();
        for _ in 0..100 {
            h.record(5); // bucket 3 = [4, 8)
        }
        // upper edge of bucket 3 is 7, but the observed max clamps it
        assert_eq!(h.p50(), 5);
        h.record(7);
        assert_eq!(h.p99(), 7);
    }

    // ------------------------------------------------------------- ring

    fn ev(t0: u64, dur: u64, tid: u32) -> SpanEvent {
        SpanEvent {
            kind: SpanKind::Step,
            t0_us: t0,
            dur_us: dur,
            tid,
            ctx: SpanCtx::NONE,
            a: 0,
            b: 0,
        }
    }

    #[test]
    fn ring_overflow_counts_drops() {
        let h = TraceShared::new(16);
        h.set_enabled(true);
        let mut buf = TraceBuf::new(h.clone(), TID_ENGINE);
        for i in 0..24u64 {
            buf.span_at(SpanKind::Step, i * 10, 5, SpanCtx::NONE, i, 0);
        }
        buf.flush();
        let (len, cap, dropped) = h.ring_stats();
        assert_eq!(cap, 16);
        assert_eq!(len, 16);
        assert_eq!(dropped, 8);
        // the ring kept the NEWEST window
        let spans = h.snapshot_spans();
        assert_eq!(spans.first().unwrap().t0_us, 80);
        assert_eq!(spans.last().unwrap().t0_us, 230);
    }

    #[test]
    fn disabled_recorder_stores_nothing() {
        let h = TraceShared::new(64);
        let mut buf = TraceBuf::new(h.clone(), TID_ENGINE);
        buf.span_at(SpanKind::Step, 0, 5, SpanCtx::NONE, 0, 0);
        buf.flush();
        h.push_one(ev(0, 1, TID_SCHED));
        let (len, _, dropped) = h.ring_stats();
        assert_eq!((len, dropped), (0, 0));
    }

    #[test]
    fn clear_resets_rings_and_drop_counters() {
        let h = TraceShared::new(4);
        h.set_enabled(true);
        for i in 0..9u64 {
            h.push_one(ev(i, 1, TID_SCHED));
        }
        h.record_journal(JournalEntry {
            t_us: 1,
            trigger: "command",
            applied: true,
            note: String::new(),
            old_budget: 2,
            new_budget: 1,
            cache_bytes: 1,
            preload_bytes: 0,
            compute_bytes: 0,
            max_seqs: 4,
            settle_us: 10,
            client_p90s: vec![],
        });
        h.record_ledger(LedgerSample {
            t_us: 2,
            cache_bytes: 1,
            preload_bytes: 1,
            compute_bytes: 1,
            kv_bytes: 1,
            slab_bytes: 1,
        });
        h.clear();
        assert_eq!(h.ring_stats(), (0, 4, 0));
        assert_eq!(h.journal_stats(), (0, 0));
        assert_eq!(h.ledger_stats(), (0, 0));
        assert!(h.enabled(), "clear must not flip the enable switch");
        // the cursor space is NOT reset: a subscriber's cursor stays
        // valid across stats_reset (it sees a quiet window, no replay)
        let (spans, cursor, missed) = h.drain_since(0);
        assert!(spans.is_empty());
        assert_eq!(cursor, 9);
        assert_eq!(missed, 9);
    }

    #[test]
    fn drain_since_cursor_and_missed_accounting() {
        let h = TraceShared::new(4);
        h.set_enabled(true);
        for i in 0..3u64 {
            h.push_one(ev(i, 1, TID_SCHED));
        }
        // first read from zero: everything, no misses
        let (spans, cur, missed) = h.drain_since(0);
        assert_eq!(spans.len(), 3);
        assert_eq!((cur, missed), (3, 0));
        // nothing new: empty, cursor stable
        let (spans, cur2, missed) = h.drain_since(cur);
        assert!(spans.is_empty());
        assert_eq!((cur2, missed), (3, 0));
        // push 6 more into a cap-4 ring: positions 3..9, ring holds 5..9
        for i in 3..9u64 {
            h.push_one(ev(i, 1, TID_SCHED));
        }
        let (spans, cur3, missed) = h.drain_since(cur2);
        assert_eq!(cur3, 9);
        assert_eq!(missed, 2, "positions 3 and 4 aged out");
        assert_eq!(spans.len(), 4);
        assert_eq!(spans.first().unwrap().t0_us, 5);
        assert_eq!(spans.last().unwrap().t0_us, 8);
        // snapshot reads are unaffected by cursor reads
        assert_eq!(h.snapshot_spans().len(), 4);
    }

    #[test]
    fn ledger_ring_bounded_and_gated() {
        let h = TraceShared::new(16);
        let mk = |i: u64| LedgerSample {
            t_us: i,
            cache_bytes: i,
            preload_bytes: 0,
            compute_bytes: 0,
            kv_bytes: 0,
            slab_bytes: 0,
        };
        // disabled: samples are dropped silently (trace surface)
        h.record_ledger(mk(0));
        assert_eq!(h.ledger_stats(), (0, 0));
        h.set_enabled(true);
        for i in 0..(LEDGER_CAP as u64 + 10) {
            h.record_ledger(mk(i));
        }
        let (len, dropped) = h.ledger_stats();
        assert_eq!(len, LEDGER_CAP);
        assert_eq!(dropped, 10);
        assert_eq!(h.snapshot_ledger().first().unwrap().t_us, 10);
    }

    // ----------------------------------------------------------- export

    /// Walk exported events checking balance + per-tid ts monotonicity
    /// + flow-event s/f pairing (the Rust-side mirror of
    /// scripts/check_trace.py). Flow events are exempt from the per-tid
    /// monotonicity walk — they are appended after the duration events
    /// and bind across tracks — but every flow id must carry exactly
    /// one `s` and one `f`, with `f.ts ≥ s.ts`.
    fn check_exported(v: &Value) {
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        use std::collections::HashMap;
        let mut stacks: HashMap<u64, Vec<String>> = HashMap::new();
        let mut last_ts: HashMap<u64, f64> = HashMap::new();
        let mut flow_s: HashMap<u64, f64> = HashMap::new();
        let mut flow_f: HashMap<u64, f64> = HashMap::new();
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            if ph == "M" {
                continue;
            }
            let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            if ph == "s" || ph == "f" {
                let id = e.get("id").unwrap().as_f64().unwrap() as u64;
                let side = if ph == "s" { &mut flow_s } else { &mut flow_f };
                assert!(
                    side.insert(id, ts).is_none(),
                    "duplicate flow {ph} for id {id}"
                );
                continue;
            }
            let prev = last_ts.entry(tid).or_insert(f64::MIN);
            assert!(ts >= *prev, "ts must be monotone per tid");
            *prev = ts;
            match ph {
                "B" => stacks
                    .entry(tid)
                    .or_default()
                    .push(e.get("name").unwrap().as_str().unwrap().into()),
                "E" => {
                    let name = e.get("name").unwrap().as_str().unwrap();
                    let top = stacks
                        .get_mut(&tid)
                        .and_then(|s| s.pop())
                        .expect("E without open B");
                    assert_eq!(top, name, "E name must match open B");
                }
                "C" => {}
                other => panic!("unexpected ph {other}"),
            }
        }
        for (tid, st) in stacks {
            assert!(st.is_empty(), "unclosed B events on tid {tid}");
        }
        assert_eq!(
            flow_s.keys().collect::<std::collections::HashSet<_>>(),
            flow_f.keys().collect::<std::collections::HashSet<_>>(),
            "every flow id needs one s and one f"
        );
        for (id, s_ts) in &flow_s {
            assert!(
                flow_f[id] >= *s_ts,
                "flow {id} runs backwards (f.ts < s.ts)"
            );
        }
    }

    /// Flow edges out of an export, as (s_ts, f_ts) pairs keyed by id.
    fn flow_edges(v: &Value) -> Vec<(f64, f64)> {
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        use std::collections::HashMap;
        let mut s_ts: HashMap<u64, f64> = HashMap::new();
        let mut f_ts: HashMap<u64, f64> = HashMap::new();
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            if ph != "s" && ph != "f" {
                continue;
            }
            let id = e.get("id").unwrap().as_f64().unwrap() as u64;
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            if ph == "s" {
                s_ts.insert(id, ts);
            } else {
                f_ts.insert(id, ts);
            }
        }
        let mut ids: Vec<u64> = s_ts.keys().copied().collect();
        ids.sort_unstable();
        ids.iter().map(|i| (s_ts[i], f_ts[i])).collect()
    }

    #[test]
    fn export_is_balanced_and_monotone() {
        let h = TraceShared::new(256);
        h.set_enabled(true);
        let mut eng = TraceBuf::new(h.clone(), TID_ENGINE);
        let mut load = TraceBuf::new(h.clone(), TID_LOADER);
        // nested: step containing two layer fetches, one overrunning
        eng.span_at(SpanKind::Step, 100, 100, SpanCtx::NONE, 1, 0);
        eng.span_at(SpanKind::LayerFetch, 110, 20, SpanCtx::NONE, 0, 0);
        // overruns parent
        eng.span_at(SpanKind::LayerFetch, 150, 80, SpanCtx::NONE, 1, 0);
        // loader: preload part overlapping the step in wall time
        load.span_at(SpanKind::PreloadPart, 120, 60, SpanCtx::NONE, 7, 2);
        eng.flush();
        load.flush();
        h.push_one(SpanEvent {
            kind: SpanKind::Wave,
            t0_us: 90,
            dur_us: 130,
            tid: TID_SCHED,
            ctx: SpanCtx::NONE,
            a: 1,
            b: 0,
        });
        h.record_journal(JournalEntry {
            t_us: 210,
            trigger: "pressure",
            applied: true,
            note: "test".into(),
            old_budget: 100,
            new_budget: 80,
            cache_bytes: 40,
            preload_bytes: 20,
            compute_bytes: 20,
            max_seqs: 2,
            settle_us: 300,
            client_p90s: vec![("tenant-a".into(), 64)],
        });
        h.record_ledger(LedgerSample {
            t_us: 220,
            cache_bytes: 40,
            preload_bytes: 20,
            compute_bytes: 20,
            kv_bytes: 8,
            slab_bytes: 4,
        });
        let v = chrome_trace(&h);
        check_exported(&v);
        let other = v.get("otherData").unwrap();
        assert_eq!(other.get("dropped").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            other.get("ring_capacity").unwrap().as_f64().unwrap(),
            256.0
        );
        // the journal and ledger both produced counter events
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        for name in ["governor_ledger", "dram_pools"] {
            assert!(
                events.iter().any(|e| e
                    .get("ph")
                    .map(|p| p.as_str() == Some("C"))
                    .unwrap_or(false)
                    && e.get("name").map(|n| n.as_str() == Some(name))
                        == Some(true)),
                "missing C track {name}"
            );
        }
        // ctx-less spans compile into zero flow events
        assert!(flow_edges(&v).is_empty());
        // and the trace round-trips through the json module
        let parsed = crate::util::json::parse(&v.to_string()).unwrap();
        check_exported(&parsed);
    }

    /// The causal contract across all seven pre-existing span kinds
    /// under one request root: every ctx-carrying span reaches the
    /// export with `req`/`seq` args, flows pair s/f with matching
    /// begin-timestamps, and every I/O-class span has an inbound edge
    /// from its step (or the request root when the step is gone).
    #[test]
    fn flows_link_request_to_io_across_all_kinds() {
        let h = TraceShared::new(256);
        h.set_enabled(true);
        let ctx = SpanCtx::new(41, 7);
        let mut eng = TraceBuf::new(h.clone(), TID_ENGINE);
        let mut load = TraceBuf::new(h.clone(), TID_LOADER);
        let mut io = TraceBuf::new(h.clone(), TID_IO_BASE);

        // request root: submit at t=50, retired at t=400
        h.push_one(SpanEvent {
            kind: SpanKind::Request,
            t0_us: 50,
            dur_us: 350,
            tid: TID_REQUEST,
            ctx,
            a: 3,
            b: 120,
        });
        // wave (ctx-less by design) containing the steps
        h.push_one(SpanEvent {
            kind: SpanKind::Wave,
            t0_us: 90,
            dur_us: 200,
            tid: TID_SCHED,
            ctx: SpanCtx::NONE,
            a: 1,
            b: 0,
        });
        // two steps of the request inside the wave
        eng.span_at(SpanKind::Step, 100, 60, ctx, 7, 0);
        eng.span_at(SpanKind::Step, 200, 60, ctx, 7, 1);
        // io-class children: bind to the LATEST step at-or-before them
        eng.span_at(SpanKind::LayerFetch, 110, 20, ctx, 0, 0);
        eng.span_at(SpanKind::OndemandRead, 130, 10, ctx, 0, 4);
        load.span_at(SpanKind::PreloadPart, 210, 30, ctx, 7, 2);
        io.span_at(SpanKind::IoBatch, 220, 15, ctx, 4, 0);
        // a rebudget records NONE and never joins the flow graph
        h.push_one(SpanEvent {
            kind: SpanKind::Rebudget,
            t0_us: 300,
            dur_us: 10,
            tid: TID_GOVERNOR,
            ctx: SpanCtx::NONE,
            a: 0,
            b: 0,
        });
        eng.flush();
        load.flush();
        io.flush();

        let v = chrome_trace(&h);
        check_exported(&v);

        // every ctx-carrying B event exports req/seq args
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        for e in events {
            if e.get("ph").unwrap().as_str() != Some("B") {
                continue;
            }
            let name = e.get("name").unwrap().as_str().unwrap();
            let req = e
                .get("args")
                .unwrap()
                .get("req")
                .unwrap()
                .as_f64()
                .unwrap();
            match name {
                "wave" | "rebudget" => assert_eq!(req, 0.0),
                _ => assert_eq!(req, 41.0, "span {name} lost its ctx"),
            }
        }

        // edges: request->wave (dedup'd to ONE despite two steps),
        // wave->step x2, step->io x4
        let edges = flow_edges(&v);
        assert_eq!(edges.len(), 7, "edges: {edges:?}");
        let count = |s: f64, f: f64| {
            edges.iter().filter(|(a, b)| (*a, *b) == (s, f)).count()
        };
        assert_eq!(count(50.0, 90.0), 1, "request->wave, deduplicated");
        assert_eq!(count(90.0, 100.0), 1, "wave->step1");
        assert_eq!(count(90.0, 200.0), 1, "wave->step2");
        assert_eq!(count(100.0, 110.0), 1, "step1->layer_fetch");
        assert_eq!(count(100.0, 130.0), 1, "step1->ondemand_read");
        assert_eq!(count(200.0, 210.0), 1, "step2->preload_part");
        assert_eq!(count(200.0, 220.0), 1, "step2->io_batch");
    }

    /// Ring drops must not orphan I/O spans: with the parent step aged
    /// out, the edge falls back to request -> io directly; with the
    /// request root itself gone, no edge is emitted at all.
    #[test]
    fn flow_fallbacks_survive_ring_drops() {
        let h = TraceShared::new(256);
        h.set_enabled(true);
        let ctx = SpanCtx::new(9, 2);
        // request root + io span, NO step/wave in the ring
        h.push_one(SpanEvent {
            kind: SpanKind::Request,
            t0_us: 10,
            dur_us: 100,
            tid: TID_REQUEST,
            ctx,
            a: 1,
            b: 0,
        });
        h.push_one(SpanEvent {
            kind: SpanKind::IoBatch,
            t0_us: 40,
            dur_us: 5,
            tid: TID_IO_BASE,
            ctx,
            a: 2,
            b: 0,
        });
        // an io span whose request root is NOT in the ring
        h.push_one(SpanEvent {
            kind: SpanKind::OndemandRead,
            t0_us: 60,
            dur_us: 5,
            tid: TID_ENGINE,
            ctx: SpanCtx::new(777, 3),
            a: 0,
            b: 1,
        });
        let v = chrome_trace(&h);
        check_exported(&v);
        let edges = flow_edges(&v);
        assert_eq!(edges, vec![(10.0, 40.0)], "request->io fallback only");
    }

    #[test]
    fn journal_ring_bounded() {
        let h = TraceShared::new(16);
        for i in 0..(JOURNAL_CAP + 10) {
            h.record_journal(JournalEntry {
                t_us: i as u64,
                trigger: "schedule",
                applied: false,
                note: String::new(),
                old_budget: 0,
                new_budget: 0,
                cache_bytes: 0,
                preload_bytes: 0,
                compute_bytes: 0,
                max_seqs: 1,
                settle_us: 0,
                client_p90s: vec![],
            });
        }
        let (len, dropped) = h.journal_stats();
        assert_eq!(len, JOURNAL_CAP);
        assert_eq!(dropped, 10);
    }
}
