//! The analytic system cost model (paper §4.1, Table 1, Eqs 1–9) and the
//! preload-and-computation-balanced greedy parameter search.
//!
//! Latency (Eq 1):  T_decode = T_load + T_overlap + T_comp
//!   T_load    = M_cl·(1−hr) / BW_small                          (Eq 3)
//!   T_comp    = M_cl / BW_mem                                   (Eq 4)
//!   T_overlap = T_onload + max(T_preload, T_comp)               (Eq 5)
//!   T_onload  = S_l·(1−sp)·(1−hr)·(1−si) / BW_small             (Eq 6)
//!   T_preload = M_cl·(1−hr) / BW_large                          (Eq 7)
//! Memory (Eq 8):   M = M_cl + M_cache + M_kv
//!   M_cl      = S_l·(1−sp)·N                                    (Eq 9)
//!
//! T_overlap/T_onload/T_preload/T_comp are *per group* quantities; the
//! per-token decode walks n_layers/N groups, so the steady-state pipeline
//! cost multiplies the overlap term by the group count (first group pays
//! T_load up front, last pays T_comp — Eq 1's three terms).

use crate::device::DeviceProfile;

/// Model geometry as the cost model sees it. Built either from a real AWGF
/// file ([`Geometry::from_awgf`]) or synthetically for paper-scale sweeps
/// (Llama-7B / Mixtral-8x7B presets).
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// Total sparse-weight bytes (S_m).
    pub model_bytes: u64,
    /// Bytes of one layer's sparse weights (S_l).
    pub layer_bytes: u64,
    /// Layer count.
    pub n_layers: usize,
    /// Bytes of one weight channel (small-chunk unit).
    pub channel_bytes: u64,
    /// Fixed KV-cache bytes (M_kv; paper considers it fixed-size).
    pub kv_bytes: u64,
}

impl Geometry {
    pub fn from_awgf(f: &crate::layout::AwgfFile) -> Geometry {
        let m = &f.model;
        let kv = (2 * m.n_layers * m.max_seq * m.d_kv() * 4) as u64;
        // representative channel: an wq row across the group
        let rb = f.op(crate::layout::OpKind::Wq).row_bytes as u64;
        Geometry {
            model_bytes: f.sparse_bytes(),
            layer_bytes: f.layer_bytes(),
            n_layers: m.n_layers,
            channel_bytes: rb * f.group_size as u64,
            kv_bytes: kv,
        }
    }

    /// Llama-2-7B at Q4: ~3.6 GB of sparse weights over 32 layers.
    pub fn llama7b_q4() -> Geometry {
        Geometry {
            model_bytes: 3_600 << 20,
            layer_bytes: (3_600 << 20) / 32,
            n_layers: 32,
            channel_bytes: 4 << 10, // paper: ~4 KB channels (Fig 3)
            kv_bytes: 256 << 20,
        }
    }

    /// Llama-3-8B at Q4.
    pub fn llama8b_q4() -> Geometry {
        Geometry {
            model_bytes: 4_300 << 20,
            layer_bytes: (4_300 << 20) / 32,
            n_layers: 32,
            channel_bytes: 4 << 10,
            kv_bytes: 256 << 20,
        }
    }

    /// Mixtral-8x7B at Q4 (~24.6 GB total, §7.2); per-token expert activity
    /// already behaves like contextual sparsity, modeled via sp.
    pub fn mixtral8x7b_q4() -> Geometry {
        Geometry {
            model_bytes: 24_600u64 << 20,
            layer_bytes: (24_600u64 << 20) / 32,
            n_layers: 32,
            channel_bytes: 14 << 10,
            kv_bytes: 256 << 20,
        }
    }
}

/// Free parameters of the pipeline (Table 1) + measured rates.
#[derive(Debug, Clone, Copy)]
pub struct PipelineParams {
    /// LLM sparsity sp ∈ [0,1).
    pub sp: f64,
    /// Cross-layer group size N ≥ 1.
    pub n_group: usize,
    /// Weight-cache bytes (M_cache).
    pub cache_bytes: u64,
    /// Average cache hit rate hr ∈ [0,1].
    pub hit_rate: f64,
    /// Average cross-layer activation similarity si ∈ [0,1].
    pub similarity: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct CostBreakdown {
    pub t_decode: f64,
    pub t_load: f64,
    pub t_comp_group: f64,
    pub t_onload_group: f64,
    pub t_preload_group: f64,
    pub t_overlap_total: f64,
    pub mem_bytes: u64,
    pub m_cl: u64,
}

/// Evaluate the cost model for a device/geometry/parameter triple.
pub fn evaluate(
    dev: &DeviceProfile,
    geo: &Geometry,
    p: &PipelineParams,
    bw_scale: f64,
) -> CostBreakdown {
    let m_cl = (geo.layer_bytes as f64 * (1.0 - p.sp)) * p.n_group as f64; // Eq 9
    let miss = 1.0 - p.hit_rate;

    // Small chunks: one channel. Large chunks: one channel × N layers.
    let bw_small = dev.bw_small(geo.channel_bytes) * bw_scale;
    let bw_large = dev.bw_large(geo.channel_bytes * p.n_group as u64) * bw_scale;
    let bw_mem = dev.mem_bw;

    let t_load = m_cl * miss / bw_small; // Eq 3
    let _ = bw_mem;
    // Eq 4 — with BW_mem taken as the *achieved* decode bandwidth (weights
    // actually consumed per second by the Q4 matvec loop), not DRAM peak.
    let t_comp = m_cl / dev.decode_bw;
    let t_onload = geo.layer_bytes as f64 * (1.0 - p.sp) * miss
        * (1.0 - p.similarity)
        * p.n_group as f64
        / bw_small; // Eq 6 (per group: S_l × N layers' worth of misses)
    let t_preload = m_cl * miss / bw_large; // Eq 7
    let t_overlap_group = t_onload + t_preload.max(t_comp); // Eq 5

    let n_groups = geo.n_layers.div_ceil(p.n_group.max(1)) as f64;
    // Eq 1: first-group load + steady-state overlapped groups + final compute.
    let t_decode = t_load + t_overlap_group * (n_groups - 1.0).max(0.0) + t_comp;

    let mem = m_cl as u64 + p.cache_bytes + geo.kv_bytes; // Eq 8
    CostBreakdown {
        t_decode,
        t_load,
        t_comp_group: t_comp,
        t_onload_group: t_onload,
        t_preload_group: t_preload,
        t_overlap_total: t_overlap_group * (n_groups - 1.0).max(0.0),
        mem_bytes: mem,
        m_cl: m_cl as u64,
    }
}

/// Estimate hit rate as a function of cache size: caching a fraction f of a
/// tensor's channels catches the hottest f of a skewed (Zipf-ish) selection
/// distribution. Calibrated against the measured context-level curves
/// (Fig 17) — concave, hr(0)=0, hr(1)=1.
pub fn estimated_hit_rate(cache_bytes: u64, geo: &Geometry, sp: f64) -> f64 {
    let active_bytes = geo.model_bytes as f64 * (1.0 - sp);
    if active_bytes <= 0.0 {
        return 1.0;
    }
    let f = (cache_bytes as f64 / active_bytes).clamp(0.0, 1.0);
    // concave locality curve: hot channels first
    f.powf(0.45).min(1.0)
}

/// Result of the greedy search (paper §4.1 "Preload-and-computation-balanced
/// cross-layer group search").
#[derive(Debug, Clone, Copy)]
pub struct SearchResult {
    pub params: PipelineParams,
    pub cost: CostBreakdown,
}

/// Greedy search:
/// 1. sp = 1 − M_max/S_m (highest accuracy that fits; Eq in §4.1),
/// 2. grow N while T_preload > T_comp and the decrement is significant,
/// 3. spend leftover memory on cache.
pub fn search(
    dev: &DeviceProfile,
    geo: &Geometry,
    mem_budget: u64,
    similarity: f64,
    bw_scale: f64,
    sp_grid: &[f64],
) -> Option<SearchResult> {
    // Step 1: minimum sparsity that fits the budget at N=1, no cache.
    let sp_needed = 1.0 - (mem_budget.saturating_sub(geo.kv_bytes)) as f64
        / geo.model_bytes as f64;
    let sp = sp_grid
        .iter()
        .copied()
        .filter(|&s| s >= sp_needed - 1e-9)
        .fold(f64::NAN, |acc: f64, s| if acc.is_nan() || s < acc { s } else { acc });
    if sp.is_nan() {
        return None; // budget smaller than the sparsest configuration
    }

    // Step 2: grow N until preload ≤ compute or gains vanish.
    let mut best: Option<SearchResult> = None;
    let mut n = 1usize;
    let mut last_t = f64::INFINITY;
    while n <= geo.n_layers {
        // Step 3 (inner): leftover memory becomes cache.
        let m_cl = (geo.layer_bytes as f64 * (1.0 - sp) * n as f64) as u64;
        let cache = mem_budget
            .saturating_sub(m_cl)
            .saturating_sub(geo.kv_bytes);
        let hr = estimated_hit_rate(cache, geo, sp);
        let p = PipelineParams {
            sp,
            n_group: n,
            cache_bytes: cache,
            hit_rate: hr,
            similarity,
        };
        let c = evaluate(dev, geo, &p, bw_scale);
        if c.mem_bytes <= mem_budget
            && best.map(|b| c.t_decode < b.cost.t_decode).unwrap_or(true)
        {
            best = Some(SearchResult { params: p, cost: c });
        }
        // stop rules from §4.1
        if c.t_preload_group <= c.t_comp_group {
            break;
        }
        if last_t.is_finite() && (last_t - c.t_decode) / last_t < 0.02 {
            break;
        }
        last_t = c.t_decode;
        n *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{INFINIX_ZERO30, ONEPLUS12, PIXEL6};
    use crate::util::prop::{check, GenExt};

    fn p(sp: f64, n: usize, hr: f64, si: f64) -> PipelineParams {
        PipelineParams {
            sp,
            n_group: n,
            cache_bytes: 0,
            hit_rate: hr,
            similarity: si,
        }
    }

    #[test]
    fn memory_eq9_matches_hand_calc() {
        let geo = Geometry::llama7b_q4();
        let c = evaluate(&PIXEL6, &geo, &p(0.5, 4, 0.0, 0.8), 1.0);
        let want = (geo.layer_bytes as f64 * 0.5 * 4.0) as u64 + geo.kv_bytes;
        assert_eq!(c.mem_bytes, want);
    }

    #[test]
    fn latency_decreases_with_hit_rate() {
        let geo = Geometry::llama7b_q4();
        check("cost-hr-monotone", |g| {
            let sp = *g.choice(&[0.5, 0.6, 0.7, 0.8]);
            let n = g.usize_in(1, 8);
            let si = g.f64() * 0.9;
            let mut last = f64::INFINITY;
            for hr in [0.0, 0.3, 0.6, 0.9] {
                let c = evaluate(&PIXEL6, &geo, &p(sp, n, hr, si), 1.0);
                if c.t_decode > last + 1e-12 {
                    return Err(format!("not monotone at hr={hr}"));
                }
                last = c.t_decode;
            }
            Ok(())
        });
    }

    #[test]
    fn latency_decreases_with_similarity() {
        let geo = Geometry::llama7b_q4();
        let lo = evaluate(&PIXEL6, &geo, &p(0.6, 4, 0.3, 0.2), 1.0);
        let hi = evaluate(&PIXEL6, &geo, &p(0.6, 4, 0.3, 0.9), 1.0);
        assert!(hi.t_decode < lo.t_decode);
    }

    #[test]
    fn memory_increases_with_group_size() {
        let geo = Geometry::llama7b_q4();
        check("cost-mem-monotone", |g| {
            let sp = *g.choice(&[0.5, 0.7]);
            let mut last = 0u64;
            for n in [1usize, 2, 4, 8] {
                let c = evaluate(&PIXEL6, &geo, &p(sp, n, 0.5, 0.8), 1.0);
                if c.mem_bytes <= last {
                    return Err("memory not increasing in N".into());
                }
                last = c.mem_bytes;
            }
            let _ = g.next_u64();
            Ok(())
        });
    }

    #[test]
    fn larger_groups_improve_preload_bandwidth() {
        // Fig 16b: bigger N ⇒ bigger chunks ⇒ lower preload time per byte.
        let geo = Geometry::llama7b_q4();
        let n1 = evaluate(&PIXEL6, &geo, &p(0.6, 1, 0.0, 0.95), 1.0);
        let n4 = evaluate(&PIXEL6, &geo, &p(0.6, 4, 0.0, 0.95), 1.0);
        // per-layer preload time = group preload / N
        assert!(
            n4.t_preload_group / 4.0 < n1.t_preload_group,
            "N=4 per-layer preload should beat N=1"
        );
    }

    #[test]
    fn search_respects_budget() {
        let geo = Geometry::llama7b_q4();
        let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
        check("search-budget", |g| {
            let budget = (1u64 << 30) + g.below(3 << 30);
            for dev in [&ONEPLUS12, &PIXEL6, &INFINIX_ZERO30] {
                if let Some(r) = search(dev, &geo, budget, 0.85, 1.0, &grid) {
                    if r.cost.mem_bytes > budget {
                        return Err(format!(
                            "{}: {} > budget {budget}",
                            dev.name, r.cost.mem_bytes
                        ));
                    }
                    if r.params.n_group < 1 {
                        return Err("N < 1".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn search_returns_none_below_min_memory() {
        let geo = Geometry::llama7b_q4();
        let grid = [0.5, 0.6, 0.7, 0.8];
        assert!(search(&PIXEL6, &geo, 64 << 20, 0.85, 1.0, &grid).is_none());
    }

    #[test]
    fn search_picks_denser_model_with_more_memory() {
        let geo = Geometry::llama7b_q4();
        let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
        let small = search(&PIXEL6, &geo, 1 << 30, 0.85, 1.0, &grid).unwrap();
        let large = search(&PIXEL6, &geo, 3 << 30, 0.85, 1.0, &grid).unwrap();
        assert!(large.params.sp < small.params.sp);
    }

    #[test]
    fn mixtral_fits_2_9gb_like_paper() {
        // §7.2: Mixtral-8x7B 4-bit decodes under 2.9 GB.
        let geo = Geometry::mixtral8x7b_q4();
        let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
        let r = search(&PIXEL6, &geo, 2_900 << 20, 0.85, 1.0, &grid);
        assert!(r.is_some(), "Mixtral should be servable at 2.9 GB");
        assert!(r.unwrap().cost.mem_bytes <= 2_900 << 20);
    }

    #[test]
    fn hit_rate_curve_shape() {
        let geo = Geometry::llama7b_q4();
        assert_eq!(estimated_hit_rate(0, &geo, 0.5), 0.0);
        let half = estimated_hit_rate(geo.model_bytes / 4, &geo, 0.5);
        assert!(half > 0.5, "concave curve: half cache > half hits");
        let full = estimated_hit_rate(geo.model_bytes, &geo, 0.5);
        assert!((full - 1.0).abs() < 1e-9);
    }
}
