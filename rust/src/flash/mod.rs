//! Flash device simulator (DESIGN.md §1 substitution for UFS storage).
//!
//! Real bytes move: reads hit an actual weights file via `pread`. Timing is
//! simulated from the device profile's chunk-size-dependent bandwidth curve
//! (paper Fig 7), in one of two clock modes:
//!
//! * **Timed** — each read sleeps out the remainder of its modeled duration,
//!   so wall-clock pipeline measurements (compute/load overlap, Fig 15/16)
//!   are faithful: an I/O "in flight" costs no CPU, exactly like io_uring
//!   waiting on UFS.
//! * **Modeled** — no sleeping; modeled nanoseconds accumulate on a virtual
//!   clock (fast parameter sweeps, cost-model validation).
//!
//! **Batched reads.** `read_batch` models an io_uring-style submission: the
//! device keeps up to `DeviceProfile::queue_depth` reads in flight, so a
//! batch is serviced in waves of that many and the per-I/O fixed latency is
//! charged once per *wave* — not once per chunk — while the payload streams
//! back-to-back at max bandwidth. This is where most of the usable flash
//! bandwidth comes from (LLM-in-a-flash, arXiv 2312.11514); single `read`s
//! keep paying the full fixed latency.
//!
//! **ReadQueue.** The async queue mirrors the paper's io_uring loader (§6):
//! `submit` is cheap and non-blocking, a small worker pool drains pending
//! requests in queue-depth-bounded waves through `read_batch`, and
//! completions are reaped by tag in any order with `wait`. Reads submitted
//! together — chunk runs of one preload part, runs across sibling parts,
//! an on-demand fetch's coalesced misses — genuinely overlap.
//!
//! **Fault injection.** On a phone, flash stalls, transient EIOs and
//! thermal latency spikes are the normal case. A seeded [`FaultPlan`]
//! (injected via [`FlashDevice::inject_faults`], reachable from the CLI's
//! `--faults` spec) deterministically degrades reads: transient errors
//! that clear after a bounded number of attempts, permanent bad ranges
//! (preload reads only — urgent reads model controller ECC recovery at a
//! latency cost, so the on-demand fallback always lands), latency spikes,
//! and a one-shot stall for wedging a worker on purpose. All injected
//! latency is charged through the timing model (`busy_ns`, slept out in
//! Timed mode) so benches under faults stay honest. The queue answers
//! with a recovery ladder: typed [`IoError`] classification, bounded
//! exponential-backoff retries of transients, and a watchdog that fails a
//! wedged worker's wave over to its reapers and spawns a replacement
//! instead of letting every reaper time out.

use std::collections::{HashMap, HashSet, VecDeque};
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::device::DeviceProfile;
use crate::trace::{
    Histo, SpanCtx, SpanEvent, SpanKind, TraceHandle, TID_IO_BASE,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    Timed,
    Modeled,
}

/// Typed I/O failure classification, carried through the queue's `done`
/// map and the loader/engine reap paths (it used to be a stringly error,
/// so "wedged" and "bad media" were indistinguishable to recovery code).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum IoError {
    /// Recoverable device hiccup (injected transient, momentary EIO):
    /// worth a bounded retry with backoff.
    Transient(String),
    /// The read can never succeed (bad media range, pread failure):
    /// retries are wasted device time — fail over immediately.
    Permanent(String),
    /// The worker servicing the read wedged and its wave was failed over
    /// by the watchdog (or the reaper's own backstop timeout fired).
    Wedged(String),
}

impl IoError {
    pub fn is_transient(&self) -> bool {
        matches!(self, IoError::Transient(_))
    }

    /// Stable lowercase tag for logs / health summaries.
    pub fn kind(&self) -> &'static str {
        match self {
            IoError::Transient(_) => "transient",
            IoError::Permanent(_) => "permanent",
            IoError::Wedged(_) => "wedged",
        }
    }
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Transient(m) => write!(f, "transient io error: {m}"),
            IoError::Permanent(m) => write!(f, "permanent io error: {m}"),
            IoError::Wedged(m) => write!(f, "wedged io worker: {m}"),
        }
    }
}

impl std::error::Error for IoError {}

/// Deterministic, seeded fault schedule for a [`FlashDevice`]. Every
/// verdict is a pure function of `(seed, offset)` plus a per-offset
/// attempt count, so a chaos run is exactly reproducible — and a retried
/// transient read returns the same bytes the fault-free run saw, which is
/// what makes the chaos suite's bit-identity check possible.
///
/// Spec-string form (CLI `--faults`, config `fault_spec`), comma-joined:
///
/// ```text
/// seed=N                 RNG seed (default 1)
/// transient=R[:D]        rate R in [0,1); affected reads fail their
///                        first D attempts (default 1, must stay below
///                        the queue's attempt bound to be recoverable)
/// bad=OFF+LEN[/OFF+LEN]  permanent bad byte ranges (preload reads only)
/// spike=R:NS             rate R latency spikes of NS nanoseconds
/// stall=NTH:NS           one-shot: the NTH fault check stalls NS
///                        nanoseconds (wedges that worker; watchdog bait)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Fraction of reads (by offset hash) that fail transiently.
    pub transient_rate: f64,
    /// Consecutive failures an affected offset serves before recovering.
    pub transient_depth: u32,
    /// Byte ranges `(offset, len)` that permanently fail non-urgent
    /// (preload) reads. Urgent reads crossing them still succeed — the
    /// model is controller-side ECC/retry recovery, paid in latency —
    /// so the engine's on-demand fallback can always land.
    pub bad_ranges: Vec<(u64, u64)>,
    /// Fraction of reads (by offset hash) hit by a latency spike.
    pub spike_rate: f64,
    /// Added nanoseconds per spike.
    pub spike_ns: u64,
    /// One-shot stall: the nth fault consultation sleeps `stall_ns`.
    pub stall_after: Option<u64>,
    pub stall_ns: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 1,
            transient_rate: 0.0,
            transient_depth: 1,
            bad_ranges: Vec::new(),
            spike_rate: 0.0,
            spike_ns: 0,
            stall_after: None,
            stall_ns: 0,
        }
    }
}

impl FaultPlan {
    /// Parse the comma-joined `key=value` spec (see the type docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for kv in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                anyhow!("fault spec entry `{kv}` is not key=value")
            })?;
            let v = v.trim();
            match k.trim() {
                "seed" => plan.seed = v.parse()?,
                "transient" => match v.split_once(':') {
                    Some((r, d)) => {
                        plan.transient_rate = r.parse()?;
                        plan.transient_depth = d.parse()?;
                    }
                    None => plan.transient_rate = v.parse()?,
                },
                "bad" => {
                    for range in v.split('/') {
                        let (o, l) =
                            range.split_once('+').ok_or_else(|| {
                                anyhow!("bad range `{range}` must be OFF+LEN")
                            })?;
                        plan.bad_ranges.push((o.parse()?, l.parse()?));
                    }
                }
                "spike" => {
                    let (r, ns) = v.split_once(':').ok_or_else(|| {
                        anyhow!("spike `{v}` must be RATE:NS")
                    })?;
                    plan.spike_rate = r.parse()?;
                    plan.spike_ns = ns.parse()?;
                }
                "stall" => {
                    let (n, ns) = v.split_once(':').ok_or_else(|| {
                        anyhow!("stall `{v}` must be NTH:NS")
                    })?;
                    plan.stall_after = Some(n.parse()?);
                    plan.stall_ns = ns.parse()?;
                }
                other => {
                    return Err(anyhow!("unknown fault knob `{other}`"))
                }
            }
        }
        Ok(plan)
    }
}

/// Live fault bookkeeping behind the plan: per-offset attempt counts (so
/// transients deterministically clear) and the consultation counter that
/// drives the one-shot stall.
struct FaultState {
    plan: FaultPlan,
    attempts: HashMap<u64, u32>,
    checks: u64,
}

/// splitmix64 — cheap, well-mixed, and stable across runs.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Uniform [0,1) roll keyed by (seed, offset, salt) — same read, same
/// verdict, every run.
fn fault_roll(seed: u64, offset: u64, salt: u64) -> f64 {
    (mix64(seed ^ mix64(offset ^ salt)) >> 11) as f64 / (1u64 << 53) as f64
}

const SALT_TRANSIENT: u64 = 0x7261_6e73;
const SALT_SPIKE: u64 = 0x7370_696b;

/// Read statistics (drives the Fig 7 bench and the energy model).
#[derive(Debug, Default)]
pub struct FlashStats {
    pub reads: AtomicU64,
    pub bytes: AtomicU64,
    /// Modeled busy nanoseconds of the flash device.
    pub busy_ns: AtomicU64,
    /// Faults the device's [`FaultPlan`] actually injected (transient
    /// verdicts, bad-range hits, latency spikes — not clean reads).
    pub faults_injected: AtomicU64,
    /// Histogram of chunk sizes: [<16K, <64K, <256K, <1M, >=1M].
    pub size_hist: [AtomicU64; 5],
}

impl FlashStats {
    fn bucket(len: u64) -> usize {
        match len {
            l if l < 16 << 10 => 0,
            l if l < 64 << 10 => 1,
            l if l < 256 << 10 => 2,
            l if l < 1 << 20 => 3,
            _ => 4,
        }
    }

    fn record(&self, len: u64, ns: u64) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len, Ordering::Relaxed);
        self.busy_ns.fetch_add(ns, Ordering::Relaxed);
        self.size_hist[Self::bucket(len)].fetch_add(1, Ordering::Relaxed);
    }

    /// One submission batch: n reads, their total modeled duration charged
    /// once (the per-read charge would double-count the amortized latency).
    fn record_batch(&self, lens: &[usize], batch_ns: u64) {
        self.reads.fetch_add(lens.len() as u64, Ordering::Relaxed);
        self.busy_ns.fetch_add(batch_ns, Ordering::Relaxed);
        for &len in lens {
            self.bytes.fetch_add(len as u64, Ordering::Relaxed);
            self.size_hist[Self::bucket(len as u64)]
                .fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.reads.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed),
        )
    }
}

/// The simulated flash device, shareable across threads.
pub struct FlashDevice {
    file: File,
    pub profile: &'static DeviceProfile,
    pub mode: ClockMode,
    /// Bandwidth scale (<1 emulates proportionally larger models).
    pub bw_scale: f64,
    pub stats: FlashStats,
    /// Serializes the (single) flash channel in Timed mode — concurrent
    /// submitters queue behind each other like a real UFS device.
    channel: Mutex<()>,
    /// Active fault schedule (None = healthy device). Interior-mutable so
    /// faults can be armed on an already-shared device (the engine owns
    /// it behind an `Arc` by the time a CLI spec arrives).
    faults: Mutex<Option<FaultState>>,
    /// Fast-path flag mirroring `faults.is_some()` — the hot read paths
    /// skip the mutex entirely on a healthy device.
    has_faults: std::sync::atomic::AtomicBool,
}

impl FlashDevice {
    pub fn open(
        path: &Path,
        profile: &'static DeviceProfile,
        mode: ClockMode,
        bw_scale: f64,
    ) -> Result<Arc<FlashDevice>> {
        let file = File::open(path)
            .with_context(|| format!("opening flash file {}", path.display()))?;
        Ok(Arc::new(FlashDevice {
            file,
            profile,
            mode,
            bw_scale,
            stats: FlashStats::default(),
            channel: Mutex::new(()),
            faults: Mutex::new(None),
            has_faults: std::sync::atomic::AtomicBool::new(false),
        }))
    }

    /// Arm (or replace) the device's fault schedule. Safe on a shared,
    /// live device; takes effect for the next read.
    pub fn inject_faults(&self, plan: FaultPlan) {
        *self.faults.lock().unwrap() = Some(FaultState {
            plan,
            attempts: HashMap::new(),
            checks: 0,
        });
        self.has_faults
            .store(true, std::sync::atomic::Ordering::Release);
    }

    pub fn faults_active(&self) -> bool {
        self.has_faults.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Consult the fault plan for one read. Returns the injected extra
    /// latency (charge via [`FlashDevice::charge_fault_ns`]) and the
    /// verdict. `urgent` reads never hit permanent bad ranges — the model
    /// is controller-side ECC/retry recovery at a latency cost — so the
    /// decode-critical fallback path can always land.
    fn fault_check(
        &self,
        offset: u64,
        len: usize,
        urgent: bool,
    ) -> (u64, Option<IoError>) {
        let mut guard = self.faults.lock().unwrap();
        let Some(st) = guard.as_mut() else {
            return (0, None);
        };
        st.checks += 1;
        let mut extra = 0u64;
        if st.plan.stall_after == Some(st.checks) {
            extra += st.plan.stall_ns;
            self.stats.faults_injected.fetch_add(1, Ordering::Relaxed);
        }
        if st.plan.spike_rate > 0.0
            && fault_roll(st.plan.seed, offset, SALT_SPIKE)
                < st.plan.spike_rate
        {
            extra += st.plan.spike_ns;
            self.stats.faults_injected.fetch_add(1, Ordering::Relaxed);
        }
        let end = offset + len as u64;
        if !urgent
            && st
                .plan
                .bad_ranges
                .iter()
                .any(|&(o, l)| offset < o + l && o < end)
        {
            self.stats.faults_injected.fetch_add(1, Ordering::Relaxed);
            return (
                extra,
                Some(IoError::Permanent(format!(
                    "flash bad range under read at offset {offset}"
                ))),
            );
        }
        if st.plan.transient_rate > 0.0
            && fault_roll(st.plan.seed, offset, SALT_TRANSIENT)
                < st.plan.transient_rate
        {
            let seen = st.attempts.entry(offset).or_insert(0);
            if *seen < st.plan.transient_depth {
                *seen += 1;
                self.stats.faults_injected.fetch_add(1, Ordering::Relaxed);
                return (
                    extra,
                    Some(IoError::Transient(format!(
                        "injected transient read error at offset {offset}"
                    ))),
                );
            }
        }
        (extra, None)
    }

    /// Charge injected fault latency through the timing model: always
    /// accounted as device busy time; in Timed mode genuinely slept out —
    /// **outside** the channel mutex, so a stall wedges only the worker
    /// it hit, never the whole device.
    fn charge_fault_ns(&self, ns: u64) {
        if ns == 0 {
            return;
        }
        self.stats.busy_ns.fetch_add(ns, Ordering::Relaxed);
        if self.mode == ClockMode::Timed {
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }

    /// Modeled duration of one read of `len` bytes.
    pub fn model_read_ns(&self, len: u64) -> u64 {
        let s = self.profile.flash_latency
            + len as f64 / (self.profile.flash_max_bw * self.bw_scale);
        (s * 1e9) as u64
    }

    /// Modeled duration of one submission batch: the fixed latency is paid
    /// once per wave of `queue_depth` in-flight reads, the payload streams
    /// at scaled max bandwidth. Delegates to
    /// [`DeviceProfile::flash_batch_seconds_at`] so the wave formula has
    /// one home (`flash_batch_seconds` is the unscaled form).
    pub fn model_batch_ns(&self, reqs: &[(u64, usize)]) -> u64 {
        let total: u64 = reqs.iter().map(|&(_, len)| len as u64).sum();
        self.model_batch_ns_n(reqs.len(), total)
    }

    /// Batch model for `n` reads totalling `total` bytes (cost comparisons
    /// that don't want to materialize a request list).
    pub fn model_batch_ns_n(&self, n: usize, total: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        let s = self.profile.flash_batch_seconds_at(
            n,
            total,
            self.profile.flash_max_bw * self.bw_scale,
        );
        (s * 1e9) as u64
    }

    /// Synchronous read with timing applied. Returns the bytes.
    pub fn read(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; len];
        self.read_into(offset, &mut buf)?;
        Ok(buf)
    }

    /// Read into a caller-provided buffer (hot path: no allocation).
    /// Synchronous reads are decode-critical (urgent class): under an
    /// armed fault plan they absorb transient verdicts with inline
    /// retries and recover bad ranges — callers see added latency, never
    /// an injected failure.
    pub fn read_into(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        if self.faults_active() {
            let mut fault_ns = 0u64;
            for attempt in 0..MAX_IO_ATTEMPTS {
                let (extra, err) = self.fault_check(offset, buf.len(), true);
                fault_ns += extra;
                match err {
                    None => break,
                    Some(_) if attempt + 1 < MAX_IO_ATTEMPTS => {
                        fault_ns += RETRY_BACKOFF_NS << attempt;
                    }
                    Some(_) => {} // urgent reads must land: proceed anyway
                }
            }
            self.charge_fault_ns(fault_ns);
        }
        let model_ns = self.model_read_ns(buf.len() as u64);
        match self.mode {
            ClockMode::Timed => {
                let _chan = self.channel.lock().unwrap();
                let t0 = Instant::now();
                self.file
                    .read_exact_at(buf, offset)
                    .context("flash pread")?;
                let real = t0.elapsed().as_nanos() as u64;
                if model_ns > real {
                    std::thread::sleep(Duration::from_nanos(model_ns - real));
                }
            }
            ClockMode::Modeled => {
                self.file
                    .read_exact_at(buf, offset)
                    .context("flash pread")?;
            }
        }
        self.stats.record(buf.len() as u64, model_ns);
        Ok(())
    }

    /// Batched read (io_uring-like): submit all, the device streams them in
    /// queue-depth-bounded waves paying one fixed latency per *wave* — not
    /// one per chunk, which is what a `read` loop would charge. Returns
    /// buffers in submission order.
    pub fn read_batch(&self, reqs: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let mut bufs: Vec<Vec<u8>> = reqs.iter().map(|_| Vec::new()).collect();
        self.read_batch_into(reqs, &mut bufs)?;
        Ok(bufs)
    }

    /// [`FlashDevice::read_batch`] into caller-provided buffers (the
    /// [`ReadQueue`] recycle pool): each buffer is resized to its request's
    /// length — reusing its capacity when it has any — and filled in
    /// submission order. Timing is identical to `read_batch`.
    pub fn read_batch_into(
        &self,
        reqs: &[(u64, usize)],
        bufs: &mut [Vec<u8>],
    ) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        debug_assert_eq!(reqs.len(), bufs.len());
        let batch_ns = self.model_batch_ns(reqs);
        let lens: Vec<usize> = reqs.iter().map(|&(_, len)| len).collect();
        let fill = |bufs: &mut [Vec<u8>]| -> Result<()> {
            for (&(off, len), buf) in reqs.iter().zip(bufs.iter_mut()) {
                buf.resize(len, 0);
                self.file
                    .read_exact_at(buf, off)
                    .context("flash pread")?;
            }
            Ok(())
        };
        match self.mode {
            ClockMode::Timed => {
                // hold the channel for the whole batch — it occupies the
                // device exactly like one long transfer — and sleep out the
                // modeled remainder ONCE, not per chunk
                let _chan = self.channel.lock().unwrap();
                let t0 = Instant::now();
                fill(bufs)?;
                let real = t0.elapsed().as_nanos() as u64;
                if batch_ns > real {
                    std::thread::sleep(Duration::from_nanos(batch_ns - real));
                }
            }
            ClockMode::Modeled => fill(bufs)?,
        }
        self.stats.record_batch(&lens, batch_ns);
        Ok(())
    }

    /// Effective throughput at a chunk size, measured through the simulator
    /// (validates against `DeviceProfile::flash_throughput`). Chunks larger
    /// than the backing file wrap: the pread covers what exists, the timing
    /// models the full chunk.
    pub fn measure_throughput(&self, chunk: usize, total: usize) -> Result<f64> {
        let file_len = self.file.metadata()?.len() as usize;
        let n = (total / chunk).max(1);
        let t0 = Instant::now();
        let mut modeled_ns = 0u64;
        let read_len = chunk.min(file_len);
        let mut buf = vec![0u8; read_len];
        for i in 0..n {
            let off = ((i * read_len) % (file_len - read_len + 1)) as u64;
            self.read_into(off, &mut buf)?;
            modeled_ns += self.model_read_ns(chunk as u64);
        }
        let secs = match self.mode {
            ClockMode::Timed => t0.elapsed().as_secs_f64(),
            ClockMode::Modeled => modeled_ns as f64 / 1e9,
        };
        Ok((n * chunk) as f64 / secs)
    }
}

/// One reaped read: the bytes plus this read's apportioned share of its
/// wave's modeled duration (the wave time split evenly across its reads —
/// summing shares over a wave reproduces the wave's total).
pub struct Completion {
    pub data: Vec<u8>,
    pub modeled_ns: u64,
}

/// Who is blocked reaping a completion — the preload loader or the
/// engine's decode-critical on-demand fetch. Wait time is attributed per
/// class so overlap diagnosis can tell preload reaping (background, often
/// free) from on-demand miss stalls (always on the token's critical path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoClass {
    Loader,
    Engine,
}

/// Cumulative queue counters (surfaced as `io_*` in stats/benches).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoSnapshot {
    /// Reads submitted.
    pub submitted: u64,
    /// `read_batch` waves issued (each charged one fixed latency per
    /// queue-depth's worth of reads).
    pub batches: u64,
    /// Peak number of reads in flight at once (≤ queue depth).
    pub inflight_peak: u64,
    /// Total time reapers spent blocked in [`ReadQueue::wait`]
    /// (both classes; `wait_loader_ns + wait_engine_ns`).
    pub wait_ns: u64,
    /// Wait time attributed to the preload loader's reaps.
    pub wait_loader_ns: u64,
    /// Wait time attributed to the engine's on-demand reaps.
    pub wait_engine_ns: u64,
    /// Read buffers served from the recycle pool instead of a fresh
    /// allocation (ROADMAP: the queue used to allocate one `Vec<u8>` per
    /// read).
    pub buffers_recycled: u64,
    /// Transient-faulted reads re-enqueued for another attempt (bounded
    /// exponential-backoff retry ladder).
    pub retries: u64,
    /// Faults the device's plan injected (device-level counter, mirrored
    /// here so one snapshot covers the whole I/O failure picture).
    pub faults_injected: u64,
    /// Wedged workers the watchdog detected and replaced.
    pub wedged_recoveries: u64,
}

/// One worker's watchdog-visible state, living INSIDE `QueueInner` so
/// watchdog scans and worker updates share the queue's single lock (no
/// second lock order to get wrong). `generation` is the recovery token: a
/// worker whose slot generation moved on while it was out executing a
/// wave has been replaced — it must drop its results and exit instead of
/// double-completing tags the watchdog already failed over.
struct WorkerSlot {
    generation: u64,
    /// Set while the worker is out of the lock executing a wave.
    busy_since: Option<Instant>,
    /// The wave's tags (what the watchdog fails over on a wedge).
    tags: Vec<u64>,
    /// Whether the wave was urgent-class (for in-flight accounting).
    urgent: bool,
}

struct QueueInner {
    /// Submitted, not yet picked up by a worker:
    /// (tag, offset, len, urgent, attempt, causal ctx).
    pending: VecDeque<(u64, u64, usize, bool, u32, SpanCtx)>,
    /// Completed, not yet reaped. Errors are typed [`IoError`]s (Clone,
    /// so one failure fans out across its wave's reads).
    done: HashMap<u64, Result<Completion, IoError>>,
    /// Tags abandoned while in flight (reaper gave up / caller no longer
    /// wants them): workers drop their completions instead of parking
    /// them in `done` forever.
    abandoned: HashSet<u64>,
    /// Reads currently inside a worker's wave.
    inflight: usize,
    /// The non-urgent (preload) share of `inflight`: capped below the
    /// full depth so an urgent arrival always finds device budget within
    /// at most one *partial* wave (see `worker_loop`).
    inflight_nonurgent: usize,
    /// Per-worker watchdog slots, indexed by worker id.
    slots: Vec<WorkerSlot>,
    next_tag: u64,
    stop: bool,
}

struct QueueShared {
    dev: Arc<FlashDevice>,
    depth: usize,
    inner: Mutex<QueueInner>,
    /// Workers wait here for pending work / freed in-flight budget.
    work_cv: Condvar,
    /// Reapers wait here for completions.
    done_cv: Condvar,
    /// Retired read buffers awaiting reuse (never locked while `inner` is
    /// wanted by the same thread *after* it — lock order is inner → free).
    free: Mutex<Vec<Vec<u8>>>,
    /// Live worker join handles keyed by slot id (current generation
    /// only — a replaced worker's handle is dropped, detaching the zombie
    /// thread, which exits on its own once its stale generation is seen).
    /// Locked standalone, never while `inner` is held.
    handles: Mutex<HashMap<usize, JoinHandle<()>>>,
    /// Watchdog wedge threshold in nanoseconds (settable for tests; the
    /// 30s reaper timeout stays as the backstop behind it).
    wedge_timeout_ns: AtomicU64,
    submitted: AtomicU64,
    batches: AtomicU64,
    inflight_peak: AtomicU64,
    wait_loader_ns: AtomicU64,
    wait_engine_ns: AtomicU64,
    buffers_recycled: AtomicU64,
    /// Transient reads re-enqueued for another attempt.
    retries: AtomicU64,
    /// Wedged workers detected and replaced by the watchdog.
    wedged_recoveries: AtomicU64,
    /// Per-class reap-wait latency histograms (µs), recorded only when a
    /// reaper actually blocked — the zero-wait fast path takes no lock.
    wait_histo_loader: Mutex<Histo>,
    wait_histo_engine: Mutex<Histo>,
    /// Flight recorder (io-batch spans, one per device wave). Lives in
    /// the shared state so watchdog-spawned replacement workers inherit
    /// it. `None` when the queue's owner never attached one.
    trace: Option<TraceHandle>,
}

impl QueueShared {
    /// Return a retired buffer to the pool (bounded — excess is dropped).
    fn push_free(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        if free.len() < BUF_POOL_CAP {
            free.push(buf);
        }
    }
}

/// Recycle-pool bound: enough for a few full waves of every worker; past
/// it buffers are simply freed (the pool must not become a leak).
const BUF_POOL_CAP: usize = 64;

/// An async read queue over a FlashDevice — the io_uring submit/reap
/// structure of the paper's loader thread (§6 Flash loading), shared by
/// the preload loader and the engine's on-demand path.
///
/// `submit`/`submit_many` enqueue without blocking and return tags;
/// `wait(tag)` reaps one completion, in any order. A worker pool (sized by
/// the queue depth, capped — one worker already drains full-depth waves,
/// the extras only matter while a wave is sleeping out its modeled time)
/// drains pending reads in waves of at most `depth` in flight, each wave
/// issued as one [`FlashDevice::read_batch`] so its fixed latency is
/// amortized across the wave.
pub struct ReadQueue {
    shared: Arc<QueueShared>,
    watchdog: Option<JoinHandle<()>>,
}

/// Above this the extra threads only add context switches: a single worker
/// drains a full-depth wave per pass.
const MAX_QUEUE_WORKERS: usize = 4;

/// A reaper blocked longer than this has hit a wedged worker (device error
/// loop, dead thread) — bail out so the decode falls back instead of
/// hanging forever. Backstop only: the watchdog usually fails a wedged
/// wave over long before this.
const REAP_TIMEOUT: Duration = Duration::from_secs(30);

/// Watchdog default: a worker out on one wave this long is wedged. Well
/// above any legitimate Timed-mode wave (milliseconds), well below the
/// reaper backstop.
const DEFAULT_WEDGE_TIMEOUT: Duration = Duration::from_secs(10);

/// Bounded retry: total attempts per read (first try + retries) before a
/// transient fault is surfaced as an error.
const MAX_IO_ATTEMPTS: u32 = 3;

/// Exponential backoff charged per retry (doubled each attempt) — device
/// time in the model, a real sleep in Timed mode.
const RETRY_BACKOFF_NS: u64 = 200_000;

impl ReadQueue {
    /// `depth` bounds the reads in flight (0 → the device profile's
    /// modeled queue depth). Software depth and device depth compose: a
    /// software depth above the device's still submits bigger waves, but
    /// `read_batch` charges one latency per *device* wave inside them.
    pub fn new(dev: Arc<FlashDevice>, depth: usize) -> Arc<ReadQueue> {
        ReadQueue::new_traced(dev, depth, None)
    }

    /// [`ReadQueue::new`] with a flight recorder attached: workers record
    /// one [`SpanKind::IoBatch`] span per device wave (no-op while
    /// tracing is disabled).
    pub fn new_traced(
        dev: Arc<FlashDevice>,
        depth: usize,
        trace: Option<TraceHandle>,
    ) -> Arc<ReadQueue> {
        let depth = if depth == 0 {
            dev.profile.queue_depth.max(1)
        } else {
            depth
        };
        let n_workers = depth.min(MAX_QUEUE_WORKERS).max(1);
        let shared = Arc::new(QueueShared {
            dev,
            depth,
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                done: HashMap::new(),
                abandoned: HashSet::new(),
                inflight: 0,
                inflight_nonurgent: 0,
                slots: (0..n_workers)
                    .map(|_| WorkerSlot {
                        generation: 0,
                        busy_since: None,
                        tags: Vec::new(),
                        urgent: false,
                    })
                    .collect(),
                next_tag: 0,
                stop: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            free: Mutex::new(Vec::new()),
            handles: Mutex::new(HashMap::new()),
            wedge_timeout_ns: AtomicU64::new(
                DEFAULT_WEDGE_TIMEOUT.as_nanos() as u64
            ),
            submitted: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            inflight_peak: AtomicU64::new(0),
            wait_loader_ns: AtomicU64::new(0),
            wait_engine_ns: AtomicU64::new(0),
            buffers_recycled: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            wedged_recoveries: AtomicU64::new(0),
            wait_histo_loader: Mutex::new(Histo::new()),
            wait_histo_engine: Mutex::new(Histo::new()),
            trace,
        });
        {
            let mut handles = shared.handles.lock().unwrap();
            for i in 0..n_workers {
                handles.insert(i, spawn_worker(&shared, i, 0));
            }
        }
        let watchdog = {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name("awf-io-watchdog".into())
                .spawn(move || watchdog_loop(sh))
                .expect("spawn io watchdog")
        };
        Arc::new(ReadQueue {
            shared,
            watchdog: Some(watchdog),
        })
    }

    pub fn depth(&self) -> usize {
        self.shared.depth
    }

    /// Lower (or raise) the watchdog's wedge threshold — chaos tests use
    /// a short one so recovery is observable without waiting out the
    /// 10s default.
    pub fn set_wedge_timeout(&self, timeout: Duration) {
        self.shared
            .wedge_timeout_ns
            .store(timeout.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Enqueue one read; returns its reap tag. Never blocks on I/O.
    pub fn submit(&self, offset: u64, len: usize) -> u64 {
        self.submit_many(&[(offset, len)])[0]
    }

    /// Enqueue a group of reads under ONE queue lock, so no worker can
    /// start a wave between them: reads submitted together are guaranteed
    /// to share waves (up to the depth) and amortize their fixed latency.
    /// Returns tags in request order.
    pub fn submit_many(&self, reqs: &[(u64, usize)]) -> Vec<u64> {
        self.submit_group(reqs, false, SpanCtx::NONE)
    }

    /// [`ReadQueue::submit_many`] with a causal context: the group's
    /// `io_batch` spans record which request paid for the reads.
    pub fn submit_many_ctx(
        &self,
        reqs: &[(u64, usize)],
        ctx: SpanCtx,
    ) -> Vec<u64> {
        self.submit_group(reqs, false, ctx)
    }

    /// Like [`ReadQueue::submit_many`], but the group jumps the pending
    /// line (keeping its internal order): decode-critical on-demand
    /// fetches must not drain behind a whole preload wavefront. A wave
    /// already in flight is not preempted, but non-urgent waves are
    /// **split** (capped at half the depth per wave, with an in-flight
    /// reserve of `depth/4` slots only urgent reads may use), so the
    /// worst-case wait is one *partial* preload wave — not a full-depth
    /// one.
    pub fn submit_many_urgent(&self, reqs: &[(u64, usize)]) -> Vec<u64> {
        self.submit_group(reqs, true, SpanCtx::NONE)
    }

    /// [`ReadQueue::submit_many_urgent`] with a causal context.
    pub fn submit_many_urgent_ctx(
        &self,
        reqs: &[(u64, usize)],
        ctx: SpanCtx,
    ) -> Vec<u64> {
        self.submit_group(reqs, true, ctx)
    }

    fn submit_group(
        &self,
        reqs: &[(u64, usize)],
        urgent: bool,
        ctx: SpanCtx,
    ) -> Vec<u64> {
        let mut q = self.shared.inner.lock().unwrap();
        let tags: Vec<u64> = reqs
            .iter()
            .map(|&(off, len)| {
                let tag = q.next_tag;
                q.next_tag += 1;
                if !urgent {
                    q.pending.push_back((tag, off, len, false, 0, ctx));
                }
                tag
            })
            .collect();
        if urgent {
            // front-insert in reverse so the group's own order survives
            for (&tag, &(off, len)) in tags.iter().zip(reqs).rev() {
                q.pending.push_front((tag, off, len, true, 0, ctx));
            }
        }
        self.shared
            .submitted
            .fetch_add(reqs.len() as u64, Ordering::Relaxed);
        drop(q);
        self.shared.work_cv.notify_all();
        tags
    }

    /// Give up on a submitted read: still pending → cancelled outright;
    /// already completed → its buffer is recycled; in flight → the
    /// worker drops its completion when the wave lands. Never blocks.
    /// Every submitted tag must be either `wait`ed or `abandon`ed, or its
    /// completion parks in the queue until drop.
    pub fn abandon(&self, tag: u64) {
        let reclaimed = {
            let mut q = self.shared.inner.lock().unwrap();
            let before = q.pending.len();
            q.pending.retain(|&(t, _, _, _, _, _)| t != tag);
            if q.pending.len() != before {
                return; // never started; nothing will ever complete
            }
            match q.done.remove(&tag) {
                None => {
                    q.abandoned.insert(tag);
                    None
                }
                Some(Ok(c)) => Some(c.data),
                Some(Err(_)) => None,
            }
        };
        if let Some(buf) = reclaimed {
            self.shared.push_free(buf);
        }
    }

    /// Hand a consumed completion's buffer back for reuse by later reads
    /// (the queue used to allocate one `Vec<u8>` per read; the pool cuts
    /// steady-state allocation on the preload and on-demand paths to
    /// zero). Optional — dropping the buffer instead is always safe.
    pub fn recycle(&self, buf: Vec<u8>) {
        self.shared.push_free(buf);
    }

    /// Reap one completion by tag, blocking until its wave lands —
    /// engine-class attribution (see [`ReadQueue::wait_as`]).
    pub fn wait(&self, tag: u64) -> Result<Completion, IoError> {
        self.wait_as(tag, IoClass::Engine)
    }

    /// Reap one completion by tag, blocking until its wave lands, and
    /// attribute any blocked time to `class` (`io_wait_loader_ns` vs
    /// `io_wait_engine_ns`). Completions are reaped at most once; tags
    /// may be waited in any order (out-of-order reap). Failures are typed
    /// [`IoError`]s so callers can tell recoverable from hopeless.
    pub fn wait_as(
        &self,
        tag: u64,
        class: IoClass,
    ) -> Result<Completion, IoError> {
        let deadline = Instant::now() + REAP_TIMEOUT;
        let mut waited = Duration::ZERO;
        let mut q = self.shared.inner.lock().unwrap();
        let out = loop {
            if let Some(res) = q.done.remove(&tag) {
                break res;
            }
            let now = Instant::now();
            if now >= deadline {
                // orphan the tag wherever it is — a completion landing
                // after this must not park in the done map forever
                let before = q.pending.len();
                q.pending.retain(|&(t, _, _, _, _, _)| t != tag);
                if q.pending.len() == before {
                    q.abandoned.insert(tag);
                }
                break Err(IoError::Wedged(format!(
                    "read queue wedged: tag {tag} never completed"
                )));
            }
            let t0 = Instant::now();
            let (guard, _) = self
                .shared
                .done_cv
                .wait_timeout(q, deadline - now)
                .unwrap();
            waited += t0.elapsed();
            q = guard;
        };
        drop(q);
        if !waited.is_zero() {
            let (ctr, histo) = match class {
                IoClass::Loader => (
                    &self.shared.wait_loader_ns,
                    &self.shared.wait_histo_loader,
                ),
                IoClass::Engine => (
                    &self.shared.wait_engine_ns,
                    &self.shared.wait_histo_engine,
                ),
            };
            ctr.fetch_add(waited.as_nanos() as u64, Ordering::Relaxed);
            histo
                .lock()
                .unwrap()
                .record(waited.as_micros() as u64);
        }
        out
    }

    /// Per-class reap-wait latency histograms (µs): `(loader, engine)`.
    /// Only blocked reaps are recorded — a completion already landed
    /// costs no wait and no sample.
    pub fn wait_histos(&self) -> (Histo, Histo) {
        (
            *self.shared.wait_histo_loader.lock().unwrap(),
            *self.shared.wait_histo_engine.lock().unwrap(),
        )
    }

    /// Zero the wait histograms (`stats_reset`).
    pub fn reset_wait_histos(&self) {
        *self.shared.wait_histo_loader.lock().unwrap() = Histo::new();
        *self.shared.wait_histo_engine.lock().unwrap() = Histo::new();
    }

    /// Reads neither reaped nor yet picked up (tests/diagnostics).
    pub fn pending(&self) -> usize {
        let q = self.shared.inner.lock().unwrap();
        q.pending.len() + q.inflight
    }

    pub fn io_stats(&self) -> IoSnapshot {
        let wl = self.shared.wait_loader_ns.load(Ordering::Relaxed);
        let we = self.shared.wait_engine_ns.load(Ordering::Relaxed);
        IoSnapshot {
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            inflight_peak: self.shared.inflight_peak.load(Ordering::Relaxed),
            wait_ns: wl + we,
            wait_loader_ns: wl,
            wait_engine_ns: we,
            buffers_recycled: self
                .shared
                .buffers_recycled
                .load(Ordering::Relaxed),
            retries: self.shared.retries.load(Ordering::Relaxed),
            faults_injected: self
                .shared
                .dev
                .stats
                .faults_injected
                .load(Ordering::Relaxed),
            wedged_recoveries: self
                .shared
                .wedged_recoveries
                .load(Ordering::Relaxed),
        }
    }
}

impl Drop for ReadQueue {
    fn drop(&mut self) {
        self.shared.inner.lock().unwrap().stop = true;
        self.shared.work_cv.notify_all();
        // watchdog first, so no replacement spawns while we join workers
        if let Some(h) = self.watchdog.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut map = self.shared.handles.lock().unwrap();
            map.drain().map(|(_, h)| h).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Urgent device-budget reserve: non-urgent (preload) reads may never
/// occupy more than `depth - reserve` in-flight slots, so an urgent
/// arrival always finds budget without waiting out a full preload wave.
fn urgent_reserve(depth: usize) -> usize {
    if depth <= 1 {
        0
    } else {
        (depth / 4).max(1)
    }
}

fn spawn_worker(
    sh: &Arc<QueueShared>,
    slot: usize,
    generation: u64,
) -> JoinHandle<()> {
    let shared = sh.clone();
    std::thread::Builder::new()
        .name(format!("awf-io-{slot}"))
        .spawn(move || worker_loop(shared, slot, generation))
        .expect("spawn io worker")
}

// pallas-lint: hot-path
fn worker_loop(sh: Arc<QueueShared>, slot: usize, generation: u64) {
    loop {
        // Claim a wave: a contiguous same-class run from the front of
        // the pending queue, up to the remaining in-flight budget.
        // Urgent waves may use the whole budget; non-urgent (preload)
        // waves are SPLIT — capped at depth/2 per wave and at
        // depth - urgent_reserve in-flight overall — so an urgent
        // submission arriving mid-wavefront lands within at most one
        // *partial* wave instead of draining behind a full-depth preload
        // wave (ROADMAP "I/O wave preemption").
        #[allow(clippy::type_complexity)]
        let (wave, wave_urgent): (
            Vec<(u64, u64, usize, bool, u32, SpanCtx)>,
            bool,
        ) = {
            let mut q = sh.inner.lock().unwrap();
            loop {
                if q.slots[slot].generation != generation {
                    return; // replaced by the watchdog — stale worker
                }
                let budget = sh.depth.saturating_sub(q.inflight);
                let front_urgent =
                    q.pending.front().map(|&(_, _, _, u, _, _)| u);
                if let (Some(urgent), true) = (front_urgent, budget > 0) {
                    let cap = if urgent {
                        budget
                    } else {
                        let class_room = (sh.depth
                            - urgent_reserve(sh.depth))
                        .saturating_sub(q.inflight_nonurgent);
                        budget.min(class_room).min((sh.depth / 2).max(1))
                    };
                    if cap > 0 {
                        let mut take = 0usize;
                        while take < cap
                            && q.pending.get(take).is_some_and(
                                |&(_, _, _, u, _, _)| u == urgent,
                            )
                        {
                            take += 1;
                        }
                        let wave: Vec<_> =
                            q.pending.drain(..take).collect();
                        q.inflight += wave.len();
                        if !urgent {
                            q.inflight_nonurgent += wave.len();
                        }
                        sh.inflight_peak.fetch_max(
                            q.inflight as u64,
                            Ordering::Relaxed,
                        );
                        // watchdog-visible: this worker is now out
                        // executing these tags
                        let s = &mut q.slots[slot];
                        s.busy_since = Some(Instant::now());
                        s.tags = wave.iter().map(|&(t, ..)| t).collect();
                        s.urgent = urgent;
                        break (wave, urgent);
                    }
                }
                if q.stop && q.pending.is_empty() {
                    return;
                }
                q = sh.work_cv.wait(q).unwrap();
            }
        };
        // flight recorder: one io_batch span per device wave (enabled
        // check only — disabled tracing costs one relaxed load here)
        let t_io = sh
            .trace
            .as_ref()
            .filter(|t| t.enabled())
            .map(|t| t.now_us());
        // Fault consultation, one verdict per read. Injected latency
        // (spikes, stalls) is charged and slept OUTSIDE the device
        // channel mutex, so a stall wedges this worker only — exactly
        // what the watchdog is built to recover.
        let mut verdicts: Vec<Option<IoError>> = Vec::new();
        if sh.dev.faults_active() {
            let mut extra_ns = 0u64;
            for &(_, off, len, urgent, _, _) in &wave {
                let (ns, err) = sh.dev.fault_check(off, len, urgent);
                extra_ns += ns;
                verdicts.push(err);
            }
            sh.dev.charge_fault_ns(extra_ns);
            if extra_ns > 0 {
                // a stall long enough for the watchdog to replace us
                // means our tags are already answered — bail before
                // touching the device channel
                let q = sh.inner.lock().unwrap();
                if q.slots[slot].generation != generation {
                    return;
                }
            }
        } else {
            verdicts.resize_with(wave.len(), || None);
        }
        let healthy: Vec<usize> = (0..wave.len())
            .filter(|&i| verdicts[i].is_none())
            .collect();
        let reqs: Vec<(u64, usize)> = healthy
            .iter()
            .map(|&i| (wave[i].1, wave[i].2))
            .collect();
        // buffers come from the recycle pool when it has any — the queue
        // used to allocate one fresh Vec per read
        let mut bufs: Vec<Vec<u8>> = {
            let mut free = sh.free.lock().unwrap();
            reqs.iter()
                .map(|_| match free.pop() {
                    Some(b) => {
                        sh.buffers_recycled.fetch_add(1, Ordering::Relaxed);
                        b
                    }
                    None => Vec::new(),
                })
                .collect()
        };
        let batch_ns = sh.dev.model_batch_ns(&reqs);
        let share = if healthy.is_empty() {
            0
        } else {
            batch_ns / healthy.len() as u64
        };
        let result = if reqs.is_empty() {
            Ok(())
        } else {
            sh.batches.fetch_add(1, Ordering::Relaxed);
            sh.dev.read_batch_into(&reqs, &mut bufs)
        };
        if let (Some(t0), Some(trace)) = (t_io, sh.trace.as_ref()) {
            // attribute the wave to the first context-carrying read in
            // it — reads submitted together share a requester, and a
            // mixed wave is still better pinned to one request than none
            let ctx = wave
                .iter()
                .map(|w| w.5)
                .find(|c| !c.is_none())
                .unwrap_or(SpanCtx::NONE);
            trace.push_one(SpanEvent {
                kind: SpanKind::IoBatch,
                t0_us: t0,
                dur_us: trace.now_us().saturating_sub(t0),
                tid: TID_IO_BASE + slot as u32,
                ctx,
                a: wave.len() as u64,
                b: wave_urgent as u64,
            });
        }
        let mut reclaimed: Vec<Vec<u8>> = Vec::new();
        let mut backoff_ns = 0u64;
        {
            let mut q = sh.inner.lock().unwrap();
            if q.slots[slot].generation != generation {
                // the watchdog failed this wave over while we were out:
                // every tag is already answered — drop the results and
                // retire quietly
                drop(q);
                for buf in bufs {
                    sh.push_free(buf);
                }
                return;
            }
            {
                let s = &mut q.slots[slot];
                s.busy_since = None;
                s.tags.clear();
            }
            q.inflight -= wave.len();
            if !wave_urgent {
                q.inflight_nonurgent -= wave.len();
            }
            let mut bufs_it = bufs.into_iter();
            match result {
                Ok(()) => {
                    for &i in &healthy {
                        let tag = wave[i].0;
                        let data = bufs_it.next().expect("buf per read");
                        if q.abandoned.remove(&tag) {
                            reclaimed.push(data); // reaper gave up
                            continue;
                        }
                        q.done.insert(
                            tag,
                            Ok(Completion {
                                data,
                                modeled_ns: share,
                            }),
                        );
                    }
                }
                Err(e) => {
                    // a real pread failure can never succeed on retry
                    let err = IoError::Permanent(format!("{e:#}"));
                    reclaimed.extend(bufs_it);
                    for &i in &healthy {
                        let tag = wave[i].0;
                        if q.abandoned.remove(&tag) {
                            continue;
                        }
                        q.done.insert(tag, Err(err.clone()));
                    }
                }
            }
            // Faulted reads: transients get a bounded retry ladder —
            // re-enqueued (keeping their urgency class) with exponential
            // backoff charged to the device; exhausted transients and
            // permanent faults surface their typed error to the reaper.
            for (i, verdict) in verdicts.into_iter().enumerate() {
                let Some(err) = verdict else { continue };
                let (tag, off, len, urgent, attempt, ctx) = wave[i];
                if q.abandoned.remove(&tag) {
                    continue;
                }
                if err.is_transient() && attempt + 1 < MAX_IO_ATTEMPTS {
                    backoff_ns += RETRY_BACKOFF_NS << attempt;
                    sh.retries.fetch_add(1, Ordering::Relaxed);
                    if urgent {
                        q.pending.push_front((
                            tag,
                            off,
                            len,
                            true,
                            attempt + 1,
                            ctx,
                        ));
                    } else {
                        q.pending.push_back((
                            tag,
                            off,
                            len,
                            false,
                            attempt + 1,
                            ctx,
                        ));
                    }
                } else {
                    q.done.insert(tag, Err(err));
                }
            }
        }
        for buf in reclaimed {
            sh.push_free(buf);
        }
        sh.dev.charge_fault_ns(backoff_ns);
        sh.done_cv.notify_all();
        sh.work_cv.notify_all(); // in-flight budget freed / retries queued
    }
}

/// Watchdog: scans worker slots for one stuck out on a single wave past
/// the wedge threshold. Recovery replaces the worker instead of letting
/// every reaper time out: the wave's tags are failed over as
/// [`IoError::Wedged`] (reapers unblock immediately and fall back), the
/// slot's generation is bumped (turning the stuck thread into a zombie
/// that exits on its own without touching shared state), and a fresh
/// worker takes the slot.
fn watchdog_loop(sh: Arc<QueueShared>) {
    loop {
        let timeout = Duration::from_nanos(
            sh.wedge_timeout_ns.load(Ordering::Relaxed),
        );
        let poll = (timeout / 4)
            .clamp(Duration::from_millis(10), Duration::from_secs(1));
        let mut replace: Vec<(usize, u64)> = Vec::new();
        {
            let mut q = sh.inner.lock().unwrap();
            if q.stop {
                return;
            }
            let (guard, _) = sh.work_cv.wait_timeout(q, poll).unwrap();
            q = guard;
            if q.stop {
                return;
            }
            let now = Instant::now();
            let wedged: Vec<usize> = q
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    s.busy_since
                        .is_some_and(|t0| now.duration_since(t0) >= timeout)
                })
                .map(|(i, _)| i)
                .collect();
            for slot in wedged {
                let (tags, urgent, new_gen) = {
                    let s = &mut q.slots[slot];
                    s.generation += 1;
                    s.busy_since = None;
                    (std::mem::take(&mut s.tags), s.urgent, s.generation)
                };
                q.inflight -= tags.len();
                if !urgent {
                    q.inflight_nonurgent -= tags.len();
                }
                for tag in tags {
                    if q.abandoned.remove(&tag) {
                        continue;
                    }
                    q.done.insert(
                        tag,
                        Err(IoError::Wedged(format!(
                            "io worker {slot} wedged; wave failed over"
                        ))),
                    );
                }
                sh.wedged_recoveries.fetch_add(1, Ordering::Relaxed);
                replace.push((slot, new_gen));
            }
        }
        if replace.is_empty() {
            continue;
        }
        for (slot, gen) in replace {
            let fresh = spawn_worker(&sh, slot, gen);
            // dropping the old handle detaches the zombie; it exits once
            // it observes its stale generation
            let _ = sh.handles.lock().unwrap().insert(slot, fresh);
        }
        sh.done_cv.notify_all();
        sh.work_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PIXEL6;
    use std::io::Write;

    fn temp_flash(len: usize, mode: ClockMode) -> (Arc<FlashDevice>, std::path::PathBuf) {
        let path = std::env::temp_dir().join(format!(
            "awf_flash_test_{}_{}",
            std::process::id(),
            len
        ));
        let mut f = File::create(&path).unwrap();
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        f.write_all(&data).unwrap();
        (
            FlashDevice::open(&path, &PIXEL6, mode, 1.0).unwrap(),
            path,
        )
    }

    #[test]
    fn read_returns_file_bytes() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let got = dev.read(100, 32).unwrap();
        let want: Vec<u8> = (100..132).map(|i| (i % 251) as u8).collect();
        assert_eq!(got, want);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn modeled_time_matches_profile() {
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let ns = dev.model_read_ns(1 << 20);
        let want = PIXEL6.flash_read_seconds(1 << 20) * 1e9;
        assert!((ns as f64 - want).abs() / want < 1e-5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn timed_read_sleeps_at_least_model_time() {
        let (dev, path) = temp_flash(256 << 10, ClockMode::Timed);
        let t0 = Instant::now();
        dev.read(0, 256 << 10).unwrap();
        let elapsed = t0.elapsed().as_nanos() as u64;
        let model = dev.model_read_ns(256 << 10);
        assert!(
            elapsed >= model,
            "elapsed {elapsed} < modeled {model}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bw_scale_slows_reads() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let base = dev.model_read_ns(1 << 20);
        let slow = FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 0.1)
            .unwrap();
        assert!(slow.model_read_ns(1 << 20) > 5 * base);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stats_accumulate() {
        let (dev, path) = temp_flash(64 << 10, ClockMode::Modeled);
        dev.read(0, 4 << 10).unwrap();
        dev.read(0, 32 << 10).unwrap();
        let (reads, bytes, busy) = dev.stats.snapshot();
        assert_eq!(reads, 2);
        assert_eq!(bytes, (4 << 10) + (32 << 10));
        assert!(busy > 0);
        assert_eq!(dev.stats.size_hist[0].load(Ordering::Relaxed), 1);
        assert_eq!(dev.stats.size_hist[1].load(Ordering::Relaxed), 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_charges_one_latency_per_wave_not_per_chunk() {
        // The doc-contract bug this fixes: read_batch used to loop over
        // read(), paying the full fixed latency per chunk. The batch model
        // must charge strictly less than N serial reads.
        let (dev, path) = temp_flash(64 << 10, ClockMode::Modeled);
        let n = 8usize;
        let reqs: Vec<(u64, usize)> =
            (0..n).map(|i| (i as u64 * 4096, 4096)).collect();
        let batch = dev.model_batch_ns(&reqs);
        let serial = n as u64 * dev.model_read_ns(4096);
        assert!(
            batch < serial,
            "batch {batch} !< {n} x single = {serial}"
        );
        // n ≤ queue depth → exactly one fixed latency + streamed bytes
        let lat = (PIXEL6.flash_latency * 1e9) as u64;
        assert!(batch < serial - (n as u64 - 1) * lat + lat / 10);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn batch_stats_accumulate_batch_time_once() {
        let (dev, path) = temp_flash(64 << 10, ClockMode::Modeled);
        let reqs: Vec<(u64, usize)> =
            (0..4).map(|i| (i as u64 * 1024, 1024)).collect();
        let bufs = dev.read_batch(&reqs).unwrap();
        assert_eq!(bufs.len(), 4);
        assert_eq!(bufs[1][0], (1024 % 251) as u8, "submission order kept");
        let (reads, bytes, busy) = dev.stats.snapshot();
        assert_eq!(reads, 4);
        assert_eq!(bytes, 4 * 1024);
        assert_eq!(busy, dev.model_batch_ns(&reqs),
                   "busy must be the amortized batch time, not 4 singles");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn timed_batch_sleeps_batch_model_not_per_chunk() {
        let (dev, path) = temp_flash(256 << 10, ClockMode::Timed);
        let reqs: Vec<(u64, usize)> =
            (0..4).map(|i| (i as u64 * (32 << 10), 32 << 10)).collect();
        let t0 = Instant::now();
        dev.read_batch(&reqs).unwrap();
        let elapsed = t0.elapsed().as_nanos() as u64;
        let batch = dev.model_batch_ns(&reqs);
        assert!(elapsed >= batch, "elapsed {elapsed} < batch {batch}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_out_of_order_reap() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 0); // device-default depth
        let tags = q.submit_many(&[(0, 8), (1000, 8)]);
        // reap in reverse submission order
        let b1 = q.wait(tags[1]).unwrap();
        let b0 = q.wait(tags[0]).unwrap();
        assert_eq!(
            b0.data,
            (0..8).map(|i| (i % 251) as u8).collect::<Vec<_>>()
        );
        assert_eq!(
            b1.data,
            (1000..1008).map(|i| (i % 251) as u8).collect::<Vec<_>>()
        );
        assert_eq!(q.pending(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_amortizes_submitted_group() {
        // submit_many pushes under one lock: the reads share waves, so the
        // device's modeled busy time is the batch charge, strictly below
        // serial single reads.
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let q = ReadQueue::new(dev.clone(), 16);
        let reqs: Vec<(u64, usize)> =
            (0..8).map(|i| (i as u64 * 4096, 4096)).collect();
        let (_, _, busy0) = dev.stats.snapshot();
        let tags = q.submit_many(&reqs);
        let mut share_sum = 0u64;
        for t in tags {
            share_sum += q.wait(t).unwrap().modeled_ns;
        }
        let (reads, _, busy1) = dev.stats.snapshot();
        assert_eq!(reads, 8);
        let serial = 8 * dev.model_read_ns(4096);
        assert!(
            busy1 - busy0 < serial,
            "queued busy {} !< serial {serial}",
            busy1 - busy0
        );
        // apportioned shares must re-add to (at most) the wave total
        assert!(share_sum <= busy1 - busy0);
        let st = q.io_stats();
        assert_eq!(st.submitted, 8);
        assert!(st.batches >= 1 && st.batches <= 8);
        assert!(st.inflight_peak >= 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_bounds_inflight_to_depth() {
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 2);
        assert_eq!(q.depth(), 2);
        let reqs: Vec<(u64, usize)> =
            (0..10).map(|i| (i as u64 * 512, 512)).collect();
        let tags = q.submit_many(&reqs);
        for t in tags {
            q.wait(t).unwrap();
        }
        let st = q.io_stats();
        assert!(
            st.inflight_peak <= 2,
            "inflight peak {} exceeds depth 2",
            st.inflight_peak
        );
        assert!(st.batches >= 5, "10 reads at depth 2 need >= 5 waves");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn nonurgent_waves_split_and_leave_urgent_headroom() {
        // ROADMAP "I/O wave preemption": a depth-8 queue must never let
        // preload reads claim the whole device budget in one wave — the
        // urgent reserve (depth/4 = 2) caps non-urgent in-flight at 6,
        // and the per-wave split (depth/2 = 4) bounds how long any one
        // non-urgent wave can hold what it did claim.
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 8);
        let reqs: Vec<(u64, usize)> =
            (0..8).map(|i| (i as u64 * 512, 512)).collect();
        let tags = q.submit_many(&reqs);
        for t in tags {
            q.wait_as(t, IoClass::Loader).unwrap();
        }
        let st = q.io_stats();
        assert!(
            st.inflight_peak <= 6,
            "non-urgent reads filled the urgent reserve: peak {}",
            st.inflight_peak
        );
        assert!(
            st.batches >= 2,
            "an 8-read preload group must split into partial waves"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn urgent_group_may_use_the_full_depth_in_one_wave() {
        // The reserve and the wave split apply to PRELOAD reads only:
        // urgent groups keep full-depth amortization.
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 8);
        let reqs: Vec<(u64, usize)> =
            (0..8).map(|i| (i as u64 * 512, 512)).collect();
        let tags = q.submit_many_urgent(&reqs);
        for t in tags {
            q.wait(t).unwrap();
        }
        let st = q.io_stats();
        assert_eq!(
            st.batches, 1,
            "an atomic urgent group within the depth is ONE wave"
        );
        assert_eq!(st.inflight_peak, 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_read_error_reaches_the_reaper() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 4);
        let tag = q.submit(1 << 30, 64); // far past EOF → pread fails
        assert!(q.wait(tag).is_err());
        // the queue keeps working after an error
        let ok = q.submit(0, 8);
        assert!(q.wait(ok).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn abandoned_tags_never_park_in_the_done_map() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 4);
        // abandon in every possible state (pending / in flight / done —
        // which one we hit is racy, the invariant isn't): both maps must
        // drain to empty
        for i in 0..8u64 {
            let tag = q.submit(i * 64, 64);
            q.abandon(tag);
        }
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            {
                let inner = q.shared.inner.lock().unwrap();
                if inner.done.is_empty()
                    && inner.abandoned.is_empty()
                    && inner.pending.is_empty()
                    && inner.inflight == 0
                {
                    break;
                }
                assert!(
                    Instant::now() < deadline,
                    "abandoned completions leaked: done={} abandoned={}",
                    inner.done.len(),
                    inner.abandoned.len()
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // the queue still works for honest reapers afterwards
        let ok = q.submit(0, 8);
        assert!(q.wait(ok).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn urgent_submission_roundtrip_keeps_group_order() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 4);
        let tags = q.submit_many_urgent(&[(0, 4), (100, 4), (200, 4)]);
        for (i, &t) in tags.iter().enumerate() {
            let c = q.wait(t).unwrap();
            assert_eq!(c.data[0], ((i * 100) % 251) as u8);
        }
        assert_eq!(q.pending(), 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn recycled_buffers_are_reused_and_counted() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 2);
        // first read allocates; hand its buffer back
        let t = q.submit(0, 64);
        let c = q.wait(t).unwrap();
        assert_eq!(c.data.len(), 64);
        q.recycle(c.data);
        // the pool must serve subsequent reads (counter counts reuses) and
        // the returned bytes must still be correct
        let mut recycled_seen = 0;
        for i in 0..4u64 {
            let t = q.submit(i * 100, 32);
            let c = q.wait(t).unwrap();
            assert_eq!(c.data.len(), 32);
            assert_eq!(c.data[0], ((i * 100) % 251) as u8);
            recycled_seen = q.io_stats().buffers_recycled;
            q.recycle(c.data);
        }
        assert!(
            recycled_seen >= 1,
            "buffer pool never reused a recycled buffer"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn abandoned_done_completions_feed_the_pool() {
        // abandon() of an already-completed tag must reclaim its buffer
        // into the pool rather than dropping it on the floor
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 2);
        let t = q.submit(0, 128);
        // wait for the completion to land without reaping it
        let deadline = Instant::now() + Duration::from_secs(2);
        while q.pending() > 0 {
            assert!(Instant::now() < deadline, "read never completed");
            std::thread::sleep(Duration::from_millis(2));
        }
        q.abandon(t);
        let t2 = q.submit(0, 128);
        q.wait(t2).unwrap();
        assert!(
            q.io_stats().buffers_recycled >= 1,
            "abandoned completion's buffer was not recycled"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wait_time_is_attributed_per_class() {
        // Timed mode: the wave sleeps out its modeled duration, so the
        // reaper genuinely blocks — all of it must land on the class the
        // caller named, and the legacy total must stay the sum.
        let (dev, path) = temp_flash(256 << 10, ClockMode::Timed);
        let q = ReadQueue::new(dev, 4);
        let t = q.submit(0, 128 << 10);
        q.wait_as(t, IoClass::Loader).unwrap();
        let st = q.io_stats();
        assert!(st.wait_loader_ns > 0, "loader wait not attributed");
        assert_eq!(st.wait_engine_ns, 0);
        assert_eq!(st.wait_ns, st.wait_loader_ns + st.wait_engine_ns);
        let t = q.submit(0, 128 << 10);
        q.wait_as(t, IoClass::Engine).unwrap();
        let st = q.io_stats();
        assert!(st.wait_engine_ns > 0, "engine wait not attributed");
        assert_eq!(st.wait_ns, st.wait_loader_ns + st.wait_engine_ns);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_drop_joins_workers() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let q = ReadQueue::new(dev, 4);
        let _ = q.submit(0, 16); // unreaped on purpose
        drop(q); // must not deadlock
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fault_spec_parses_every_knob() {
        let plan = FaultPlan::parse(
            "seed=7,transient=0.25:2,bad=4096+8192/65536+512,\
             spike=0.5:2000000,stall=3:50000000",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert!((plan.transient_rate - 0.25).abs() < 1e-12);
        assert_eq!(plan.transient_depth, 2);
        assert_eq!(plan.bad_ranges, vec![(4096, 8192), (65536, 512)]);
        assert!((plan.spike_rate - 0.5).abs() < 1e-12);
        assert_eq!(plan.spike_ns, 2_000_000);
        assert_eq!(plan.stall_after, Some(3));
        assert_eq!(plan.stall_ns, 50_000_000);
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("transient").is_err());
    }

    #[test]
    fn transient_faults_are_retried_to_identical_bytes() {
        // rate 1.0: every read faults once (depth 1); the retry ladder
        // must absorb it — the reaper sees clean, correct bytes, and the
        // retry/fault counters record what happened underneath
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        dev.inject_faults(FaultPlan {
            transient_rate: 1.0,
            ..FaultPlan::default()
        });
        let q = ReadQueue::new(dev, 4);
        let tag = q.submit(100, 64);
        let c = q.wait(tag).unwrap();
        let want: Vec<u8> = (100..164).map(|i| (i % 251) as u8).collect();
        assert_eq!(c.data, want);
        let st = q.io_stats();
        assert!(st.retries >= 1, "transient fault was not retried");
        assert!(st.faults_injected >= 1);
        assert_eq!(st.wedged_recoveries, 0);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn exhausted_transients_surface_a_typed_transient_error() {
        // depth 10 > the 3-attempt bound: the ladder gives up and the
        // reaper gets the typed Transient error, not a stringly mess
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        dev.inject_faults(FaultPlan {
            transient_rate: 1.0,
            transient_depth: 10,
            ..FaultPlan::default()
        });
        let q = ReadQueue::new(dev, 4);
        let tag = q.submit(0, 64);
        match q.wait(tag) {
            Err(IoError::Transient(_)) => {}
            other => panic!("want Transient error, got {other:?}"),
        }
        assert_eq!(q.io_stats().retries, (MAX_IO_ATTEMPTS - 1) as u64);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn permanent_bad_range_fails_preload_but_urgent_recovers() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        dev.inject_faults(FaultPlan {
            bad_ranges: vec![(0, 1024)],
            ..FaultPlan::default()
        });
        let q = ReadQueue::new(dev, 4);
        // non-urgent (preload-class) read across the bad range: permanent
        // failure, no retries wasted
        let tag = q.submit(512, 64);
        match q.wait_as(tag, IoClass::Loader) {
            Err(IoError::Permanent(_)) => {}
            other => panic!("want Permanent error, got {other:?}"),
        }
        assert_eq!(q.io_stats().retries, 0);
        // urgent read of the SAME range recovers (modeled controller ECC
        // retry) — this is what keeps the on-demand fallback viable
        let tags = q.submit_many_urgent(&[(512, 64)]);
        let c = q.wait(tags[0]).unwrap();
        assert_eq!(c.data[0], (512 % 251) as u8);
        // reads outside the range are untouched
        let tag = q.submit(4096, 64);
        assert!(q.wait_as(tag, IoClass::Loader).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn spikes_charge_the_timing_model() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        dev.inject_faults(FaultPlan {
            spike_rate: 1.0,
            spike_ns: 5_000_000,
            ..FaultPlan::default()
        });
        let (_, _, busy0) = dev.stats.snapshot();
        let mut buf = [0u8; 64];
        dev.read_into(0, &mut buf).unwrap();
        let (_, _, busy1) = dev.stats.snapshot();
        assert!(
            busy1 - busy0 >= 5_000_000 + dev.model_read_ns(64),
            "spike latency not charged to busy_ns"
        );
        assert!(dev.stats.faults_injected.load(Ordering::Relaxed) >= 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn watchdog_replaces_a_wedged_worker() {
        // Timed mode so the injected stall genuinely blocks the worker.
        // depth 1 → one worker; the one-shot stall wedges it mid-wave,
        // the watchdog (armed with a short threshold) must fail the wave
        // over as Wedged, count the recovery, and leave a fresh worker
        // serving the queue.
        let (dev, path) = temp_flash(8192, ClockMode::Timed);
        dev.inject_faults(FaultPlan {
            stall_after: Some(1),
            stall_ns: 700_000_000, // 0.7s — far past the wedge threshold
            ..FaultPlan::default()
        });
        let q = ReadQueue::new(dev, 1);
        q.set_wedge_timeout(Duration::from_millis(50));
        let tag = q.submit(0, 64);
        let t0 = Instant::now();
        match q.wait(tag) {
            Err(IoError::Wedged(_)) => {}
            other => panic!("want Wedged error, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "wedged wave failed over via the watchdog, not a long timeout"
        );
        let st = q.io_stats();
        assert_eq!(st.wedged_recoveries, 1);
        // the replacement worker serves the queue (stall was one-shot)
        let tag = q.submit(100, 16);
        let c = q.wait(tag).unwrap();
        assert_eq!(c.data[0], (100 % 251) as u8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn throughput_rises_with_chunk_size() {
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let small = dev.measure_throughput(4 << 10, 1 << 20).unwrap();
        let large = dev.measure_throughput(1 << 20, 4 << 20).unwrap();
        assert!(large > 5.0 * small);
        std::fs::remove_file(path).ok();
    }
}
