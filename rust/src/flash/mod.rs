//! Flash device simulator (DESIGN.md §1 substitution for UFS storage).
//!
//! Real bytes move: reads hit an actual weights file via `pread`. Timing is
//! simulated from the device profile's chunk-size-dependent bandwidth curve
//! (paper Fig 7), in one of two clock modes:
//!
//! * **Timed** — each read sleeps out the remainder of its modeled duration,
//!   so wall-clock pipeline measurements (compute/load overlap, Fig 15/16)
//!   are faithful: an I/O "in flight" costs no CPU, exactly like io_uring
//!   waiting on UFS.
//! * **Modeled** — no sleeping; modeled nanoseconds accumulate on a virtual
//!   clock (fast parameter sweeps, cost-model validation).
//!
//! The async queue mirrors the paper's io_uring usage: `submit` is cheap,
//! completions are reaped with `wait_all`, and in-flight reads overlap each
//! other up to the queue depth.

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::device::DeviceProfile;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockMode {
    Timed,
    Modeled,
}

/// Read statistics (drives the Fig 7 bench and the energy model).
#[derive(Debug, Default)]
pub struct FlashStats {
    pub reads: AtomicU64,
    pub bytes: AtomicU64,
    /// Modeled busy nanoseconds of the flash device.
    pub busy_ns: AtomicU64,
    /// Histogram of chunk sizes: [<16K, <64K, <256K, <1M, >=1M].
    pub size_hist: [AtomicU64; 5],
}

impl FlashStats {
    fn record(&self, len: u64, ns: u64) {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(len, Ordering::Relaxed);
        self.busy_ns.fetch_add(ns, Ordering::Relaxed);
        let bucket = match len {
            l if l < 16 << 10 => 0,
            l if l < 64 << 10 => 1,
            l if l < 256 << 10 => 2,
            l if l < 1 << 20 => 3,
            _ => 4,
        };
        self.size_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.reads.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.busy_ns.load(Ordering::Relaxed),
        )
    }
}

/// The simulated flash device, shareable across threads.
pub struct FlashDevice {
    file: File,
    pub profile: &'static DeviceProfile,
    pub mode: ClockMode,
    /// Bandwidth scale (<1 emulates proportionally larger models).
    pub bw_scale: f64,
    pub stats: FlashStats,
    /// Serializes the (single) flash channel in Timed mode — concurrent
    /// submitters queue behind each other like a real UFS device.
    channel: Mutex<()>,
}

impl FlashDevice {
    pub fn open(
        path: &Path,
        profile: &'static DeviceProfile,
        mode: ClockMode,
        bw_scale: f64,
    ) -> Result<Arc<FlashDevice>> {
        let file = File::open(path)
            .with_context(|| format!("opening flash file {}", path.display()))?;
        Ok(Arc::new(FlashDevice {
            file,
            profile,
            mode,
            bw_scale,
            stats: FlashStats::default(),
            channel: Mutex::new(()),
        }))
    }

    /// Modeled duration of one read of `len` bytes.
    pub fn model_read_ns(&self, len: u64) -> u64 {
        let s = self.profile.flash_latency
            + len as f64 / (self.profile.flash_max_bw * self.bw_scale);
        (s * 1e9) as u64
    }

    /// Synchronous read with timing applied. Returns the bytes.
    pub fn read(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut buf = vec![0u8; len];
        self.read_into(offset, &mut buf)?;
        Ok(buf)
    }

    /// Read into a caller-provided buffer (hot path: no allocation).
    pub fn read_into(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let model_ns = self.model_read_ns(buf.len() as u64);
        match self.mode {
            ClockMode::Timed => {
                let _chan = self.channel.lock().unwrap();
                let t0 = Instant::now();
                self.file
                    .read_exact_at(buf, offset)
                    .context("flash pread")?;
                let real = t0.elapsed().as_nanos() as u64;
                if model_ns > real {
                    std::thread::sleep(Duration::from_nanos(model_ns - real));
                }
            }
            ClockMode::Modeled => {
                self.file
                    .read_exact_at(buf, offset)
                    .context("flash pread")?;
            }
        }
        self.stats.record(buf.len() as u64, model_ns);
        Ok(())
    }

    /// Batched read (io_uring-like): submit all, device streams them
    /// back-to-back paying one fixed latency per chunk. Returns buffers in
    /// submission order.
    pub fn read_batch(&self, reqs: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let mut out = Vec::with_capacity(reqs.len());
        for &(off, len) in reqs {
            out.push(self.read(off, len)?);
        }
        Ok(out)
    }

    /// Effective throughput at a chunk size, measured through the simulator
    /// (validates against `DeviceProfile::flash_throughput`). Chunks larger
    /// than the backing file wrap: the pread covers what exists, the timing
    /// models the full chunk.
    pub fn measure_throughput(&self, chunk: usize, total: usize) -> Result<f64> {
        let file_len = self.file.metadata()?.len() as usize;
        let n = (total / chunk).max(1);
        let t0 = Instant::now();
        let mut modeled_ns = 0u64;
        let read_len = chunk.min(file_len);
        let mut buf = vec![0u8; read_len];
        for i in 0..n {
            let off = ((i * read_len) % (file_len - read_len + 1)) as u64;
            self.read_into(off, &mut buf)?;
            modeled_ns += self.model_read_ns(chunk as u64);
        }
        let secs = match self.mode {
            ClockMode::Timed => t0.elapsed().as_secs_f64(),
            ClockMode::Modeled => modeled_ns as f64 / 1e9,
        };
        Ok((n * chunk) as f64 / secs)
    }
}

/// An async read queue over a FlashDevice: submit from one thread, reap
/// completions in order. Mirrors the io_uring submit/wait structure of the
/// paper's loader thread (§6 Flash loading).
pub struct ReadQueue {
    dev: Arc<FlashDevice>,
    pending: Vec<(u64, usize)>,
}

impl ReadQueue {
    pub fn new(dev: Arc<FlashDevice>) -> ReadQueue {
        ReadQueue {
            dev,
            pending: Vec::new(),
        }
    }

    pub fn submit(&mut self, offset: u64, len: usize) {
        self.pending.push((offset, len));
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Complete all pending reads (in order), returning their buffers.
    pub fn wait_all(&mut self) -> Result<Vec<Vec<u8>>> {
        let reqs = std::mem::take(&mut self.pending);
        self.dev.read_batch(&reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::PIXEL6;
    use std::io::Write;

    fn temp_flash(len: usize, mode: ClockMode) -> (Arc<FlashDevice>, std::path::PathBuf) {
        let path = std::env::temp_dir().join(format!(
            "awf_flash_test_{}_{}",
            std::process::id(),
            len
        ));
        let mut f = File::create(&path).unwrap();
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        f.write_all(&data).unwrap();
        (
            FlashDevice::open(&path, &PIXEL6, mode, 1.0).unwrap(),
            path,
        )
    }

    #[test]
    fn read_returns_file_bytes() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let got = dev.read(100, 32).unwrap();
        let want: Vec<u8> = (100..132).map(|i| (i % 251) as u8).collect();
        assert_eq!(got, want);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn modeled_time_matches_profile() {
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let ns = dev.model_read_ns(1 << 20);
        let want = PIXEL6.flash_read_seconds(1 << 20) * 1e9;
        assert!((ns as f64 - want).abs() / want < 1e-5);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn timed_read_sleeps_at_least_model_time() {
        let (dev, path) = temp_flash(256 << 10, ClockMode::Timed);
        let t0 = Instant::now();
        dev.read(0, 256 << 10).unwrap();
        let elapsed = t0.elapsed().as_nanos() as u64;
        let model = dev.model_read_ns(256 << 10);
        assert!(
            elapsed >= model,
            "elapsed {elapsed} < modeled {model}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bw_scale_slows_reads() {
        let (dev, path) = temp_flash(4096, ClockMode::Modeled);
        let base = dev.model_read_ns(1 << 20);
        let slow = FlashDevice::open(&path, &PIXEL6, ClockMode::Modeled, 0.1)
            .unwrap();
        assert!(slow.model_read_ns(1 << 20) > 5 * base);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn stats_accumulate() {
        let (dev, path) = temp_flash(64 << 10, ClockMode::Modeled);
        dev.read(0, 4 << 10).unwrap();
        dev.read(0, 32 << 10).unwrap();
        let (reads, bytes, busy) = dev.stats.snapshot();
        assert_eq!(reads, 2);
        assert_eq!(bytes, (4 << 10) + (32 << 10));
        assert!(busy > 0);
        assert_eq!(dev.stats.size_hist[0].load(Ordering::Relaxed), 1);
        assert_eq!(dev.stats.size_hist[1].load(Ordering::Relaxed), 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn queue_roundtrip_in_order() {
        let (dev, path) = temp_flash(8192, ClockMode::Modeled);
        let mut q = ReadQueue::new(dev.clone());
        q.submit(0, 8);
        q.submit(1000, 8);
        assert_eq!(q.pending(), 2);
        let bufs = q.wait_all().unwrap();
        assert_eq!(q.pending(), 0);
        assert_eq!(bufs[0], (0..8).map(|i| (i % 251) as u8).collect::<Vec<_>>());
        assert_eq!(
            bufs[1],
            (1000..1008).map(|i| (i % 251) as u8).collect::<Vec<_>>()
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn throughput_rises_with_chunk_size() {
        let (dev, path) = temp_flash(1 << 20, ClockMode::Modeled);
        let small = dev.measure_throughput(4 << 10, 1 << 20).unwrap();
        let large = dev.measure_throughput(1 << 20, 4 << 20).unwrap();
        assert!(large > 5.0 * small);
        std::fs::remove_file(path).ok();
    }
}
