//! Paper-figure harnesses. Each prints the same rows/series the paper
//! reports (absolute numbers differ — our substrate is a simulator + tiny
//! model — but the *shapes* are the reproduction target; see
//! EXPERIMENTS.md for paper-vs-measured).

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::baselines::{self, DenseInMemory};
use crate::cache::CachePolicy;
use crate::costmodel::{self, Geometry};
use crate::device::{self, DeviceProfile};
use crate::engine::{EngineOptions, PreloadTrigger, SwapEngine, SwapMode};
use crate::flash::{ClockMode, FlashDevice};
use crate::layout::AwgfFile;
use crate::metrics;
use crate::tokenizer;
use crate::util::cli::Args;
use crate::util::human_bytes;
use crate::util::json;

fn artifact_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt_or("artifacts", "artifacts"))
}

fn opts(
    sp: f64,
    group: usize,
    mode: SwapMode,
    cache_kb: u64,
    dev: &'static DeviceProfile,
    clock: ClockMode,
    bw_scale: f64,
) -> EngineOptions {
    EngineOptions {
        sparsity: sp,
        group_size: group,
        swap_mode: mode,
        cache_bytes: cache_kb * 1024,
        cache_policy: CachePolicy::Contextual,
        device: dev,
        clock,
        bw_scale,
        trigger: PreloadTrigger::FirstLayer,
        io_queue_depth: 0,
        kv_block_tokens: 16,
        attn_buckets: true,
    }
}

/// Default bandwidth scale that puts the tiny model in the paper's regime
/// (layer-load-time : layer-compute-time ratio of a 7B on UFS 3.1). The
/// tiny model's layers are ~3000× smaller than Llama-2-7B's, so unscaled
/// flash is effectively infinitely fast; scaling BW down restores the
/// paper's bandwidth-bound decode. Override with --bw-scale.
const DEFAULT_BW_SCALE: f64 = 0.004;

fn bw_scale(args: &Args) -> f64 {
    args.opt_f64("bw-scale", DEFAULT_BW_SCALE)
        .unwrap_or(DEFAULT_BW_SCALE)
}

// ================================================================ Fig 7

/// Flash read throughput vs I/O chunk size on the three device profiles.
pub fn fig7_flash_throughput(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let cfg = crate::config::ArtifactConfig::load(&dir)?;
    println!("Fig 7: flash read throughput (MB/s) vs chunk size");
    println!("{:>10} {:>14} {:>14} {:>14}", "chunk", "oneplus12", "pixel6",
             "infinix");
    for chunk in
        [4usize << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20]
    {
        let mut row = format!("{:>10}", human_bytes(chunk as u64));
        for dev in device::ALL {
            let flash = FlashDevice::open(&cfg.weights_file, dev,
                                          ClockMode::Modeled, 1.0)?;
            let bw = flash.measure_throughput(chunk, 4 << 20)?;
            row += &format!(" {:>12.1}", bw / 1e6);
        }
        println!("{row}");
    }
    println!("(modeled curve = fixed-latency + streaming-BW; knee >64 KB \
              as in the paper)");
    Ok(())
}

// ================================================================ Fig 4

/// Cross-layer activation similarity: per-site cosine + top-k precision.
pub fn fig4_similarity(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let mut eng = SwapEngine::open(
        &dir,
        opts(0.5, 1, SwapMode::Preload, 0, &device::PIXEL6,
             ClockMode::Modeled, 1.0),
    )?;
    let toks = tokenizer::eval_corpus();
    eng.forced_logits(&toks[..96.min(toks.len())])?;
    println!("Fig 4: cross-layer activation similarity (50% sparsity, \
              consecutive layers)");
    println!("{:<14} {:>10} {:>16}", "site", "cosine", "topk-precision");
    use crate::preload::ActSite;
    for site in ActSite::ALL {
        println!(
            "{:<14} {:>10.3} {:>16.3}",
            format!("{site:?}"),
            eng.tracker.site_cosine(site),
            eng.tracker.site_precision(site)
        );
    }
    println!("average precision = {:.3} (paper 7B: >0.8; tiny 8-layer \
              model has a shallower residual stream)",
             eng.tracker.avg_precision());
    Ok(())
}

// ================================================================ Fig 6

/// Hot-weight selection probability: context level vs task level.
pub fn fig6_hot_weights(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    println!("Fig 6: active-weight selection probability (sp=0.5, wg of \
              middle layer)");
    let probe = crate::layout::TensorId::new(4, crate::layout::OpKind::Wg);
    let collect = |tokens: &[u32]| -> Result<Vec<f64>> {
        let mut eng = SwapEngine::open(
            &dir,
            opts(0.5, 4, SwapMode::Preload, 0, &device::PIXEL6,
                 ClockMode::Modeled, 1.0),
        )?;
        eng.forced_logits(tokens)?;
        let counts = eng.cache_counts(probe);
        let n = tokens.len() as f64;
        Ok(counts.iter().map(|&c| c as f64 / n).collect())
    };

    // context level: a single context per domain
    let mut ctx_high = 0.0;
    for dom in tokenizer::DOMAIN_NAMES {
        let toks = tokenizer::task_corpus(dom, 7, 20);
        let probs = collect(&toks[..64.min(toks.len())])?;
        let high = probs.iter().filter(|&&p| p > 0.7).count() as f64
            / probs.len() as f64;
        ctx_high += high / tokenizer::DOMAIN_NAMES.len() as f64;
        println!("  context[{dom:<5}]: {:5.1}% of channels selected with \
                  p>0.7", high * 100.0);
    }
    // task level: mixed corpus
    let toks = tokenizer::eval_corpus();
    let probs = collect(&toks[..256.min(toks.len())])?;
    let task_high =
        probs.iter().filter(|&&p| p > 0.7).count() as f64 / probs.len() as f64;
    println!("  task  [mixed]: {:5.1}% of channels selected with p>0.7",
             task_high * 100.0);
    println!("context-level hot set ({:.1}%) > task-level ({:.1}%) — the \
              paper's Fig 6 gap", ctx_high * 100.0, task_high * 100.0);
    Ok(())
}

// ================================================================ Fig 1

/// Perplexity vs memory Pareto: ours (distilled) vs Top-K baseline (TEAL-
/// like) vs static pruning vs dense.
pub fn fig1_pareto(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let eval_path = dir.join("distill_eval.json");
    let eval = std::fs::read_to_string(&eval_path).map_err(|_| {
        anyhow!("{} missing — run `python -m compile.distill --eval`",
                eval_path.display())
    })?;
    let eval = json::parse(&eval)?;
    println!("Fig 1: perplexity vs DRAM cost (tiny model; ppl from python \
              eval, memory measured by the rust engine)");
    println!("{:>6} {:>12} {:>12} {:>12} {:>12}", "sp", "mem(ours)",
             "ppl(ours)", "ppl(topk)", "ppl(pruned)");
    let rows = eval.req("rows")?.as_arr().unwrap().to_vec();
    for row in &rows {
        let sp = row.req("sp")?.as_f64().unwrap();
        if sp == 0.0 {
            continue;
        }
        let mut eng = SwapEngine::open(
            &dir,
            opts(sp, 4, SwapMode::Preload, 128, &device::PIXEL6,
                 ClockMode::Modeled, 1.0),
        )?;
        let toks = tokenizer::eval_corpus();
        eng.forced_logits(&toks[..48])?;
        let mem = eng.memory_report().dram_total();
        let pruned = row
            .get("pruned")
            .and_then(json::Value::as_f64)
            .unwrap_or(f64::NAN);
        println!(
            "{:>6.2} {:>12} {:>12.3} {:>12.3} {:>12.3}",
            sp,
            human_bytes(mem),
            row.req("distilled")?.as_f64().unwrap(),
            row.req("baseline")?.as_f64().unwrap(),
            pruned
        );
    }
    // dense reference point
    let dense = DenseInMemory::open(&dir)?;
    println!(
        "dense reference: mem {} ppl {:.3}",
        human_bytes(dense.weight_bytes()),
        rows[0].req("baseline")?.as_f64().unwrap()
    );
    Ok(())
}

// ================================================================ Fig 14

/// End-to-end decode speed + memory across devices and sparsity levels.
pub fn fig14_e2e(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let n_tok = args.opt_usize("n", 24)?;
    let scale = bw_scale(args);
    println!("Fig 14a: decode speed (tok/s) and DRAM vs sparsity \
              (timed flash, bw-scale {scale})");
    println!("{:<10} {:>5} {:>9} {:>10} {:>9} {:>9}", "device", "sp",
             "tok/s", "dram", "hit%", "preload%");
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");
    for dev in device::ALL {
        for sp in [0.8, 0.7, 0.6, 0.5] {
            let mut eng = SwapEngine::open(
                &dir,
                opts(sp, 4, SwapMode::Preload, 256, dev, ClockMode::Timed,
                     scale),
            )?;
            eng.generate(&prompt, n_tok, 0.0)?;
            let mem = eng.memory_report();
            println!(
                "{:<10} {:>5.1} {:>9.2} {:>10} {:>8.1}% {:>8.1}%",
                dev.name,
                sp,
                eng.metrics.tokens_per_sec(),
                human_bytes(mem.dram_total()),
                eng.cache_hit_rate() * 100.0,
                eng.metrics.preload_precision() * 100.0
            );
        }
    }
    // dense-in-memory reference (llama.cpp-like)
    let mut dense = DenseInMemory::open(&dir)?;
    dense.generate(&prompt, n_tok)?;
    println!(
        "dense-in-memory reference: {:.2} tok/s, weights {}",
        dense.metrics.tokens_per_sec(),
        human_bytes(dense.weight_bytes())
    );
    Ok(())
}

// ================================================================ Fig 15

/// Ablation: serial → +pipeline(N=1) → +pipeline(N=4) → +cache.
pub fn fig15_ablation(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let n_tok = args.opt_usize("n", 20)?;
    let scale = bw_scale(args);
    let prompt = tokenizer::encode("does the polite assistant summarize? ");
    println!("Fig 15: decode speedup breakdown (sp=0.6, timed flash, \
              bw-scale {scale})");
    println!("{:<10} {:>10} {:>12} {:>12} {:>12}", "device", "serial",
             "+pipe N=1", "+pipe N=4", "+cache");
    for dev in device::ALL {
        let mut row = format!("{:<10}", dev.name);
        let mut base = 0.0;
        for (i, o) in [
            baselines::serial_options(0.6, dev, ClockMode::Timed, scale),
            opts(0.6, 1, SwapMode::Preload, 0, dev, ClockMode::Timed, scale),
            opts(0.6, 4, SwapMode::Preload, 0, dev, ClockMode::Timed, scale),
            opts(0.6, 4, SwapMode::Preload, 512, dev, ClockMode::Timed,
                 scale),
        ]
        .into_iter()
        .enumerate()
        {
            let mut eng = SwapEngine::open(&dir, o)?;
            eng.generate(&prompt, n_tok, 0.0)?;
            let tps = eng.metrics.tokens_per_sec();
            if i == 0 {
                base = tps;
                row += &format!(" {:>8.2}/s", tps);
            } else {
                row += &format!(" {:>10.2}x", tps / base);
            }
        }
        println!("{row}");
    }
    Ok(())
}

// ================================================================ Fig 16a

/// Preload vs on-demand latency as a function of cross-layer similarity.
pub fn fig16a_preload_tradeoff(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let cfg = crate::config::ArtifactConfig::load(&dir)?;
    let awgf = AwgfFile::open(&cfg.weights_file)?;
    let dev = &device::PIXEL6;
    let flash =
        FlashDevice::open(&cfg.weights_file, dev, ClockMode::Modeled, 1.0)?;
    let info = awgf.op(crate::layout::OpKind::Wg);
    let k = cfg.model.k_active(0.5, info.d_in);
    println!("Fig 16a: per-layer preload vs on-demand load time vs \
              similarity (wg, k={k}, N=1)");
    println!("{:>6} {:>14} {:>14}", "cos~si", "preload(us)",
             "on-demand(us)");
    for si in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        // preload reads k predicted rows ahead of time (overlapped), then
        // corrects the mispredicted (1-si)·k rows on demand — only the
        // correction is exposed latency, but both are device-busy time.
        let miss = (k as f64 * (1.0 - si)).round();
        let preload_ns =
            miss * flash.model_read_ns(info.row_bytes as u64) as f64;
        // pure on-demand: k single-row reads after the activation is known
        let ondemand_ns =
            k as f64 * flash.model_read_ns(info.row_bytes as u64) as f64;
        println!("{:>6.1} {:>14.1} {:>14.1}", si, preload_ns / 1e3,
                 ondemand_ns / 1e3);
    }
    println!("(exposed preload cost falls linearly with similarity; \
              on-demand is flat — preload wins once similarity clears the \
              paper's ~0.2-0.4 crossover)");
    Ok(())
}

// ================================================================ Fig 16b

/// Latency + memory vs cross-layer-group size N on an 8-layer decoder.
pub fn fig16b_layer_group(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let n_tok = args.opt_usize("n", 16)?;
    let scale = bw_scale(args);
    let prompt = tokenizer::encode("fn sparse buffer loads the cache. ");
    println!("Fig 16b: layer-group size sweep (sp=0.6, timed, bw-scale \
              {scale})");
    println!("{:>4} {:>9} {:>12} {:>12} {:>16}", "N", "tok/s", "ms/token",
             "preload-mem", "flash-bytes/tok");
    for n in [0usize, 1, 2, 4, 8] {
        let o = if n == 0 {
            baselines::serial_options(0.6, &device::PIXEL6,
                                      ClockMode::Timed, scale)
        } else {
            opts(0.6, n, SwapMode::Preload, 0, &device::PIXEL6,
                 ClockMode::Timed, scale)
        };
        let mut eng = SwapEngine::open(&dir, o)?;
        eng.generate(&prompt, n_tok, 0.0)?;
        let st = eng.loader_stats();
        println!(
            "{:>4} {:>9.2} {:>12.2} {:>12} {:>16}",
            n,
            eng.metrics.tokens_per_sec(),
            1e3 / eng.metrics.tokens_per_sec().max(1e-9),
            human_bytes(eng.peak_preload_bytes),
            human_bytes(
                (st.bytes_read + eng.metrics.flash_bytes)
                    / eng.metrics.tokens.max(1)
            )
        );
    }
    Ok(())
}

// ================================================================ Fig 17

/// Context-level vs task-level cache hit rate.
pub fn fig17_cache_policy(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    println!("Fig 17: cache hit rate — context-level vs task-level \
              (sp=0.5, cache 512 KB)");

    let run = |policy: CachePolicy, warm: bool, toks: &[u32]| -> Result<f64> {
        let mut o = opts(0.5, 4, SwapMode::Preload, 512, &device::PIXEL6,
                         ClockMode::Modeled, 1.0);
        o.cache_policy = policy;
        let mut eng = SwapEngine::open(&dir, o)?;
        if warm {
            // task-level: warm with the *mixed* corpus statistics, then
            // freeze (TaskStatic never evicts)
            let mixed = tokenizer::eval_corpus();
            eng.forced_logits(&mixed[..128])?;
        }
        eng.metrics.cache_hits = 0;
        eng.metrics.cache_misses = 0;
        eng.cache_reset_stats();
        eng.forced_logits(toks)?;
        Ok(eng.metrics.cache_hit_rate())
    };

    println!("(a) hit rate vs token count (qa-domain context):");
    println!("{:>8} {:>12} {:>12}", "tokens", "context", "task");
    for len in [10usize, 20, 40] {
        let toks = tokenizer::task_corpus("qa", 11, 12);
        let toks = &toks[..len.min(toks.len())];
        let ctx = run(CachePolicy::Contextual, false, toks)?;
        let task = run(CachePolicy::TaskStatic, true, toks)?;
        println!("{:>8} {:>11.1}% {:>11.1}%", len, ctx * 100.0,
                 task * 100.0);
    }

    println!("(b) hit rate per downstream task (64 tokens):");
    println!("{:>8} {:>12} {:>12}", "task", "context", "task-cache");
    for dom in tokenizer::DOMAIN_NAMES {
        let toks = tokenizer::task_corpus(dom, 23, 20);
        let toks = &toks[..64.min(toks.len())];
        let ctx = run(CachePolicy::Contextual, false, toks)?;
        let task = run(CachePolicy::TaskStatic, true, toks)?;
        println!("{:>8} {:>11.1}% {:>11.1}%", dom, ctx * 100.0,
                 task * 100.0);
    }
    Ok(())
}

// ================================================================ Fig 19

/// Power + energy per token vs memory cost, ours vs dense baseline.
pub fn fig19_energy(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let n_tok = args.opt_usize("n", 20)?;
    let scale = bw_scale(args);
    let dev = &device::ONEPLUS12; // paper measures Device 1
    let prompt = tokenizer::encode("please write a helpful clear reply. ");
    println!("Fig 19: power / energy on {} (timed, bw-scale {scale})",
             dev.name);
    println!("{:<18} {:>10} {:>10} {:>12}", "config", "avg W", "J/token",
             "mem");
    for sp in [0.8, 0.7, 0.6, 0.5] {
        let mut eng = SwapEngine::open(
            &dir,
            opts(sp, 4, SwapMode::Preload, 256, dev, ClockMode::Timed,
                 scale),
        )?;
        eng.generate(&prompt, n_tok, 0.0)?;
        let e = metrics::energy(dev, &eng.metrics);
        println!(
            "{:<18} {:>10.2} {:>10.3} {:>12}",
            format!("activeflow sp={sp}"),
            e.avg_power_w,
            e.energy_per_token_j,
            human_bytes(eng.memory_report().dram_total())
        );
    }
    let mut dense = DenseInMemory::open(&dir)?;
    dense.generate(&prompt, n_tok)?;
    // the dense baseline keeps the CPU busy the whole wall time (no flash
    // wait): compute fraction ≈ 1
    let mut m = dense.metrics.clone();
    m.compute_busy = m.wall;
    let e = metrics::energy(dev, &m);
    println!(
        "{:<18} {:>10.2} {:>10.3} {:>12}",
        "dense-in-memory",
        e.avg_power_w,
        e.energy_per_token_j,
        human_bytes(dense.weight_bytes())
    );
    Ok(())
}

// ================================================================ §7.2 MoE

/// Mixtral-8x7B feasibility via the cost model (paper: 1.8 tok/s @2.9 GB
/// on Pixel 6).
pub fn moe_sim(_args: &Args) -> Result<()> {
    let geo = Geometry::mixtral8x7b_q4();
    println!("§7.2 Mixtral-8x7B-Q4 feasibility (cost model, si=0.85)");
    println!("{:<10} {:>10} {:>12} {:>12}", "device", "budget",
             "pred tok/s", "paper tok/s");
    let paper: &[(&str, f64, f64)] = &[
        ("oneplus12", 4.3, 1.3),
        ("pixel6", 4.3, 1.0),
        ("infinix", 4.3, 0.4),
        ("oneplus12", 2.9, 2.3),
        ("pixel6", 2.9, 1.8),
        ("infinix", 2.9, 0.8),
    ];
    for &(name, gb, paper_tps) in paper {
        let dev = device::by_name(name).unwrap();
        let budget = (gb * 1024.0) as u64 * (1 << 20);
        // finer grid: Mixtral feasibility is decided between 0.80 and 0.95
        let grid = [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95];
        match costmodel::search(dev, &geo, budget, 0.85, 1.0, &grid) {
            None => println!("{:<10} {:>9.1}G {:>12} {:>12.1}", name, gb,
                             "infeasible", paper_tps),
            Some(r) => println!(
                "{:<10} {:>9.1}G {:>12.2} {:>12.1}",
                name,
                gb,
                1.0 / r.cost.t_decode,
                paper_tps
            ),
        }
    }
    println!("(shape check: less memory → higher sparsity → *faster* \
              decode, and device order follows flash BW — both as in §7.2)");
    Ok(())
}

// ======================================================== perf trajectory

/// `bench smoke` (PERF.md): decode a fixed synthetic prompt on a fixed
/// config and emit `BENCH_decode.json` — the machine-readable point the
/// perf trajectory tracks from PR to PR. Numbers are for *comparing runs
/// on the same machine*, not paper claims; see PERF.md for the
/// methodology and the field reference.
pub fn bench_smoke(args: &Args) -> Result<()> {
    use crate::util::json::{num, obj, s};

    let dir = artifact_dir(args);
    let n_tok = args.opt_usize("n", 32)?;
    let scale = bw_scale(args);
    let out_path = args.opt_or("out", "BENCH_decode.json");
    let dev = &device::PIXEL6;
    let o = opts(0.6, 4, SwapMode::Preload, 256, dev, ClockMode::Timed,
                 scale);
    // fixed prompt: the same one fig14 uses, so numbers line up
    let prompt = tokenizer::encode("the sparse model swaps active weights. ");

    let mut eng = SwapEngine::open(&dir, o)?;
    eng.generate(&prompt, n_tok, 0.0)?;
    let m = eng.metrics.clone();
    let mem = eng.memory_report();
    let loader = eng.loader_stats();
    let (_io_loader, io_engine) = eng.io_wait_histos();
    let e = metrics::energy(dev, &m);

    let v = obj(vec![
        ("bench", s("decode-smoke")),
        ("device", s(dev.name)),
        ("sparsity", num(0.6)),
        ("group_size", num(4.0)),
        ("bw_scale", num(scale)),
        ("prompt_tokens", num(prompt.len() as f64)),
        ("gen_tokens", num(n_tok as f64)),
        ("tokens", num(m.tokens as f64)),
        ("tokens_per_sec", num(m.tokens_per_sec())),
        ("wall_ms", num(m.wall.as_secs_f64() * 1e3)),
        ("compute_busy_ms", num(m.compute_busy.as_secs_f64() * 1e3)),
        ("flash_busy_ms", num(m.flash_busy.as_secs_f64() * 1e3)),
        ("flash_bytes", num(m.flash_bytes as f64)),
        // swap-volume companions of flash_bytes: cache-served bytes and
        // compute DRAM traffic (the energy model's activity inputs)
        ("cache_bytes", num(m.cache_bytes as f64)),
        ("dram_bytes", num(m.dram_bytes as f64)),
        ("cache_hit_rate", num(eng.cache_hit_rate())),
        ("preload_precision", num(m.preload_precision())),
        ("cache_lock_acquires", num(m.cache_lock_acquires as f64)),
        ("cache_locks_avoided", num(m.cache_locks_avoided as f64)),
        ("batched_inserts", num(m.batched_inserts as f64)),
        ("ondemand_rows", num(m.ondemand_rows as f64)),
        (
            "ondemand_coalesced_runs",
            num(m.ondemand_coalesced_runs as f64),
        ),
        ("slab_bytes_peak", num(m.slab_bytes_peak as f64)),
        ("io_batches", num(m.io_batches as f64)),
        ("io_inflight_peak", num(m.io_inflight_peak as f64)),
        // legacy total + the per-class split (loader reaping vs engine
        // on-demand stalls — the overlap-diagnosis pair)
        ("io_wait_us", num(m.io_wait_total().as_secs_f64() * 1e6)),
        (
            "io_wait_loader_us",
            num(m.io_wait_loader.as_secs_f64() * 1e6),
        ),
        (
            "io_wait_engine_us",
            num(m.io_wait_engine.as_secs_f64() * 1e6),
        ),
        ("io_buffers_recycled", num(m.io_buffers_recycled as f64)),
        // flight-recorder percentiles (log2-bucket histograms; PERF.md
        // §Observability) — check-perf gates the ITL tail
        ("itl_p50_us", num(m.h_itl_us.p50() as f64)),
        ("itl_p95_us", num(m.h_itl_us.p95() as f64)),
        ("itl_p99_us", num(m.h_itl_us.p99() as f64)),
        ("wave_p99_us", num(m.h_wave_us.p99() as f64)),
        ("ondemand_p99_us", num(m.h_ondemand_us.p99() as f64)),
        (
            "admission_wait_p99_us",
            num(m.h_admission_wait_us.p99() as f64),
        ),
        ("io_wait_engine_p99_us", num(io_engine.p99() as f64)),
        ("loader_chunks_read", num(loader.chunks_read as f64)),
        ("loader_bytes_read", num(loader.bytes_read as f64)),
        ("loader_parts_failed", num(loader.parts_failed as f64)),
        // fault-injection / recovery ladder: all zero on a healthy run —
        // check_perf watches them so a regression that silently starts
        // retrying or falling back is visible in the trajectory
        ("faults_injected", num(m.faults_injected as f64)),
        ("retries", num(m.io_retries as f64)),
        ("wedged_recoveries", num(m.wedged_recoveries as f64)),
        ("fallback_rows", num(m.fallback_rows as f64)),
        ("degraded_fallbacks", num(m.degraded_fallbacks as f64)),
        ("kv_blocks_peak", num(m.kv_blocks_peak as f64)),
        ("dram_total_bytes", num(mem.dram_total() as f64)),
        ("energy_per_token_j", num(e.energy_per_token_j)),
    ]);
    let mut text = v.to_string();
    text.push('\n');
    std::fs::write(&out_path, &text)?;
    println!(
        "bench smoke: {:.2} tok/s | hit {:.1}% | preload {:.1}% | \
         {} lock acquisitions ({} avoided) | slab peak {}",
        m.tokens_per_sec(),
        eng.cache_hit_rate() * 100.0,
        m.preload_precision() * 100.0,
        m.cache_lock_acquires,
        m.cache_locks_avoided,
        human_bytes(m.slab_bytes_peak),
    );
    println!("wrote {out_path}");
    Ok(())
}

// ================================================================ Fig 2

/// Upper-bound contextual sparsity (computed by python analysis; printed
/// here if present).
pub fn fig2_upper_bound(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let path = dir.join("upper_bound.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        println!(
            "Fig 2 data not found — run `cd python && python -m \
             compile.analysis upper-bound` first ({})",
            path.display()
        );
        return Ok(());
    };
    let v = json::parse(&text)?;
    println!("Fig 2: upper-bound active-weight fraction per decoded token \
              (|W|·|x| scoring)");
    let fr = v.req("fractions")?.as_arr().unwrap();
    let vals: Vec<f64> = fr.iter().map(|x| x.as_f64().unwrap()).collect();
    let mean = vals.iter().sum::<f64>() / vals.len() as f64;
    let max = vals.iter().cloned().fold(0.0f64, f64::max);
    let hist =
        |lo: f64, hi: f64| vals.iter().filter(|&&v| v >= lo && v < hi).count();
    println!("tokens analyzed: {}", vals.len());
    println!("mean active fraction: {:.1}%  max: {:.1}%", mean * 100.0,
             max * 100.0);
    println!("distribution: <5%: {}  5-10%: {}  10-15%: {}  >=15%: {}",
             hist(0.0, 0.05), hist(0.05, 0.10), hist(0.10, 0.15),
             hist(0.15, 1.01));
    Ok(())
}
