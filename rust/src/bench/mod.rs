//! Paper-figure bench harnesses (`activeflow bench <name>`). Each prints
//! the rows/series of the corresponding paper table or figure — see the
//! per-experiment index in DESIGN.md §4.

use anyhow::{bail, Result};

use crate::util::cli::Args;

mod figures;

pub fn dispatch(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match which {
        "flash" => figures::fig7_flash_throughput(args),
        "similarity" => figures::fig4_similarity(args),
        "hot-weights" => figures::fig6_hot_weights(args),
        "pareto" => figures::fig1_pareto(args),
        "e2e" => figures::fig14_e2e(args),
        "ablation" => figures::fig15_ablation(args),
        "preload-tradeoff" => figures::fig16a_preload_tradeoff(args),
        "layer-group" => figures::fig16b_layer_group(args),
        "cache-policy" => figures::fig17_cache_policy(args),
        "energy" => figures::fig19_energy(args),
        "moe-sim" => figures::moe_sim(args),
        "upper-bound" => figures::fig2_upper_bound(args),
        "smoke" => figures::bench_smoke(args),
        "all" => {
            for name in [
                "flash", "similarity", "hot-weights", "upper-bound",
                "pareto", "e2e", "ablation", "preload-tradeoff",
                "layer-group", "cache-policy", "energy", "moe-sim",
            ] {
                println!("\n================ bench {name} ================");
                let mut sub = args.clone();
                sub.positional = vec![name.to_string()];
                dispatch(&sub)?;
            }
            Ok(())
        }
        "help" | _ => {
            bail!(
                "bench what? flash|similarity|hot-weights|upper-bound|pareto|\
                 e2e|ablation|preload-tradeoff|layer-group|cache-policy|\
                 energy|moe-sim|smoke|all"
            )
        }
    }
}
