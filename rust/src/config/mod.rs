//! Configuration: model geometry (read from `artifacts/model_config.json`,
//! written by the python AOT path) and runtime knobs.
//!
//! Field names mirror `python/compile/configs.py` — keep in sync.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Value};

/// Transformer geometry (mirror of python `ModelConfig`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Active channels of an input dim at sparsity `sp` (mirror of python).
    pub fn k_active(&self, sp: f64, dim: usize) -> usize {
        let k = (dim as f64 * (1.0 - sp)).round() as usize;
        k.clamp(1, dim)
    }

    pub fn from_json(v: &Value) -> Result<ModelConfig> {
        let g = |k: &str| -> Result<usize> {
            Ok(v.req(k)?.as_usize().ok_or_else(|| anyhow!("{k} not int"))?)
        };
        Ok(ModelConfig {
            name: v
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("model")
                .to_string(),
            vocab_size: g("vocab_size")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            n_kv_heads: g("n_kv_heads")?,
            head_dim: g("head_dim")?,
            d_ff: g("d_ff")?,
            max_seq: g("max_seq")?,
            rope_theta: v.req("rope_theta")?.as_f64().unwrap_or(10000.0) as f32,
            norm_eps: v.req("norm_eps")?.as_f64().unwrap_or(1e-5) as f32,
        })
    }

    /// The tiny config used across unit tests (matches python TINY).
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab_size: 256,
            d_model: 128,
            n_layers: 8,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ff: 384,
            max_seq: 256,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }
}

/// One entry of the sparsity-level table emitted by aot.py.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparsityLevel {
    pub sp: f64,
    pub k_attn: usize,
    pub k_o: usize,
    pub k_ff: usize,
}

/// Parsed `artifacts/model_config.json`.
#[derive(Debug, Clone)]
pub struct ArtifactConfig {
    pub model: ModelConfig,
    pub quant: String,
    pub group_size: usize,
    pub sparsity_levels: Vec<SparsityLevel>,
    pub weights_file: PathBuf,
    pub artifact_dir: PathBuf,
}

impl ArtifactConfig {
    pub fn load(dir: &Path) -> Result<ArtifactConfig> {
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).context("parsing model_config.json")?;
        let model = ModelConfig::from_json(v.req("model")?)?;
        let mut levels = Vec::new();
        for lv in v
            .req("sparsity_levels")?
            .as_arr()
            .ok_or_else(|| anyhow!("sparsity_levels not array"))?
        {
            levels.push(SparsityLevel {
                sp: lv.req("sp")?.as_f64().unwrap(),
                k_attn: lv.req("k_attn")?.as_usize().unwrap(),
                k_o: lv.req("k_o")?.as_usize().unwrap(),
                k_ff: lv.req("k_ff")?.as_usize().unwrap(),
            });
        }
        Ok(ArtifactConfig {
            model,
            quant: v
                .req("quant")?
                .as_str()
                .ok_or_else(|| anyhow!("quant"))?
                .to_string(),
            group_size: v.req("group_size")?.as_usize().unwrap_or(4),
            sparsity_levels: levels,
            weights_file: dir.join(
                v.req("weights_file")?.as_str().unwrap_or("model.awgf"),
            ),
            artifact_dir: dir.to_path_buf(),
        })
    }

    /// Nearest configured sparsity level (levels are coarse by design; the
    /// elastic controller snaps to the closest compiled artifact set).
    pub fn nearest_level(&self, sp: f64) -> Option<&SparsityLevel> {
        self.sparsity_levels.iter().min_by(|a, b| {
            (a.sp - sp)
                .abs()
                .partial_cmp(&(b.sp - sp).abs())
                .unwrap()
        })
    }
}

/// Runtime knobs for the swapping engine (paper Table 1 parameters).
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Contextual sparsity (fraction of channels *skipped*). 0 = dense.
    pub sparsity: f64,
    /// Layers per cross-layer preload group (paper N).
    pub group_size: usize,
    /// Weight-cache budget in bytes (paper M_cache).
    pub cache_bytes: u64,
    /// Total DRAM budget in bytes (paper M_max); used by the searcher.
    pub mem_budget: u64,
    /// Device profile name (see [`crate::device`]).
    pub device: String,
    /// `true` → flash reads really sleep (wall-clock overlap measurements);
    /// `false` → virtual-clock accounting only (fast sweeps).
    pub timed_flash: bool,
    /// Scale flash bandwidth to emulate larger models on the tiny geometry
    /// (e.g. 0.02 ≈ Llama-7B-sized layers per DESIGN.md §1).
    pub bw_scale: f64,
    /// Software bound on flash reads in flight through the shared async
    /// read queue (loader preloads + on-demand fetch misses). `0` defers
    /// to the device profile's modeled queue depth.
    pub io_queue_depth: usize,
    /// Runtime DRAM governor: relative budget change below which a
    /// `set_budget` event is ignored (anti-thrash hysteresis).
    pub rebudget_hysteresis: f64,
    /// Runtime DRAM governor: optional scripted pressure trace
    /// (`"<size>@<token>,..."` — see [`crate::governor::PressureSchedule`]).
    pub pressure_schedule: Option<String>,
    /// Continuous-batching scheduler: hard cap on concurrently decoding
    /// sequences (`--max-seqs`). The governor may lower the effective
    /// ceiling below this when the DRAM budget cannot hold that much KV.
    pub max_seqs: usize,
    /// Scheduler wait-queue bound; submissions past it are rejected.
    pub sched_queue_cap: usize,
    /// Paged KV pool: tokens per block (`--kv-block-tokens`). A sequence
    /// is charged `ceil(pos / kv_block_tokens)` blocks instead of a full
    /// `max_seq` window.
    pub kv_block_tokens: usize,
    /// Runtime DRAM governor: optional available-DRAM file polled on the
    /// server worker (`--pressure-file`, `/proc/meminfo`-style or a plain
    /// byte count) and fed to `set_budget` as a third trigger next to
    /// `command`/`schedule`.
    pub pressure_file: Option<std::path::PathBuf>,
    /// Deterministic fault-injection plan (`--faults`, see
    /// [`crate::flash::FaultPlan::parse`]) armed on the flash device —
    /// drives the chaos suite's transient/permanent/stall schedules.
    pub fault_spec: Option<String>,
    /// Length-bucketed attention windows (`--attn-buckets`): run each step
    /// through the smallest compiled `attn_core_<cap>` artifact covering
    /// `pos + 1` instead of the monolithic `[max_seq, d_kv]` window.
    /// Bit-identical output; falls back to monolithic automatically when
    /// the artifact dir predates bucketed compilation.
    pub attn_buckets: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            sparsity: 0.6,
            group_size: 4,
            cache_bytes: 256 * 1024,
            mem_budget: u64::MAX,
            device: "pixel6".into(),
            timed_flash: true,
            bw_scale: 1.0,
            io_queue_depth: 0,
            rebudget_hysteresis: 0.05,
            pressure_schedule: None,
            max_seqs: 4,
            sched_queue_cap: 64,
            kv_block_tokens: 16,
            pressure_file: None,
            fault_spec: None,
            attn_buckets: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dims() {
        let c = ModelConfig::tiny();
        assert_eq!(c.d_kv(), 64);
        assert_eq!(c.q_dim(), 128);
        assert_eq!(c.k_active(0.5, 128), 64);
        assert_eq!(c.k_active(0.9, 128), 13);
        assert_eq!(c.k_active(0.999, 128), 1); // clamped at 1
    }

    #[test]
    fn model_from_json() {
        let j = r#"{"name":"t","vocab_size":256,"d_model":128,"n_layers":8,
            "n_heads":4,"n_kv_heads":2,"head_dim":32,"d_ff":384,
            "max_seq":256,"rope_theta":10000.0,"norm_eps":1e-5}"#;
        let v = json::parse(j).unwrap();
        let c = ModelConfig::from_json(&v).unwrap();
        assert_eq!(c, ModelConfig::tiny().clone_with_name("t"));
    }

    impl ModelConfig {
        fn clone_with_name(&self, n: &str) -> ModelConfig {
            let mut c = self.clone();
            c.name = n.into();
            c
        }
    }

    #[test]
    fn runtime_defaults_include_governor_knobs() {
        let rc = RuntimeConfig::default();
        assert_eq!(rc.rebudget_hysteresis, 0.05);
        assert!(rc.pressure_schedule.is_none());
        assert_eq!(rc.io_queue_depth, 0, "0 = device-profile queue depth");
        assert_eq!(rc.max_seqs, 4);
        assert_eq!(rc.sched_queue_cap, 64);
        assert_eq!(rc.kv_block_tokens, 16);
        assert!(rc.pressure_file.is_none());
        assert!(rc.fault_spec.is_none(), "faults are strictly opt-in");
        assert!(rc.attn_buckets, "bucketed attention is the default path");
    }

    #[test]
    fn nearest_level_snaps() {
        let mk = |sp| SparsityLevel { sp, k_attn: 1, k_o: 1, k_ff: 1 };
        let ac = ArtifactConfig {
            model: ModelConfig::tiny(),
            quant: "q4_0".into(),
            group_size: 4,
            sparsity_levels: vec![mk(0.5), mk(0.7), mk(0.9)],
            weights_file: "/tmp/x".into(),
            artifact_dir: "/tmp".into(),
        };
        assert_eq!(ac.nearest_level(0.55).unwrap().sp, 0.5);
        assert_eq!(ac.nearest_level(0.65).unwrap().sp, 0.7);
        assert_eq!(ac.nearest_level(1.0).unwrap().sp, 0.9);
    }
}
