//! Dynamic contextual weight cache (paper §4.2, Fig 12).
//!
//! Per-tensor LFU: every (layer, op) tensor keeps independent frequency
//! counters per channel; a newly activated channel replaces the least-used
//! cached channel only if its count is higher ("If a newly activated channel
//! has a higher count than the least-used channel in the cache, we evict the
//! least-used channel"). Counters reset at sequence start — that is what
//! makes the policy *context-level* rather than task-level (Fig 6/17).
//!
//! The task-level baseline pre-fills each tensor with the statically hottest
//! channels of a calibration corpus and never evicts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::layout::TensorId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Dynamic LFU with per-sequence counter reset (the paper's policy).
    Contextual,
    /// Static residency from task-level hot-weight statistics (baseline).
    TaskStatic,
}

/// Cache for one weight tensor's channels (rows already dequantized to f32).
pub struct TensorCache {
    pub d_in: usize,
    pub row_len: usize,
    pub capacity: usize,
    policy: CachePolicy,
    counts: Vec<u32>,
    /// channel -> slot + 1 (0 = not cached)
    slot_of: Vec<u32>,
    /// slot -> channel
    chan_of: Vec<u32>,
    used_slots: usize,
    store: Vec<f32>,
    pub hits: u64,
    pub misses: u64,
}

impl TensorCache {
    pub fn new(d_in: usize, row_len: usize, capacity: usize,
               policy: CachePolicy) -> TensorCache {
        let capacity = capacity.min(d_in);
        TensorCache {
            d_in,
            row_len,
            capacity,
            policy,
            counts: vec![0; d_in],
            slot_of: vec![0; d_in],
            chan_of: vec![u32::MAX; capacity],
            used_slots: 0,
            store: vec![0f32; capacity * row_len],
            hits: 0,
            misses: 0,
        }
    }

    pub fn contains(&self, channel: usize) -> bool {
        self.slot_of[channel] != 0
    }

    /// Count one use of `channel` and look it up. Hit/miss accounting
    /// happens here (N_hit / (N_hit + N_miss), paper §7.1).
    pub fn lookup(&mut self, channel: usize) -> Option<&[f32]> {
        self.counts[channel] = self.counts[channel].saturating_add(1);
        match self.slot_of[channel] {
            0 => {
                self.misses += 1;
                None
            }
            s => {
                self.hits += 1;
                let slot = (s - 1) as usize;
                Some(&self.store[slot * self.row_len..(slot + 1) * self.row_len])
            }
        }
    }

    /// Peek without accounting (used by the preloader to skip cached
    /// channels when building load lists).
    pub fn peek(&self, channel: usize) -> Option<&[f32]> {
        match self.slot_of[channel] {
            0 => None,
            s => {
                let slot = (s - 1) as usize;
                Some(&self.store[slot * self.row_len..(slot + 1) * self.row_len])
            }
        }
    }

    /// Offer a freshly loaded row to the cache. Returns true if admitted.
    pub fn insert(&mut self, channel: usize, row: &[f32]) -> bool {
        debug_assert_eq!(row.len(), self.row_len);
        if self.capacity == 0 || self.contains(channel) {
            return self.contains(channel);
        }
        if self.policy == CachePolicy::TaskStatic {
            // static residency: only fill while warm-up slots remain
            if self.used_slots >= self.capacity {
                return false;
            }
            let slot = self.used_slots;
            self.used_slots += 1;
            self.place(channel, slot, row);
            return true;
        }
        if self.used_slots < self.capacity {
            let slot = self.used_slots;
            self.used_slots += 1;
            self.place(channel, slot, row);
            return true;
        }
        // full: evict the least-frequently-used cached channel if the new
        // channel's count is at least as high. (The paper states "higher
        // count", but its own Fig 12 example evicts on a tie — ties favor
        // the newly activated channel, i.e. recency.)
        let (victim_slot, victim_chan, victim_count) = self.min_count_slot();
        if self.counts[channel] >= victim_count {
            self.slot_of[victim_chan] = 0;
            self.place(channel, victim_slot, row);
            true
        } else {
            false
        }
    }

    /// Batched [`TensorCache::insert`]: offer many freshly loaded rows in
    /// one call. The single-lock fetch path stages every row of an op fetch
    /// and admits them together instead of re-acquiring the cache mutex per
    /// row. Returns how many rows were admitted.
    pub fn insert_rows<'a, I>(&mut self, rows: I) -> usize
    where
        I: IntoIterator<Item = (usize, &'a [f32])>,
    {
        rows.into_iter()
            .filter(|&(ch, row)| self.insert(ch, row))
            .count()
    }

    fn place(&mut self, channel: usize, slot: usize, row: &[f32]) {
        self.slot_of[channel] = (slot + 1) as u32;
        self.chan_of[slot] = channel as u32;
        self.store[slot * self.row_len..(slot + 1) * self.row_len]
            .copy_from_slice(row);
    }

    fn min_count_slot(&self) -> (usize, usize, u32) {
        let mut best = (0usize, 0usize, u32::MAX);
        for slot in 0..self.used_slots {
            let chan = self.chan_of[slot] as usize;
            let c = self.counts[chan];
            if c < best.2 {
                best = (slot, chan, c);
            }
        }
        best
    }

    /// Re-budget this tensor's capacity in place (runtime DRAM governor).
    /// Growing keeps every resident row; shrinking evicts the
    /// lowest-count channels until the survivors fit, compacting the
    /// store so allocated bytes drop to the new capacity. Surviving rows
    /// are moved verbatim — bit-identical contents, LFU counters intact.
    /// Returns the number of evicted rows.
    pub fn resize(&mut self, new_capacity: usize) -> usize {
        let new_capacity = new_capacity.min(self.d_in);
        if new_capacity == self.capacity {
            return 0;
        }
        if new_capacity > self.capacity {
            let used = self.used_slots * self.row_len;
            let mut store = vec![0f32; new_capacity * self.row_len];
            store[..used].copy_from_slice(&self.store[..used]);
            self.store = store;
            self.chan_of.resize(new_capacity, u32::MAX);
            self.capacity = new_capacity;
            return 0;
        }
        // Shrink: keep the highest-count residents (ties → lower channel,
        // deterministic). Rebuild slot maps and compact the store.
        let mut keep: Vec<(usize, usize)> = (0..self.used_slots)
            .map(|slot| (slot, self.chan_of[slot] as usize))
            .collect();
        let counts = &self.counts;
        keep.sort_by(|a, b| {
            counts[b.1].cmp(&counts[a.1]).then(a.1.cmp(&b.1))
        });
        let survivors = keep.len().min(new_capacity);
        let evicted = keep.len() - survivors;
        let mut store = vec![0f32; new_capacity * self.row_len];
        let mut chan_of = vec![u32::MAX; new_capacity];
        for &(_, ch) in &keep {
            self.slot_of[ch] = 0;
        }
        for (new_slot, &(old_slot, ch)) in
            keep[..survivors].iter().enumerate()
        {
            store[new_slot * self.row_len..(new_slot + 1) * self.row_len]
                .copy_from_slice(
                    &self.store[old_slot * self.row_len
                        ..(old_slot + 1) * self.row_len],
                );
            chan_of[new_slot] = ch as u32;
            self.slot_of[ch] = (new_slot + 1) as u32;
        }
        self.store = store;
        self.chan_of = chan_of;
        self.used_slots = survivors;
        self.capacity = new_capacity;
        evicted
    }

    /// Contiguous resident rows in slot order. With full capacity and
    /// channel-order inserts (the dense baseline's bulk load) this is the
    /// whole `[d_in, d_out]` matrix.
    pub fn packed_rows(&self) -> &[f32] {
        &self.store[..self.used_slots * self.row_len]
    }

    /// Sequence boundary: context-level counters restart (cached contents
    /// stay — only the recency signal resets).
    pub fn reset_context(&mut self) {
        if self.policy == CachePolicy::Contextual {
            self.counts.fill(0);
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn resident_channels(&self) -> usize {
        self.used_slots
    }

    /// Selection count of a channel (doubles as the Fig 6 hot-weight
    /// frequency statistic).
    pub fn count_of(&self, channel: usize) -> u32 {
        self.counts[channel]
    }

    pub fn bytes(&self) -> u64 {
        (self.capacity * self.row_len * 4) as u64
    }
}

/// The full model weight cache: one [`TensorCache`] per (layer, op), with a
/// byte budget split proportionally to tensor size so every tensor caches
/// the same *fraction* of its channels ("balanced cache size across all
/// weights", §4.2).
pub struct WeightCache {
    pub tensors: BTreeMap<TensorId, TensorCache>,
    pub policy: CachePolicy,
    pub budget_bytes: u64,
}

/// The §4.2 balanced split, shared by construction and runtime resize so
/// the two can never diverge: the fraction of each tensor's channels a
/// byte budget affords when split proportionally to tensor size.
fn balanced_frac(total_bytes: u64, budget_bytes: u64) -> f64 {
    if total_bytes == 0 {
        0.0
    } else {
        (budget_bytes as f64 / total_bytes as f64).min(1.0)
    }
}

impl WeightCache {
    /// `tensor_dims`: (id, d_in, d_out_f32_len) for every cached tensor.
    pub fn new(
        tensor_dims: &[(TensorId, usize, usize)],
        budget_bytes: u64,
        policy: CachePolicy,
    ) -> WeightCache {
        let total: u64 = tensor_dims
            .iter()
            .map(|(_, din, dlen)| (din * dlen * 4) as u64)
            .sum();
        let frac = balanced_frac(total, budget_bytes);
        let tensors = tensor_dims
            .iter()
            .map(|&(id, din, dlen)| {
                let cap = (din as f64 * frac).floor() as usize;
                (id, TensorCache::new(din, dlen, cap, policy))
            })
            .collect();
        WeightCache {
            tensors,
            policy,
            budget_bytes,
        }
    }

    pub fn tensor_mut(&mut self, id: TensorId) -> &mut TensorCache {
        self.tensors.get_mut(&id).expect("unknown tensor id")
    }

    pub fn tensor(&self, id: TensorId) -> &TensorCache {
        &self.tensors[&id]
    }

    /// Batched insert for one tensor (see [`TensorCache::insert_rows`]).
    pub fn insert_rows<'a, I>(&mut self, id: TensorId, rows: I) -> usize
    where
        I: IntoIterator<Item = (usize, &'a [f32])>,
    {
        self.tensor_mut(id).insert_rows(rows)
    }

    pub fn reset_context(&mut self) {
        for t in self.tensors.values_mut() {
            t.reset_context();
        }
    }

    pub fn reset_stats(&mut self) {
        for t in self.tensors.values_mut() {
            t.reset_stats();
        }
    }

    /// Aggregate hit rate across all tensors.
    pub fn hit_rate(&self) -> f64 {
        let (mut h, mut m) = (0u64, 0u64);
        for t in self.tensors.values() {
            h += t.hits;
            m += t.misses;
        }
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Actual allocated bytes (≤ budget).
    pub fn bytes(&self) -> u64 {
        self.tensors.values().map(|t| t.bytes()).sum()
    }

    /// Re-budget the whole cache to `budget_bytes` (runtime DRAM
    /// governor): the byte budget is re-split proportionally so every
    /// tensor keeps caching the same *fraction* of its channels (§4.2
    /// balanced split), then each [`TensorCache`] resizes in place —
    /// shrink evicts its coldest rows, grow preserves everything.
    /// Returns total evicted rows.
    pub fn resize(&mut self, budget_bytes: u64) -> u64 {
        let total: u64 = self
            .tensors
            .values()
            .map(|t| (t.d_in * t.row_len * 4) as u64)
            .sum();
        let frac = balanced_frac(total, budget_bytes);
        let mut evicted = 0u64;
        for t in self.tensors.values_mut() {
            let cap = (t.d_in as f64 * frac).floor() as usize;
            evicted += t.resize(cap) as u64;
        }
        self.budget_bytes = budget_bytes;
        evicted
    }
}

/// Thread-shared handle to the weight cache: the mutex plus an acquisition
/// counter. Only the engine thread ever locks it — the loader works from
/// pre-filtered preload jobs and never touches the cache. The decode hot
/// path is budgeted at **one** acquisition per op-family fetch
/// (`engine::fetch_packed` classifies, copies, and batch-inserts under a
/// single guard) plus one brief containment-only acquisition per preload
/// site (`engine::issue_preload`). Every `lock()` bumps the counter, so
/// `rust/tests/engine_golden.rs` can assert the whole-engine acquisition
/// count from the outside — a re-lock smuggled into the fetch path shows
/// up there even if the self-reported `DecodeMetrics::cache_lock_acquires`
/// is not bumped.
pub struct SharedCache {
    inner: Mutex<WeightCache>,
    acquires: AtomicU64,
}

impl SharedCache {
    pub fn new(cache: WeightCache) -> Arc<SharedCache> {
        Arc::new(SharedCache {
            inner: Mutex::new(cache),
            acquires: AtomicU64::new(0),
        })
    }

    /// Acquire the cache mutex (counted).
    pub fn lock(&self) -> MutexGuard<'_, WeightCache> {
        self.acquires.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap()
    }

    /// Total acquisitions since construction (all threads).
    pub fn lock_acquires(&self) -> u64 {
        self.acquires.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::OpKind;
    use crate::util::prop::{check, GenExt};

    fn tc(cap: usize) -> TensorCache {
        TensorCache::new(8, 4, cap, CachePolicy::Contextual)
    }

    fn row(v: f32) -> Vec<f32> {
        vec![v; 4]
    }

    #[test]
    fn paper_fig12_walkthrough() {
        // 8 channels, capacity 4; channel 0 pre-cached.
        let mut c = tc(4);
        c.insert(0, &row(0.0));
        c.counts.fill(0);

        // token 1 activates {0,1,4,6}: 0 hits, 1/4/6 miss then load+insert.
        let mut hits = 0;
        for ch in [0usize, 1, 4, 6] {
            if c.lookup(ch).is_some() {
                hits += 1;
            } else {
                c.insert(ch, &row(ch as f32));
            }
        }
        assert_eq!(hits, 1); // 25% hit ratio, as in the paper's example

        // token 2 activates {0,4,6,7}: 0/4/6 hit, 7 misses; 1 has the lowest
        // count and gets evicted for 7.
        let mut hits = 0;
        for ch in [0usize, 4, 6, 7] {
            if c.lookup(ch).is_some() {
                hits += 1;
            } else {
                assert!(c.insert(ch, &row(ch as f32)), "7 should evict 1");
            }
        }
        assert_eq!(hits, 3); // 75%
        assert!(!c.contains(1));
        assert!(c.contains(7));
    }

    #[test]
    fn lookup_returns_inserted_row() {
        let mut c = tc(2);
        c.lookup(3); // count++
        c.insert(3, &row(9.0));
        assert_eq!(c.lookup(3).unwrap(), &[9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn insert_respects_lfu_rule() {
        let mut c = tc(1);
        c.lookup(0);
        c.lookup(0); // count(0) = 2
        c.insert(0, &row(0.0));
        c.lookup(1); // count(1) = 1 < 2 -> no eviction
        assert!(!c.insert(1, &row(1.0)));
        assert!(c.contains(0));
        c.lookup(1);
        c.lookup(1); // count(1) = 3 > 2 -> evicts
        assert!(c.insert(1, &row(1.0)));
        assert!(!c.contains(0));
    }

    #[test]
    fn capacity_never_exceeded_property() {
        check("cache-capacity", |g| {
            let d = g.usize_in(4, 64);
            let cap = g.usize_in(0, d);
            let mut c =
                TensorCache::new(d, 2, cap, CachePolicy::Contextual);
            for _ in 0..500 {
                let ch = g.usize_in(0, d - 1);
                if c.lookup(ch).is_none() {
                    c.insert(ch, &[ch as f32, 0.0]);
                }
                if c.resident_channels() > cap {
                    return Err("capacity exceeded".into());
                }
            }
            // accounting consistency
            if c.hits + c.misses != 500 {
                return Err("hit+miss != lookups".into());
            }
            Ok(())
        });
    }

    #[test]
    fn cached_row_contents_stay_correct_property() {
        check("cache-contents", |g| {
            let d = g.usize_in(4, 32);
            let cap = g.usize_in(1, d);
            let mut c =
                TensorCache::new(d, 2, cap, CachePolicy::Contextual);
            for _ in 0..300 {
                let ch = g.usize_in(0, d - 1);
                match c.lookup(ch) {
                    Some(r) => {
                        if r != [ch as f32, (ch * 2) as f32] {
                            return Err(format!("channel {ch} corrupt: {r:?}"));
                        }
                    }
                    None => {
                        c.insert(ch, &[ch as f32, (ch * 2) as f32]);
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn task_static_never_evicts() {
        let mut c = TensorCache::new(8, 2, 2, CachePolicy::TaskStatic);
        c.insert(0, &[0.0, 0.0]);
        c.insert(1, &[1.0, 1.0]);
        for ch in 2..8 {
            c.lookup(ch);
            c.lookup(ch);
            c.lookup(ch);
            assert!(!c.insert(ch, &[9.0, 9.0]));
        }
        assert!(c.contains(0) && c.contains(1));
    }

    #[test]
    fn context_reset_zeroes_counts_keeps_contents() {
        let mut c = tc(2);
        c.lookup(5);
        c.insert(5, &row(5.0));
        c.reset_context();
        assert_eq!(c.counts[5], 0);
        assert!(c.contains(5));
    }

    #[test]
    fn weight_cache_budget_split() {
        let dims = vec![
            (TensorId::new(0, OpKind::Wq), 128usize, 128usize),
            (TensorId::new(0, OpKind::Wg), 128, 384),
        ];
        let total_bytes: u64 = dims
            .iter()
            .map(|(_, a, b)| (a * b * 4) as u64)
            .sum();
        let wc = WeightCache::new(&dims, total_bytes / 2, CachePolicy::Contextual);
        // both tensors cache ~half their channels
        for (id, din, _) in &dims {
            let cap = wc.tensor(*id).capacity;
            assert!(
                (cap as f64 - *din as f64 / 2.0).abs() <= 1.0,
                "cap {cap} not ~{}",
                din / 2
            );
        }
        assert!(wc.bytes() <= total_bytes / 2 + 16);
    }

    #[test]
    fn weight_cache_budget_exceeding_size_caps_at_full() {
        let dims = vec![(TensorId::new(0, OpKind::Wq), 16usize, 4usize)];
        let wc = WeightCache::new(&dims, u64::MAX, CachePolicy::Contextual);
        assert_eq!(wc.tensor(dims[0].0).capacity, 16);
    }

    #[test]
    fn insert_rows_matches_per_row_inserts() {
        check("insert-rows-batched", |g| {
            let d = g.usize_in(4, 48);
            let cap = g.usize_in(0, d);
            let mut a = TensorCache::new(d, 2, cap, CachePolicy::Contextual);
            let mut b = TensorCache::new(d, 2, cap, CachePolicy::Contextual);
            for _ in 0..20 {
                // identical lookup history drives identical LFU state
                let touched: Vec<usize> =
                    (0..g.usize_in(1, 8)).map(|_| g.usize_in(0, d - 1)).collect();
                for &ch in &touched {
                    a.lookup(ch);
                    b.lookup(ch);
                }
                let rows: Vec<(usize, Vec<f32>)> = touched
                    .iter()
                    .map(|&ch| (ch, vec![ch as f32, (ch * 3) as f32]))
                    .collect();
                let batched = a.insert_rows(
                    rows.iter().map(|(ch, r)| (*ch, r.as_slice())),
                );
                let mut single = 0usize;
                for (ch, r) in &rows {
                    if b.insert(*ch, r) {
                        single += 1;
                    }
                }
                if batched != single {
                    return Err(format!("admitted {batched} != {single}"));
                }
                for ch in 0..d {
                    if a.contains(ch) != b.contains(ch) {
                        return Err(format!("residency diverged at {ch}"));
                    }
                    if a.contains(ch) && a.peek(ch) != b.peek(ch) {
                        return Err(format!("contents diverged at {ch}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn resize_shrink_evicts_cold_rows_keeps_survivors_intact() {
        // Property (governor correctness): after a shrink the cache holds
        // ≤ target rows, every surviving row is bit-identical to the
        // reference content, and no evicted channel out-counts a survivor.
        check("cache-resize-shrink", |g| {
            let d = g.usize_in(4, 48);
            let cap = g.usize_in(1, d);
            let mut c = TensorCache::new(d, 2, cap, CachePolicy::Contextual);
            let refrow = |ch: usize| [ch as f32, (ch * 7) as f32];
            for _ in 0..g.usize_in(10, 300) {
                let ch = g.usize_in(0, d - 1);
                if c.lookup(ch).is_none() {
                    c.insert(ch, &refrow(ch));
                }
            }
            let new_cap = g.usize_in(0, d);
            let before: Vec<usize> =
                (0..d).filter(|&ch| c.contains(ch)).collect();
            let evicted = c.resize(new_cap);
            let after: Vec<usize> =
                (0..d).filter(|&ch| c.contains(ch)).collect();
            if c.resident_channels() > new_cap {
                return Err("residents exceed new capacity".into());
            }
            if c.bytes() != (new_cap.min(d) * 2 * 4) as u64 {
                return Err("allocated bytes != new capacity".into());
            }
            if evicted != before.len() - after.len() {
                return Err("evicted count wrong".into());
            }
            for &ch in &after {
                if !before.contains(&ch) {
                    return Err(format!("resize invented channel {ch}"));
                }
                if c.peek(ch) != Some(&refrow(ch)[..]) {
                    return Err(format!("survivor {ch} corrupted"));
                }
            }
            // LFU discipline: survivors out-count (or tie) every evictee
            let min_kept =
                after.iter().map(|&ch| c.count_of(ch)).min().unwrap_or(0);
            for &ch in before.iter().filter(|ch| !after.contains(ch)) {
                if c.count_of(ch) > min_kept {
                    return Err(format!(
                        "evicted hot channel {ch} over a colder survivor"
                    ));
                }
            }
            // the shrunk cache keeps working: lookups + inserts stay sane
            for _ in 0..20 {
                let ch = g.usize_in(0, d - 1);
                match c.lookup(ch) {
                    Some(r) => {
                        if r != refrow(ch) {
                            return Err(format!("post-resize {ch} corrupt"));
                        }
                    }
                    None => {
                        c.insert(ch, &refrow(ch));
                    }
                }
                if c.resident_channels() > new_cap {
                    return Err("post-resize capacity exceeded".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn resize_grow_preserves_contents() {
        let mut c = tc(2);
        c.lookup(1);
        c.insert(1, &row(1.0));
        c.lookup(5);
        c.insert(5, &row(5.0));
        assert_eq!(c.resize(6), 0);
        assert_eq!(c.capacity, 6);
        assert_eq!(c.peek(1).unwrap(), &row(1.0)[..]);
        assert_eq!(c.peek(5).unwrap(), &row(5.0)[..]);
        // new headroom is usable
        c.lookup(3);
        assert!(c.insert(3, &row(3.0)));
        assert_eq!(c.resident_channels(), 3);
    }

    #[test]
    fn weight_cache_resize_rebalances_budget() {
        let dims = vec![
            (TensorId::new(0, OpKind::Wq), 128usize, 128usize),
            (TensorId::new(0, OpKind::Wg), 128, 384),
        ];
        let total: u64 =
            dims.iter().map(|(_, a, b)| (a * b * 4) as u64).sum();
        let mut wc = WeightCache::new(&dims, total, CachePolicy::Contextual);
        // warm every channel of both tensors
        for (id, din, dlen) in &dims {
            let row = vec![1.0f32; *dlen];
            let t = wc.tensor_mut(*id);
            for ch in 0..*din {
                t.lookup(ch);
                t.insert(ch, &row);
            }
        }
        assert_eq!(wc.bytes(), total);
        let evicted = wc.resize(total / 4);
        assert!(wc.bytes() <= total / 4, "{} > {}", wc.bytes(), total / 4);
        assert_eq!(wc.budget_bytes, total / 4);
        // both tensors keep ~a quarter of their channels (balanced split)
        for (id, din, _) in &dims {
            let cap = wc.tensor(*id).capacity;
            assert!(
                (cap as f64 - *din as f64 / 4.0).abs() <= 1.0,
                "cap {cap} not ~{}",
                din / 4
            );
        }
        assert_eq!(evicted as usize, 2 * 128 - 2 * 32);
    }

    #[test]
    fn packed_rows_is_the_full_matrix_after_bulk_fill() {
        // dense-baseline contract: channel-order fill at full capacity
        // makes the store the whole [d_in, row_len] matrix in order
        let mut c = TensorCache::new(4, 2, 4, CachePolicy::TaskStatic);
        for ch in 0..4 {
            c.insert(ch, &[ch as f32, ch as f32 + 0.5]);
        }
        assert_eq!(
            c.packed_rows(),
            &[0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5]
        );
    }

    #[test]
    fn shared_cache_counts_acquisitions() {
        let dims = vec![(TensorId::new(0, OpKind::Wq), 8usize, 2usize)];
        let shared = SharedCache::new(WeightCache::new(
            &dims,
            u64::MAX,
            CachePolicy::Contextual,
        ));
        assert_eq!(shared.lock_acquires(), 0);
        {
            let mut c = shared.lock();
            // a full batched fetch path — lookups + inserts — is one
            // acquisition no matter how many rows move
            let t = c.tensor_mut(dims[0].0);
            for ch in 0..8 {
                t.lookup(ch);
            }
            let rows: Vec<(usize, Vec<f32>)> =
                (0..8).map(|ch| (ch, vec![ch as f32; 2])).collect();
            t.insert_rows(rows.iter().map(|(ch, r)| (*ch, r.as_slice())));
        }
        assert_eq!(shared.lock_acquires(), 1);
        shared.lock();
        assert_eq!(shared.lock_acquires(), 2);
    }

}
