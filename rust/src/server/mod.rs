//! Serving front-end: a TCP line-JSON server with a FIFO admission queue in
//! front of one decode engine.
//!
//! On-device engines decode one sequence at a time (the paper's setting —
//! decode is memory-bandwidth-bound, so batching buys nothing on a phone);
//! the "batcher" therefore multiplexes *requests*, tracking queueing vs
//! decode latency separately, and exposes the elastic-memory controls
//! (`set_budget` re-runs the §4.1 search and reports the parameters the
//! engine would adopt).
//!
//! Protocol: one JSON object per line.
//!   {"prompt": "...", "n_tokens": 32, "temp": 0.0}
//!   {"cmd": "stats"}
//!   {"cmd": "set_budget", "bytes": 1200000000}
//!   {"cmd": "shutdown"}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::costmodel;
use crate::engine::{EngineOptions, SwapEngine};
use crate::layout::AwgfFile;
use crate::metrics;
use crate::tokenizer;
use crate::util::json::{self, arr, num, obj, s, Value};

pub struct ServerConfig {
    pub addr: String,
    pub artifact_dir: PathBuf,
    pub opts: EngineOptions,
}

struct Request {
    prompt: Vec<u32>,
    n_tokens: usize,
    temp: f32,
    enqueued: Instant,
    resp: Sender<Value>,
}

enum Job {
    Decode(Request),
    Stop,
}

#[derive(Default)]
struct ServerStats {
    served: AtomicU64,
    tokens: AtomicU64,
    queue_ns: AtomicU64,
    decode_ns: AtomicU64,
    // hot-path counters mirrored out of DecodeMetrics (PERF.md): the
    // engine lives on the worker thread, so `stats` connections read these
    // atomics instead of poking the engine
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    lock_acquires: AtomicU64,
    locks_avoided: AtomicU64,
    batched_inserts: AtomicU64,
    ondemand_rows: AtomicU64,
    ondemand_coalesced_runs: AtomicU64,
    slab_bytes_peak: AtomicU64,
}

/// Run the server until a `shutdown` command arrives. Returns the number of
/// requests served.
pub fn serve(cfg: ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    eprintln!("[server] listening on {}", cfg.addr);

    let (job_tx, job_rx) = channel::<Job>();
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));

    // ---- engine worker: owns the SwapEngine, drains the queue FIFO.
    let worker_stats = stats.clone();
    let artifact_dir = cfg.artifact_dir.clone();
    let opts_device = cfg.opts.device;
    let worker = std::thread::spawn(move || -> Result<()> {
        let mut engine = SwapEngine::open(&artifact_dir, cfg.opts)?;
        eprintln!(
            "[server] engine ready: model={} level={} device={}",
            engine.model().name,
            engine.sparsity_tag(),
            opts_device.name
        );
        while let Ok(job) = job_rx.recv() {
            let req = match job {
                Job::Stop => break,
                Job::Decode(r) => r,
            };
            let queue_t = req.enqueued.elapsed();
            let t0 = Instant::now();
            let before = engine.metrics.clone();
            let result = engine.generate(&req.prompt, req.n_tokens, req.temp);
            let decode_t = t0.elapsed();
            let resp = match result {
                Err(e) => obj(vec![("error", s(&format!("{e:#}")))]),
                Ok(toks) => {
                    let m = &engine.metrics;
                    let delta_tokens = m.tokens - before.tokens;
                    worker_stats.served.fetch_add(1, Ordering::Relaxed);
                    worker_stats
                        .tokens
                        .fetch_add(delta_tokens, Ordering::Relaxed);
                    worker_stats.cache_hits.fetch_add(
                        m.cache_hits - before.cache_hits,
                        Ordering::Relaxed,
                    );
                    worker_stats.cache_misses.fetch_add(
                        m.cache_misses - before.cache_misses,
                        Ordering::Relaxed,
                    );
                    worker_stats.lock_acquires.fetch_add(
                        m.cache_lock_acquires - before.cache_lock_acquires,
                        Ordering::Relaxed,
                    );
                    worker_stats.locks_avoided.fetch_add(
                        m.cache_locks_avoided - before.cache_locks_avoided,
                        Ordering::Relaxed,
                    );
                    worker_stats.batched_inserts.fetch_add(
                        m.batched_inserts - before.batched_inserts,
                        Ordering::Relaxed,
                    );
                    worker_stats.ondemand_rows.fetch_add(
                        m.ondemand_rows - before.ondemand_rows,
                        Ordering::Relaxed,
                    );
                    worker_stats.ondemand_coalesced_runs.fetch_add(
                        m.ondemand_coalesced_runs
                            - before.ondemand_coalesced_runs,
                        Ordering::Relaxed,
                    );
                    worker_stats
                        .slab_bytes_peak
                        .fetch_max(m.slab_bytes_peak, Ordering::Relaxed);
                    worker_stats.queue_ns.fetch_add(
                        queue_t.as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    worker_stats.decode_ns.fetch_add(
                        decode_t.as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    obj(vec![
                        ("text", s(&tokenizer::decode(&toks))),
                        (
                            "tokens",
                            arr(toks.iter().map(|&t| num(t as f64)).collect()),
                        ),
                        ("queue_ms", num(queue_t.as_secs_f64() * 1e3)),
                        ("decode_ms", num(decode_t.as_secs_f64() * 1e3)),
                        (
                            "toks_per_sec",
                            num(req.n_tokens as f64
                                / decode_t.as_secs_f64().max(1e-9)),
                        ),
                        ("cache_hit_rate", num(engine.cache_hit_rate())),
                    ])
                }
            };
            let _ = req.resp.send(resp);
        }
        Ok(())
    });

    // ---- accept loop
    for conn in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let conn = match conn {
            Ok(c) => c,
            Err(_) => continue,
        };
        let job_tx = job_tx.clone();
        let stats = stats.clone();
        let stop2 = stop.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(conn, job_tx, stats, stop2, &artifact_dir,
                                opts_device);
        });
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    let _ = job_tx.send(Job::Stop);
    let _ = worker.join();
    Ok(stats.served.load(Ordering::Relaxed))
}

fn handle_conn(
    conn: TcpStream,
    job_tx: Sender<Job>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    artifact_dir: &std::path::Path,
    device: &'static crate::device::DeviceProfile,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                respond(&mut writer,
                        &obj(vec![("error", s(&format!("bad json: {e}")))]))?;
                continue;
            }
        };
        match req.get("cmd").and_then(Value::as_str) {
            Some("stats") => {
                let served = stats.served.load(Ordering::Relaxed);
                let tokens = stats.tokens.load(Ordering::Relaxed);
                let dec_ns = stats.decode_ns.load(Ordering::Relaxed);
                respond(
                    &mut writer,
                    &obj(vec![
                        ("served", num(served as f64)),
                        ("tokens", num(tokens as f64)),
                        (
                            "avg_queue_ms",
                            num(stats.queue_ns.load(Ordering::Relaxed) as f64
                                / 1e6
                                / served.max(1) as f64),
                        ),
                        (
                            "throughput_toks_per_sec",
                            num(tokens as f64 / (dec_ns as f64 / 1e9).max(1e-9)),
                        ),
                        (
                            "cache_hit_rate",
                            num({
                                let h = stats
                                    .cache_hits
                                    .load(Ordering::Relaxed)
                                    as f64;
                                let mi = stats
                                    .cache_misses
                                    .load(Ordering::Relaxed)
                                    as f64;
                                if h + mi == 0.0 { 0.0 } else { h / (h + mi) }
                            }),
                        ),
                        (
                            "cache_lock_acquires",
                            num(stats.lock_acquires.load(Ordering::Relaxed)
                                as f64),
                        ),
                        (
                            "cache_locks_avoided",
                            num(stats.locks_avoided.load(Ordering::Relaxed)
                                as f64),
                        ),
                        (
                            "batched_inserts",
                            num(stats.batched_inserts.load(Ordering::Relaxed)
                                as f64),
                        ),
                        (
                            "ondemand_rows",
                            num(stats.ondemand_rows.load(Ordering::Relaxed)
                                as f64),
                        ),
                        (
                            "ondemand_coalesced_runs",
                            num(stats
                                .ondemand_coalesced_runs
                                .load(Ordering::Relaxed)
                                as f64),
                        ),
                        (
                            "slab_bytes_peak",
                            num(stats.slab_bytes_peak.load(Ordering::Relaxed)
                                as f64),
                        ),
                    ]),
                )?;
            }
            Some("set_budget") => {
                // Elastic memory: re-run the §4.1 search for the new budget
                // and report the configuration the engine adopts on reload.
                let budget =
                    req.get("bytes").and_then(Value::as_f64).unwrap_or(0.0)
                        as u64;
                let awgf = AwgfFile::open(
                    &crate::config::ArtifactConfig::load(artifact_dir)?
                        .weights_file,
                )?;
                let geo = costmodel::Geometry::from_awgf(&awgf);
                let grid = [0.5, 0.6, 0.7, 0.8, 0.9];
                let resp = match costmodel::search(device, &geo, budget, 0.85,
                                                   1.0, &grid) {
                    None => obj(vec![(
                        "error",
                        s("budget below minimum servable configuration"),
                    )]),
                    Some(r) => obj(vec![
                        ("sparsity", num(r.params.sp)),
                        ("group_size", num(r.params.n_group as f64)),
                        ("cache_bytes", num(r.params.cache_bytes as f64)),
                        ("pred_mem_bytes", num(r.cost.mem_bytes as f64)),
                        ("pred_decode_ms", num(r.cost.t_decode * 1e3)),
                    ]),
                };
                respond(&mut writer, &resp)?;
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                respond(&mut writer, &obj(vec![("ok", Value::Bool(true))]))?;
                // poke the accept loop
                let _ = TcpStream::connect(
                    conn_addr(&writer).unwrap_or("127.0.0.1:0".into()),
                );
                break;
            }
            _ => {
                let prompt = tokenizer::encode(
                    req.get("prompt").and_then(Value::as_str).unwrap_or(" "),
                );
                let n_tokens = req
                    .get("n_tokens")
                    .and_then(Value::as_usize)
                    .unwrap_or(32);
                let temp = req
                    .get("temp")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0) as f32;
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Decode(Request {
                    prompt,
                    n_tokens,
                    temp,
                    enqueued: Instant::now(),
                    resp: tx,
                }));
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
        }
    }
    Ok(())
}

fn conn_addr(stream: &TcpStream) -> Option<String> {
    stream.local_addr().ok().map(|a| a.to_string())
}

fn respond(w: &mut TcpStream, v: &Value) -> Result<()> {
    let mut line = v.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    Ok(())
}

/// Client helper (examples + tests): send one request, read one response.
pub fn client_roundtrip(addr: &str, request: &Value) -> Result<Value> {
    let mut conn = TcpStream::connect(addr)?;
    let mut line = request.to_string();
    line.push('\n');
    conn.write_all(line.as_bytes())?;
    let mut reader = BufReader::new(conn);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    json::parse(resp.trim())
}

/// Energy summary helper reused by the CLI.
pub fn energy_summary(
    dev: &crate::device::DeviceProfile,
    m: &crate::metrics::DecodeMetrics,
) -> metrics::EnergyReport {
    metrics::energy(dev, m)
}
