//! Serving front-end: a TCP line-JSON server with a FIFO admission queue in
//! front of one decode engine.
//!
//! On-device engines decode one sequence at a time (the paper's setting —
//! decode is memory-bandwidth-bound, so batching buys nothing on a phone);
//! the "batcher" therefore multiplexes *requests*, tracking queueing vs
//! decode latency separately.
//!
//! The elastic-memory control (`set_budget`) is **live**: the worker
//! thread owns a [`DramGovernor`] next to the engine, so a budget change
//! re-runs the §4.1 search online and applies `(sp, N, cache)` to the
//! running engine — cache eviction to the new target, preload-depth and
//! slab-cap retune, sparsity-level artifact switch — between requests,
//! with no restart. Ledger totals and re-budget decisions surface in
//! `stats`.
//!
//! Protocol: one JSON object per line.
//!   {"prompt": "...", "n_tokens": 32, "temp": 0.0}
//!   {"cmd": "stats"}
//!   {"cmd": "set_budget", "bytes": 1200000000}
//!   {"cmd": "shutdown"}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::engine::{EngineOptions, SwapEngine};
use crate::governor::{
    DramGovernor, GovernorConfig, PressureSchedule, RebudgetTrigger,
};
use crate::metrics;
use crate::tokenizer;
use crate::util::json::{self, arr, num, obj, s, Value};

pub struct ServerConfig {
    pub addr: String,
    pub artifact_dir: PathBuf,
    pub opts: EngineOptions,
    /// Governor knobs (hysteresis, search grid) — see
    /// [`GovernorConfig::from_runtime`].
    pub governor: GovernorConfig,
    /// Apply this DRAM budget at startup (otherwise the governor assumes
    /// the device's physical DRAM until the first `set_budget`).
    pub initial_budget: Option<u64>,
    /// Scripted pressure trace (`"<size>@<token>,..."`): the worker fires
    /// each step between requests once the served-token count passes it —
    /// the same path a `set_budget` command takes.
    pub pressure_schedule: Option<String>,
}

struct Request {
    prompt: Vec<u32>,
    n_tokens: usize,
    temp: f32,
    enqueued: Instant,
    resp: Sender<Value>,
}

enum Job {
    Decode(Request),
    /// Live re-budget: the worker runs the governor against its engine
    /// between requests and answers with the decision.
    Rebudget { bytes: u64, resp: Sender<Value> },
    Stop,
}

#[derive(Default)]
struct ServerStats {
    served: AtomicU64,
    tokens: AtomicU64,
    queue_ns: AtomicU64,
    decode_ns: AtomicU64,
    // hot-path counters mirrored out of DecodeMetrics (PERF.md): the
    // engine lives on the worker thread, so `stats` connections read these
    // atomics instead of poking the engine
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    lock_acquires: AtomicU64,
    locks_avoided: AtomicU64,
    batched_inserts: AtomicU64,
    ondemand_rows: AtomicU64,
    ondemand_coalesced_runs: AtomicU64,
    slab_bytes_peak: AtomicU64,
    // async read-queue mirror (shared ReadQueue, PERF.md)
    io_batches: AtomicU64,
    io_inflight_peak: AtomicU64,
    io_wait_us: AtomicU64,
    /// Loader parts that failed to load (read/planning errors); waiters
    /// fell back to on-demand. Non-zero here means the flash file or the
    /// preload requests are broken — previously only visible on stderr.
    parts_failed: AtomicU64,
    // runtime DRAM governor mirror: budget, pool ledger, decision counters
    budget_bytes: AtomicU64,
    ledger_cache_bytes: AtomicU64,
    ledger_preload_bytes: AtomicU64,
    ledger_compute_bytes: AtomicU64,
    rebudgets_applied: AtomicU64,
    rebudgets_skipped: AtomicU64,
    rebudget_rows_evicted: AtomicU64,
    level_switches: AtomicU64,
    last_settle_us: AtomicU64,
}

impl ServerStats {
    /// Refresh the governor mirror from the worker-side engine state.
    fn publish_governor(&self, engine: &SwapEngine, gov: &DramGovernor) {
        let ledger = engine.pool_ledger();
        self.budget_bytes.store(gov.budget(), Ordering::Relaxed);
        self.ledger_cache_bytes
            .store(ledger.cache_bytes, Ordering::Relaxed);
        self.ledger_preload_bytes
            .store(ledger.preload_bytes, Ordering::Relaxed);
        self.ledger_compute_bytes
            .store(ledger.compute_bytes, Ordering::Relaxed);
        let m = &engine.metrics;
        self.rebudgets_applied
            .store(m.rebudgets_applied, Ordering::Relaxed);
        self.rebudgets_skipped
            .store(m.rebudgets_skipped, Ordering::Relaxed);
        self.rebudget_rows_evicted
            .store(m.rebudget_rows_evicted, Ordering::Relaxed);
        self.level_switches
            .store(m.level_switches, Ordering::Relaxed);
        if let Some(d) = gov.last_decision() {
            self.last_settle_us
                .store(d.settle.as_micros() as u64, Ordering::Relaxed);
        }
    }
}

/// Run the server until a `shutdown` command arrives. Returns the number of
/// requests served.
pub fn serve(cfg: ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    eprintln!("[server] listening on {}", cfg.addr);

    let (job_tx, job_rx) = channel::<Job>();
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));

    // ---- engine worker: owns the SwapEngine + DramGovernor, drains FIFO.
    let worker_stats = stats.clone();
    let artifact_dir = cfg.artifact_dir.clone();
    let opts_device = cfg.opts.device;
    let initial_budget = cfg.initial_budget;
    let governor_cfg = cfg.governor.clone();
    let mut schedule = match &cfg.pressure_schedule {
        Some(spec) => Some(PressureSchedule::parse(spec)?),
        None => None,
    };
    let worker = std::thread::spawn(move || -> Result<()> {
        let mut engine = SwapEngine::open(&artifact_dir, cfg.opts)?;
        let mut gov = DramGovernor::new(
            &engine,
            governor_cfg,
            opts_device.dram_bytes,
        );
        let mut served_tokens = 0u64;
        if let Some(budget) = initial_budget {
            let d = gov.set_budget(&mut engine, budget,
                                   RebudgetTrigger::Command)?;
            eprintln!(
                "[server] initial budget {}: sp={:.2} N={} cache={} ({})",
                budget, d.new_sp, d.new_group, d.cache_target, d.note
            );
        }
        worker_stats.publish_governor(&engine, &gov);
        eprintln!(
            "[server] engine ready: model={} level={} device={}",
            engine.model().name,
            engine.sparsity_tag(),
            opts_device.name
        );
        while let Ok(job) = job_rx.recv() {
            let req = match job {
                Job::Stop => break,
                Job::Rebudget { bytes, resp } => {
                    let v = match gov.set_budget(&mut engine, bytes,
                                                 RebudgetTrigger::Command) {
                        Err(e) => obj(vec![("error", s(&format!("{e:#}")))]),
                        Ok(d) if d.note == "infeasible" => obj(vec![(
                            "error",
                            s("budget below minimum servable configuration"),
                        )]),
                        Ok(d) => obj(vec![
                            ("applied", Value::Bool(d.applied)),
                            ("note", s(d.note)),
                            ("sparsity", num(d.new_sp)),
                            ("group_size", num(d.new_group as f64)),
                            ("cache_bytes", num(d.cache_target as f64)),
                            ("slab_cap_bytes", num(d.slab_cap as f64)),
                            ("evicted_rows", num(d.evicted_rows as f64)),
                            (
                                "settle_ms",
                                num(d.settle.as_secs_f64() * 1e3),
                            ),
                            (
                                "ledger_cache_bytes",
                                num(d.new_pools.cache_bytes as f64),
                            ),
                            (
                                "ledger_preload_bytes",
                                num(d.new_pools.preload_bytes as f64),
                            ),
                            (
                                "ledger_compute_bytes",
                                num(d.new_pools.compute_bytes as f64),
                            ),
                        ]),
                    };
                    worker_stats.publish_governor(&engine, &gov);
                    let _ = resp.send(v);
                    continue;
                }
                Job::Decode(r) => r,
            };
            let queue_t = req.enqueued.elapsed();
            let t0 = Instant::now();
            let before = engine.metrics.clone();
            let result = engine.generate(&req.prompt, req.n_tokens, req.temp);
            let decode_t = t0.elapsed();
            {
                // published on BOTH result paths: loader failures are the
                // likeliest cause of a failed decode, so the visibility
                // counters must not go stale exactly when things break
                let m = &engine.metrics;
                worker_stats.io_batches.fetch_add(
                    m.io_batches - before.io_batches,
                    Ordering::Relaxed,
                );
                worker_stats
                    .io_inflight_peak
                    .fetch_max(m.io_inflight_peak, Ordering::Relaxed);
                worker_stats.io_wait_us.fetch_add(
                    (m.io_wait - before.io_wait).as_micros() as u64,
                    Ordering::Relaxed,
                );
                worker_stats.parts_failed.store(
                    engine.loader_stats().parts_failed,
                    Ordering::Relaxed,
                );
            }
            let resp = match result {
                Err(e) => obj(vec![("error", s(&format!("{e:#}")))]),
                Ok(toks) => {
                    let m = &engine.metrics;
                    let delta_tokens = m.tokens - before.tokens;
                    worker_stats.served.fetch_add(1, Ordering::Relaxed);
                    worker_stats
                        .tokens
                        .fetch_add(delta_tokens, Ordering::Relaxed);
                    worker_stats.cache_hits.fetch_add(
                        m.cache_hits - before.cache_hits,
                        Ordering::Relaxed,
                    );
                    worker_stats.cache_misses.fetch_add(
                        m.cache_misses - before.cache_misses,
                        Ordering::Relaxed,
                    );
                    worker_stats.lock_acquires.fetch_add(
                        m.cache_lock_acquires - before.cache_lock_acquires,
                        Ordering::Relaxed,
                    );
                    worker_stats.locks_avoided.fetch_add(
                        m.cache_locks_avoided - before.cache_locks_avoided,
                        Ordering::Relaxed,
                    );
                    worker_stats.batched_inserts.fetch_add(
                        m.batched_inserts - before.batched_inserts,
                        Ordering::Relaxed,
                    );
                    worker_stats.ondemand_rows.fetch_add(
                        m.ondemand_rows - before.ondemand_rows,
                        Ordering::Relaxed,
                    );
                    worker_stats.ondemand_coalesced_runs.fetch_add(
                        m.ondemand_coalesced_runs
                            - before.ondemand_coalesced_runs,
                        Ordering::Relaxed,
                    );
                    worker_stats
                        .slab_bytes_peak
                        .fetch_max(m.slab_bytes_peak, Ordering::Relaxed);
                    worker_stats.queue_ns.fetch_add(
                        queue_t.as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    worker_stats.decode_ns.fetch_add(
                        decode_t.as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    worker_stats.publish_governor(&engine, &gov);
                    obj(vec![
                        ("text", s(&tokenizer::decode(&toks))),
                        (
                            "tokens",
                            arr(toks.iter().map(|&t| num(t as f64)).collect()),
                        ),
                        ("queue_ms", num(queue_t.as_secs_f64() * 1e3)),
                        ("decode_ms", num(decode_t.as_secs_f64() * 1e3)),
                        (
                            "toks_per_sec",
                            num(req.n_tokens as f64
                                / decode_t.as_secs_f64().max(1e-9)),
                        ),
                        ("cache_hit_rate", num(engine.cache_hit_rate())),
                    ])
                }
            };
            let _ = req.resp.send(resp);
            // scripted pressure trace: fire due steps between requests,
            // through the same governor path a set_budget command takes
            served_tokens = engine.metrics.tokens.max(served_tokens);
            if let Some(sched) = schedule.as_mut() {
                if let Some(budget) = sched.due(served_tokens) {
                    // a failed step must not take down serving — log and
                    // keep the engine on its previous configuration, the
                    // same degradation a failed set_budget command gets
                    match gov.set_budget(&mut engine, budget,
                                         RebudgetTrigger::Schedule) {
                        Ok(d) => eprintln!(
                            "[server] pressure step -> {} ({}): sp={:.2} \
                             N={} cache={}",
                            budget, d.note, d.new_sp, d.new_group,
                            d.cache_target
                        ),
                        Err(e) => eprintln!(
                            "[server] pressure step failed: {e:#}"
                        ),
                    }
                    worker_stats.publish_governor(&engine, &gov);
                }
            }
        }
        Ok(())
    });

    // ---- accept loop
    for conn in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let conn = match conn {
            Ok(c) => c,
            Err(_) => continue,
        };
        let job_tx = job_tx.clone();
        let stats = stats.clone();
        let stop2 = stop.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(conn, job_tx, stats, stop2);
        });
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    let _ = job_tx.send(Job::Stop);
    let _ = worker.join();
    Ok(stats.served.load(Ordering::Relaxed))
}

fn handle_conn(
    conn: TcpStream,
    job_tx: Sender<Job>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                respond(&mut writer,
                        &obj(vec![("error", s(&format!("bad json: {e}")))]))?;
                continue;
            }
        };
        match req.get("cmd").and_then(Value::as_str) {
            Some("stats") => {
                let served = stats.served.load(Ordering::Relaxed);
                let tokens = stats.tokens.load(Ordering::Relaxed);
                let dec_ns = stats.decode_ns.load(Ordering::Relaxed);
                let g = |a: &AtomicU64| num(a.load(Ordering::Relaxed) as f64);
                respond(
                    &mut writer,
                    &obj(vec![
                        ("served", num(served as f64)),
                        ("tokens", num(tokens as f64)),
                        (
                            "avg_queue_ms",
                            num(stats.queue_ns.load(Ordering::Relaxed) as f64
                                / 1e6
                                / served.max(1) as f64),
                        ),
                        (
                            "throughput_toks_per_sec",
                            num(tokens as f64 / (dec_ns as f64 / 1e9).max(1e-9)),
                        ),
                        (
                            "cache_hit_rate",
                            num({
                                let h = stats
                                    .cache_hits
                                    .load(Ordering::Relaxed)
                                    as f64;
                                let mi = stats
                                    .cache_misses
                                    .load(Ordering::Relaxed)
                                    as f64;
                                if h + mi == 0.0 { 0.0 } else { h / (h + mi) }
                            }),
                        ),
                        ("cache_lock_acquires", g(&stats.lock_acquires)),
                        ("cache_locks_avoided", g(&stats.locks_avoided)),
                        ("batched_inserts", g(&stats.batched_inserts)),
                        ("ondemand_rows", g(&stats.ondemand_rows)),
                        (
                            "ondemand_coalesced_runs",
                            g(&stats.ondemand_coalesced_runs),
                        ),
                        ("slab_bytes_peak", g(&stats.slab_bytes_peak)),
                        // async flash read path (PERF.md)
                        ("io_batches", g(&stats.io_batches)),
                        ("io_inflight_peak", g(&stats.io_inflight_peak)),
                        ("io_wait_us", g(&stats.io_wait_us)),
                        ("parts_failed", g(&stats.parts_failed)),
                        // runtime DRAM governor: budget, pools, decisions
                        ("budget_bytes", g(&stats.budget_bytes)),
                        ("ledger_cache_bytes", g(&stats.ledger_cache_bytes)),
                        (
                            "ledger_preload_bytes",
                            g(&stats.ledger_preload_bytes),
                        ),
                        (
                            "ledger_compute_bytes",
                            g(&stats.ledger_compute_bytes),
                        ),
                        ("rebudgets_applied", g(&stats.rebudgets_applied)),
                        ("rebudgets_skipped", g(&stats.rebudgets_skipped)),
                        (
                            "rebudget_rows_evicted",
                            g(&stats.rebudget_rows_evicted),
                        ),
                        ("level_switches", g(&stats.level_switches)),
                        ("last_settle_us", g(&stats.last_settle_us)),
                    ]),
                )?;
            }
            Some("set_budget") => {
                // Elastic memory, live: the worker re-runs the §4.1
                // search under the new M_max and applies the result to
                // the running engine between requests.
                let bytes =
                    req.get("bytes").and_then(Value::as_f64).unwrap_or(0.0)
                        as u64;
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Rebudget { bytes, resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                respond(&mut writer, &obj(vec![("ok", Value::Bool(true))]))?;
                // poke the accept loop
                let _ = TcpStream::connect(
                    conn_addr(&writer).unwrap_or("127.0.0.1:0".into()),
                );
                break;
            }
            _ => {
                let prompt = tokenizer::encode(
                    req.get("prompt").and_then(Value::as_str).unwrap_or(" "),
                );
                let n_tokens = req
                    .get("n_tokens")
                    .and_then(Value::as_usize)
                    .unwrap_or(32);
                let temp = req
                    .get("temp")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0) as f32;
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Decode(Request {
                    prompt,
                    n_tokens,
                    temp,
                    enqueued: Instant::now(),
                    resp: tx,
                }));
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
        }
    }
    Ok(())
}

fn conn_addr(stream: &TcpStream) -> Option<String> {
    stream.local_addr().ok().map(|a| a.to_string())
}

fn respond(w: &mut TcpStream, v: &Value) -> Result<()> {
    let mut line = v.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    Ok(())
}

/// Client helper (examples + tests): send one request, read one response.
pub fn client_roundtrip(addr: &str, request: &Value) -> Result<Value> {
    let mut conn = TcpStream::connect(addr)?;
    let mut line = request.to_string();
    line.push('\n');
    conn.write_all(line.as_bytes())?;
    let mut reader = BufReader::new(conn);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    json::parse(resp.trim())
}

/// Energy summary helper reused by the CLI.
pub fn energy_summary(
    dev: &crate::device::DeviceProfile,
    m: &crate::metrics::DecodeMetrics,
) -> metrics::EnergyReport {
    metrics::energy(dev, m)
}
