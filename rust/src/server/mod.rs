//! Serving front-end: a TCP line-JSON server with a continuous-batching
//! scheduler in front of one decode engine.
//!
//! The worker used to run one blocking `generate()` per request (FIFO):
//! the swap pipeline only ever served one sequence, and `stats` /
//! `set_budget` starved behind long generations. It now owns a
//! [`Scheduler`] and drives it in **waves** — one token per live sequence
//! per wave, admit-on-arrival, retire-on-EOS/limit — so concurrent
//! requests decode interleaved (their cross-token preload chains keep the
//! flash queue saturated while peers compute) and control jobs are
//! serviced at every wave boundary, which is an inter-token safe point
//! for all live sequences.
//!
//! The elastic-memory control (`set_budget`) is **live** and now applies
//! *mid-generation*: the worker drains control jobs between waves, so a
//! budget change re-runs the §4.1 search online and applies
//! `(sp, N, cache, max_seqs)` to the running engine within one wave —
//! including mid-sequence sparsity-level switches (KV is
//! level-independent) and a shrink of the concurrent-sequence ceiling,
//! which preempts the newest sequences (recompute-on-resume) to free
//! their KV. Ledger totals, re-budget decisions, and the scheduler's
//! counters surface in `stats`.
//!
//! Protocol: one JSON object per line.
//!   {"prompt": "...", "n_tokens": 32, "temp": 0.0}
//!   {"cmd": "stats"}            — counters + p50/p95/p99 latency keys
//!   {"cmd": "stats_reset"}      — zero the cumulative counters/histograms
//!   {"cmd": "set_budget", "bytes": 1200000000}
//!   {"cmd": "trace", "enable": true, "out": "trace.json"}
//!       — flight recorder control: toggle span recording and/or export
//!         the ring as Chrome trace-event JSON (`--trace-out` records
//!         from startup and writes at shutdown). See PERF.md
//!         §Observability.
//!   {"cmd": "journal"}          — the governor's re-budget decision log
//!   {"cmd": "health"}           — recovery-ladder + telemetry-drop verdict
//!   {"cmd": "metrics"}          — Prometheus text exposition of the full
//!       counter registry + log2 histograms (cumulative `le` buckets)
//!   {"cmd": "subscribe", "interval_ms": 250}
//!       — upgrade this connection into a push stream: sequence-numbered
//!         frames of span deltas (drained from the flight-recorder ring)
//!         plus a stats snapshot, one JSON object per line, until the
//!         client disconnects. A slow reader drops frames (bounded
//!         per-subscriber queue, counted in `frames_dropped`) — the
//!         decode hot path never blocks on a subscriber. See PERF.md
//!         §Live telemetry.
//!   {"cmd": "shutdown"}
//!
//! Decode requests may carry `"client": "<name>"` — the engine keys its
//! per-client ended-sequence-length histograms (expected-occupancy
//! signal, surfaced as `client_p90` in `stats` and in the governor's
//! decision journal) by it, and the reply's span context inherits it.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{EngineOptions, SwapEngine};
use crate::governor::{
    DramGovernor, GovernorConfig, PressureSchedule, RebudgetTrigger,
};
use crate::metrics;
use crate::metrics::DecodeMetrics;
use crate::sched::{
    SchedConfig, SchedStats, Scheduler, SeqRequest, SubmitOutcome,
};
use crate::tokenizer;
use crate::trace::{LedgerSample, TraceHandle};
use crate::util::json::{self, arr, num, obj, s, Value};

mod expo;

pub struct ServerConfig {
    pub addr: String,
    pub artifact_dir: PathBuf,
    pub opts: EngineOptions,
    /// Governor knobs (hysteresis, search grid, KV-pool seq ceiling) —
    /// see [`GovernorConfig::from_runtime`].
    pub governor: GovernorConfig,
    /// Apply this DRAM budget at startup (otherwise the governor assumes
    /// the device's physical DRAM until the first `set_budget`).
    pub initial_budget: Option<u64>,
    /// Scripted pressure trace (`"<size>@<token>,..."`): the worker fires
    /// each step between waves once the decoded-token count passes it —
    /// the same path a `set_budget` command takes.
    pub pressure_schedule: Option<String>,
    /// Available-DRAM file (`--pressure-file`): polled on the worker
    /// between waves; a *changed* figure is fed to the governor as a
    /// `pressure` trigger — the OS memory-pressure source next to
    /// `command`/`schedule`. `/proc/meminfo` format or a plain byte
    /// count (mockable in tests).
    pub pressure_file: Option<PathBuf>,
    /// Scheduler: hard cap on concurrently decoding sequences
    /// (`--max-seqs`); the governor lowers the effective ceiling under
    /// tight budgets.
    pub max_seqs: usize,
    /// Scheduler wait-queue bound (submissions past it are rejected).
    pub sched_queue_cap: usize,
    /// Deterministic fault plan (`--faults`, [`crate::flash::FaultPlan`]
    /// spec) armed on the engine's flash device at startup — the chaos
    /// suite drives the whole recovery ladder through this knob.
    pub fault_spec: Option<String>,
    /// Enable the flight recorder from startup and write the span ring as
    /// Chrome trace-event JSON to this path at shutdown (`--trace-out`).
    /// `{"cmd":"trace"}` can toggle/export at any time regardless.
    pub trace_out: Option<PathBuf>,
    /// Default frame interval for `{"cmd":"subscribe"}` streams
    /// (`--telemetry-interval-ms`); a subscriber may override per
    /// connection with `"interval_ms"`.
    pub telemetry_interval_ms: u64,
}

/// How often the worker re-reads the `--pressure-file` between waves
/// (the file mirrors a slow OS signal; per-wave reads would be noise).
const PRESSURE_POLL_EVERY: Duration = Duration::from_millis(250);

struct Request {
    prompt: Vec<u32>,
    n_tokens: usize,
    temp: f32,
    /// Per-request deadline in scheduler waves (`"deadline_waves"`):
    /// expiry returns the partial stream with `"status": "timeout"`.
    deadline_waves: Option<u64>,
    /// Causal root id minted at connection accept — every span this
    /// request produces (wave, step, layer fetch, flash I/O) carries it
    /// in its [`crate::trace::SpanCtx`].
    req_id: u64,
    /// Optional `"client"` tag: keys the engine's per-client
    /// expected-occupancy histogram.
    client: Option<String>,
    enqueued: Instant,
    resp: Sender<Value>,
}

enum Job {
    Decode(Request),
    /// Live re-budget: the worker runs the governor against its engine at
    /// the next wave boundary and answers with the decision.
    Rebudget { bytes: u64, resp: Sender<Value> },
    /// Flight-recorder control: toggle span recording and/or export the
    /// ring as Chrome trace-event JSON. Runs on the worker at a wave
    /// boundary — the export walks the shared ring under its mutex, which
    /// must not race a wave mid-flush.
    Trace {
        enable: Option<bool>,
        out: Option<PathBuf>,
        resp: Sender<Value>,
    },
    /// Snapshot the governor's decision journal.
    Journal { resp: Sender<Value> },
    /// Render the counter registry + histograms in Prometheus text
    /// exposition format (`{"cmd":"metrics"}` → [`expo::render`]).
    Metrics { resp: Sender<Value> },
    /// Zero the cumulative counters and histograms (engine metrics,
    /// scheduler stats, queue-wait histograms, request totals). The trace
    /// ring and journal survive — they have their own `trace` control.
    StatsReset { resp: Sender<Value> },
    Stop,
}

#[derive(Default)]
struct ServerStats {
    served: AtomicU64,
    tokens: AtomicU64,
    queue_ns: AtomicU64,
    /// Total wave wall time (the denominator of aggregate throughput —
    /// sequences decode interleaved, so per-request durations overlap).
    decode_ns: AtomicU64,
    // hot-path counters mirrored out of DecodeMetrics (PERF.md): the
    // engine lives on the worker thread, so `stats` connections read these
    // atomics instead of poking the engine
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Bytes loaded from flash / served from compute DRAM traffic —
    /// the swap-volume pair the bench points carry; mirrored so `stats`
    /// tracks the same counters the perf gate watches.
    flash_bytes: AtomicU64,
    dram_bytes: AtomicU64,
    /// Preload precision inputs (correctly preloaded / total needed).
    preload_hits: AtomicU64,
    preload_total: AtomicU64,
    /// Cross-token group-0 preload chains issued at token boundaries.
    cross_token_preloads: AtomicU64,
    lock_acquires: AtomicU64,
    locks_avoided: AtomicU64,
    batched_inserts: AtomicU64,
    ondemand_rows: AtomicU64,
    ondemand_coalesced_runs: AtomicU64,
    slab_bytes_peak: AtomicU64,
    // kernel hot-path mirror (bucketed attention + block-kernel dequant,
    // PERF.md "Kernel hot paths")
    host_copy_bytes: AtomicU64,
    attn_bucket_cap: AtomicU64,
    dequant_rows_vectorized: AtomicU64,
    subslab_waste_bytes: AtomicU64,
    // async read-queue mirror (shared ReadQueue, PERF.md)
    io_batches: AtomicU64,
    io_inflight_peak: AtomicU64,
    io_wait_loader_us: AtomicU64,
    io_wait_engine_us: AtomicU64,
    io_buffers_recycled: AtomicU64,
    /// Loader parts that failed to load (read/planning errors); waiters
    /// fell back to on-demand. Non-zero here means the flash file or the
    /// preload requests are broken — previously only visible on stderr.
    parts_failed: AtomicU64,
    // fault-injection / recovery-ladder mirror (flash + engine + sched):
    // the `health` command summarizes these
    faults_injected: AtomicU64,
    io_retries: AtomicU64,
    wedged_recoveries: AtomicU64,
    fallback_rows: AtomicU64,
    degraded_fallbacks: AtomicU64,
    seqs_timed_out: AtomicU64,
    seqs_panicked: AtomicU64,
    // runtime DRAM governor mirror: budget, pool ledger, decision counters
    budget_bytes: AtomicU64,
    ledger_cache_bytes: AtomicU64,
    ledger_preload_bytes: AtomicU64,
    ledger_compute_bytes: AtomicU64,
    rebudgets_applied: AtomicU64,
    rebudgets_skipped: AtomicU64,
    rebudget_rows_evicted: AtomicU64,
    level_switches: AtomicU64,
    last_settle_us: AtomicU64,
    // continuous-batching scheduler mirror
    seqs_active: AtomicU64,
    seqs_waiting: AtomicU64,
    seqs_admitted: AtomicU64,
    seqs_queued: AtomicU64,
    seqs_rejected: AtomicU64,
    seqs_preempted: AtomicU64,
    seqs_completed: AtomicU64,
    /// High-water mark of concurrently live sequences (realized admitted
    /// concurrency — the paged-KV bench's acceptance metric).
    seqs_active_peak: AtomicU64,
    sched_waves: AtomicU64,
    sched_wave_us: AtomicU64,
    max_active_seqs: AtomicU64,
    kv_per_seq_bytes: AtomicU64,
    // paged KV pool mirror (block-granular M_kv)
    kv_block_bytes: AtomicU64,
    kv_blocks_total: AtomicU64,
    kv_blocks_free: AtomicU64,
    kv_blocks_peak: AtomicU64,
    kv_preemptions_oom: AtomicU64,
    // latency percentiles (log2-bucket histograms, µs) — refreshed per
    // wave like the other hot mirrors, so `stats` connections never walk
    // a histogram themselves
    itl_p50_us: AtomicU64,
    itl_p95_us: AtomicU64,
    itl_p99_us: AtomicU64,
    wave_p50_us: AtomicU64,
    wave_p99_us: AtomicU64,
    ondemand_p99_us: AtomicU64,
    admission_wait_p99_us: AtomicU64,
    io_wait_loader_p99_us: AtomicU64,
    io_wait_engine_p50_us: AtomicU64,
    io_wait_engine_p95_us: AtomicU64,
    io_wait_engine_p99_us: AtomicU64,
    // flight-recorder ring health (overhead bound: capacity + drops are
    // always visible, so a saturated ring is a reported condition)
    trace_enabled: AtomicU64,
    trace_events: AtomicU64,
    trace_capacity: AtomicU64,
    trace_dropped: AtomicU64,
    journal_entries: AtomicU64,
    journal_dropped: AtomicU64,
    // live-telemetry plane: push-stream subscribers and their bounded
    // queues' drop accounting (`health` folds frames_dropped into the
    // degraded verdict — a starved subscriber is a reported condition)
    subscribers: AtomicU64,
    frames_sent: AtomicU64,
    frames_dropped: AtomicU64,
    /// Per-client p90 of ended-sequence lengths (expected-occupancy
    /// signal) — refreshed per wave from the engine's keyed histograms.
    client_p90s: Mutex<Vec<(String, u64)>>,
}

impl ServerStats {
    /// Refresh the hot-path mirror from the engine's cumulative counters
    /// (absolute stores — one engine, one worker).
    fn publish_hot(&self, m: &DecodeMetrics, parts_failed: u64) {
        let st = |a: &AtomicU64, v: u64| a.store(v, Ordering::Relaxed);
        st(&self.cache_hits, m.cache_hits);
        st(&self.cache_misses, m.cache_misses);
        st(&self.flash_bytes, m.flash_bytes);
        st(&self.dram_bytes, m.dram_bytes);
        st(&self.preload_hits, m.preload_hits);
        st(&self.preload_total, m.preload_total);
        st(&self.cross_token_preloads, m.cross_token_preloads);
        st(&self.lock_acquires, m.cache_lock_acquires);
        st(&self.locks_avoided, m.cache_locks_avoided);
        st(&self.batched_inserts, m.batched_inserts);
        st(&self.ondemand_rows, m.ondemand_rows);
        st(&self.ondemand_coalesced_runs, m.ondemand_coalesced_runs);
        st(&self.slab_bytes_peak, m.slab_bytes_peak);
        st(&self.host_copy_bytes, m.host_copy_bytes);
        st(&self.attn_bucket_cap, m.attn_bucket_cap);
        st(&self.dequant_rows_vectorized, m.dequant_rows_vectorized);
        st(&self.subslab_waste_bytes, m.subslab_waste_bytes);
        st(&self.io_batches, m.io_batches);
        st(&self.io_inflight_peak, m.io_inflight_peak);
        st(
            &self.io_wait_loader_us,
            m.io_wait_loader.as_micros() as u64,
        );
        st(
            &self.io_wait_engine_us,
            m.io_wait_engine.as_micros() as u64,
        );
        st(&self.io_buffers_recycled, m.io_buffers_recycled);
        st(&self.parts_failed, parts_failed);
        st(&self.faults_injected, m.faults_injected);
        st(&self.io_retries, m.io_retries);
        st(&self.wedged_recoveries, m.wedged_recoveries);
        st(&self.fallback_rows, m.fallback_rows);
        st(&self.degraded_fallbacks, m.degraded_fallbacks);
        st(&self.itl_p50_us, m.h_itl_us.p50());
        st(&self.itl_p95_us, m.h_itl_us.p95());
        st(&self.itl_p99_us, m.h_itl_us.p99());
        st(&self.wave_p50_us, m.h_wave_us.p50());
        st(&self.wave_p99_us, m.h_wave_us.p99());
        st(&self.ondemand_p99_us, m.h_ondemand_us.p99());
        st(&self.admission_wait_p99_us, m.h_admission_wait_us.p99());
    }

    /// Refresh the queue-wait percentile and flight-recorder mirrors
    /// (small mutex reads on the worker, once per wave).
    fn publish_trace(&self, engine: &SwapEngine) {
        let st = |a: &AtomicU64, v: u64| a.store(v, Ordering::Relaxed);
        let (h_loader, h_engine) = engine.io_wait_histos();
        st(&self.io_wait_loader_p99_us, h_loader.p99());
        st(&self.io_wait_engine_p50_us, h_engine.p50());
        st(&self.io_wait_engine_p95_us, h_engine.p95());
        st(&self.io_wait_engine_p99_us, h_engine.p99());
        let t = engine.trace_handle();
        let (len, cap, dropped) = t.ring_stats();
        st(&self.trace_enabled, t.enabled() as u64);
        st(&self.trace_events, len as u64);
        st(&self.trace_capacity, cap as u64);
        st(&self.trace_dropped, dropped);
        let (jlen, jdropped) = t.journal_stats();
        st(&self.journal_entries, jlen as u64);
        st(&self.journal_dropped, jdropped);
        *self.client_p90s.lock().unwrap() = engine.client_p90s();
    }

    /// Zero the request totals (`stats_reset`; the per-wave mirrors are
    /// re-published right after from the freshly zeroed sources).
    fn reset_request_totals(&self) {
        self.served.store(0, Ordering::Relaxed);
        self.tokens.store(0, Ordering::Relaxed);
        self.queue_ns.store(0, Ordering::Relaxed);
        self.decode_ns.store(0, Ordering::Relaxed);
    }

    /// Refresh the scheduler mirror.
    fn publish_sched(
        &self,
        st: &SchedStats,
        active: usize,
        waiting: usize,
        max_active: usize,
    ) {
        let w = |a: &AtomicU64, v: u64| a.store(v, Ordering::Relaxed);
        w(&self.seqs_active, active as u64);
        w(&self.seqs_waiting, waiting as u64);
        w(&self.seqs_admitted, st.seqs_admitted);
        w(&self.seqs_queued, st.seqs_queued);
        w(&self.seqs_rejected, st.seqs_rejected);
        w(&self.seqs_preempted, st.seqs_preempted);
        w(&self.seqs_completed, st.seqs_completed);
        w(&self.seqs_active_peak, st.peak_active);
        w(&self.sched_waves, st.waves);
        w(&self.sched_wave_us, st.wave_time.as_micros() as u64);
        w(&self.max_active_seqs, max_active as u64);
        w(&self.kv_preemptions_oom, st.kv_preempted_oom);
        w(&self.seqs_timed_out, st.seqs_timed_out);
        w(&self.seqs_panicked, st.seqs_panicked);
        self.decode_ns
            .store(st.wave_time.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Refresh the governor mirror from the worker-side engine state.
    fn publish_governor(&self, engine: &SwapEngine, gov: &DramGovernor) {
        let ledger = engine.pool_ledger();
        self.budget_bytes.store(gov.budget(), Ordering::Relaxed);
        self.kv_per_seq_bytes
            .store(gov.kv_per_seq(), Ordering::Relaxed);
        let kv = engine.kv_pool_stats();
        self.kv_block_bytes
            .store(engine.kv_block_bytes(), Ordering::Relaxed);
        // an unthrottled pool reports 0 total/free rather than usize::MAX
        // noise — "total" is meaningful only once the governor set one
        let total = if kv.capacity_blocks == usize::MAX {
            0
        } else {
            kv.capacity_blocks as u64
        };
        self.kv_blocks_total.store(total, Ordering::Relaxed);
        self.kv_blocks_free.store(
            if total == 0 { 0 } else { kv.free_blocks as u64 },
            Ordering::Relaxed,
        );
        self.kv_blocks_peak
            .store(kv.peak_blocks as u64, Ordering::Relaxed);
        self.ledger_cache_bytes
            .store(ledger.cache_bytes, Ordering::Relaxed);
        self.ledger_preload_bytes
            .store(ledger.preload_bytes, Ordering::Relaxed);
        self.ledger_compute_bytes
            .store(ledger.compute_bytes, Ordering::Relaxed);
        let m = &engine.metrics;
        self.rebudgets_applied
            .store(m.rebudgets_applied, Ordering::Relaxed);
        self.rebudgets_skipped
            .store(m.rebudgets_skipped, Ordering::Relaxed);
        self.rebudget_rows_evicted
            .store(m.rebudget_rows_evicted, Ordering::Relaxed);
        self.level_switches
            .store(m.level_switches, Ordering::Relaxed);
        if let Some(d) = gov.last_decision() {
            self.last_settle_us
                .store(d.settle.as_micros() as u64, Ordering::Relaxed);
        }
    }
}

/// Run the server until a `shutdown` command arrives. Returns the number of
/// requests served.
pub fn serve(cfg: ServerConfig) -> Result<u64> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    eprintln!("[server] listening on {}", cfg.addr);

    let (job_tx, job_rx) = channel::<Job>();
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));
    // subscriber streams read the flight-recorder ring directly (its own
    // mutex, never the engine's) — the worker parks a handle here once
    // the engine is open
    let trace_slot: Arc<Mutex<Option<TraceHandle>>> =
        Arc::new(Mutex::new(None));
    let telemetry_interval_ms = cfg.telemetry_interval_ms.max(1);

    // ---- engine worker: owns Scheduler<SwapEngine> + DramGovernor,
    //      alternates job-drain and decode waves.
    let worker_stats = stats.clone();
    let artifact_dir = cfg.artifact_dir.clone();
    let opts_device = cfg.opts.device;
    let initial_budget = cfg.initial_budget;
    let governor_cfg = cfg.governor.clone();
    let sched_cfg = SchedConfig {
        max_seqs: cfg.max_seqs.max(1),
        queue_cap: cfg.sched_queue_cap,
    };
    let mut pressure = match &cfg.pressure_schedule {
        Some(spec) => Some(PressureSchedule::parse(spec)?),
        None => None,
    };
    let pressure_file = cfg.pressure_file.clone();
    let fault_spec = cfg.fault_spec.clone();
    let trace_out = cfg.trace_out.clone();
    let trace_slot_w = trace_slot.clone();
    let worker = std::thread::spawn(move || -> Result<()> {
        let mut engine = SwapEngine::open(&artifact_dir, cfg.opts)?;
        *trace_slot_w.lock().unwrap() =
            Some(engine.trace_handle().clone());
        if let Some(spec) = &fault_spec {
            engine.inject_fault_spec(spec)?;
            eprintln!("[server] fault injection armed: {spec}");
        }
        if let Some(path) = &trace_out {
            engine.trace_handle().set_enabled(true);
            eprintln!(
                "[server] flight recorder on, writes {} at shutdown",
                path.display()
            );
        }
        // interleaved decode: every sequence's next-token group-0 chain
        // loads while its peers compute
        engine.set_cross_token_preload(true);
        let mut gov = DramGovernor::new(
            &engine,
            governor_cfg,
            opts_device.dram_bytes,
        );
        if let Some(budget) = initial_budget {
            let d = gov.set_budget(&mut engine, budget,
                                   RebudgetTrigger::Command)?;
            eprintln!(
                "[server] initial budget {}: sp={:.2} N={} cache={} \
                 max_seqs={} ({})",
                budget, d.new_sp, d.new_group, d.cache_target, d.max_seqs,
                d.note
            );
        }
        worker_stats.publish_governor(&engine, &gov);
        eprintln!(
            "[server] engine ready: model={} level={} device={} max_seqs={}",
            engine.model().name,
            engine.sparsity_tag(),
            opts_device.name,
            sched_cfg.max_seqs,
        );
        let mut sched = Scheduler::new(engine, sched_cfg);
        sched.set_max_active(gov.max_seqs());
        // response routing: sched seq id → (reply channel, time already
        // spent queueing before the scheduler saw the request, and the
        // engine's failure counters at submit time — the finish path
        // diffs against them for per-request failure detail. The
        // counters are engine-global, so a delta attributes every
        // failure that happened DURING the request's lifetime (peers
        // included): best-effort attribution, exact when serial.
        struct Waiter {
            resp: Sender<Value>,
            pre_queue: Duration,
            parts_failed0: u64,
            degraded0: u64,
        }
        let mut waiting: HashMap<u64, Waiter> = HashMap::new();
        let mut seed_counter = 0u64;
        let mut last_parts_failed = 0u64;
        // available-DRAM file source: throttled poll state (dedupe on the
        // read value — only a *change* reaches the governor; its
        // hysteresis gate then filters wiggle below the threshold)
        let mut pressure_last_bytes: Option<u64> = None;
        let mut pressure_last_poll = Instant::now()
            .checked_sub(PRESSURE_POLL_EVERY)
            .unwrap_or_else(Instant::now);
        let mut pressure_err_logged = false;
        'outer: loop {
            // drain every pending job at this wave boundary — the safe
            // point where re-budgets (level switches, ceiling shrinks)
            // apply mid-generation instead of after it
            loop {
                let job = if sched.has_work() {
                    match job_rx.try_recv() {
                        Ok(j) => Some(j),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => break 'outer,
                    }
                } else {
                    match job_rx.recv() {
                        Ok(j) => Some(j),
                        Err(_) => break 'outer,
                    }
                };
                let Some(job) = job else { break };
                match job {
                    Job::Stop => break 'outer,
                    Job::Rebudget { bytes, resp } => {
                        let v = apply_rebudget(&mut sched, &mut gov, bytes);
                        worker_stats
                            .publish_governor(sched.backend(), &gov);
                        let _ = resp.send(v);
                    }
                    Job::Trace { enable, out, resp } => {
                        let h = sched.backend().trace_handle().clone();
                        if let Some(on) = enable {
                            h.set_enabled(on);
                        }
                        let (len, cap, dropped) = h.ring_stats();
                        let mut fields = vec![
                            ("enabled", Value::Bool(h.enabled())),
                            ("events", num(len as f64)),
                            ("capacity", num(cap as f64)),
                            ("dropped", num(dropped as f64)),
                        ];
                        if let Some(path) = out {
                            match write_trace(&path, &h) {
                                Ok(()) => fields.push((
                                    "written",
                                    s(&path.display().to_string()),
                                )),
                                Err(e) => fields.push((
                                    "error",
                                    s(&format!("{e:#}")),
                                )),
                            }
                        }
                        worker_stats.publish_trace(sched.backend());
                        let _ = resp.send(obj(fields));
                    }
                    Job::Metrics { resp } => {
                        let engine = sched.backend();
                        let (h_loader, h_engine) =
                            engine.io_wait_histos();
                        let text = expo::render(
                            &engine.metrics,
                            &sched.stats(),
                            &engine.io_snapshot(),
                            &h_loader,
                            &h_engine,
                        );
                        let _ =
                            resp.send(obj(vec![("metrics", s(&text))]));
                    }
                    Job::Journal { resp } => {
                        let h = sched.backend().trace_handle();
                        let (len, dropped) = h.journal_stats();
                        let entries: Vec<Value> = h
                            .snapshot_journal()
                            .iter()
                            .map(|e| e.to_json())
                            .collect();
                        let _ = resp.send(obj(vec![
                            ("entries", arr(entries)),
                            ("len", num(len as f64)),
                            ("dropped", num(dropped as f64)),
                        ]));
                    }
                    Job::StatsReset { resp } => {
                        // zero every cumulative source, then re-publish
                        // the absolute mirrors from the zeroed state so
                        // `stats` is consistent immediately (not at the
                        // next wave)
                        let engine = sched.backend_mut();
                        engine.metrics = DecodeMetrics::default();
                        engine.reset_io_wait_histos();
                        sched.reset_stats();
                        worker_stats.reset_request_totals();
                        worker_stats.publish_hot(
                            &sched.backend().metrics,
                            last_parts_failed,
                        );
                        worker_stats.publish_trace(sched.backend());
                        let (active, queued, max_active) = (
                            sched.active(),
                            sched.queued(),
                            sched.max_active(),
                        );
                        worker_stats.publish_sched(
                            &sched.stats(),
                            active,
                            queued,
                            max_active,
                        );
                        worker_stats
                            .publish_governor(sched.backend(), &gov);
                        let _ = resp
                            .send(obj(vec![("ok", Value::Bool(true))]));
                    }
                    Job::Decode(r) => {
                        seed_counter += 1;
                        let pre_queue = r.enqueued.elapsed();
                        let parts_failed0 =
                            sched.backend().loader_stats().parts_failed;
                        let degraded0 =
                            sched.backend().metrics.degraded_fallbacks;
                        let outcome = sched.submit(SeqRequest {
                            prompt: r.prompt,
                            n_tokens: r.n_tokens,
                            temp: r.temp,
                            seed: seed_counter,
                            eos: None,
                            deadline_waves: r.deadline_waves,
                            req_id: r.req_id,
                            client: r.client,
                        });
                        match outcome {
                            SubmitOutcome::Admitted { id }
                            | SubmitOutcome::Queued { id, .. } => {
                                waiting.insert(id, Waiter {
                                    resp: r.resp,
                                    pre_queue,
                                    parts_failed0,
                                    degraded0,
                                });
                            }
                            SubmitOutcome::Rejected { reason } => {
                                let _ = r.resp.send(obj(vec![(
                                    "error",
                                    s(reason),
                                )]));
                            }
                        }
                    }
                }
            }
            if !sched.has_work() {
                continue; // nothing live — block on the next job
            }

            // one wave: each live sequence decodes one token
            let finished = sched.wave();
            let any_finished = !finished.is_empty();
            for f in finished {
                let Some(w) = waiting.remove(&f.id) else {
                    continue;
                };
                let (resp, pre_queue) = (w.resp, w.pre_queue);
                let parts_failed_delta = sched
                    .backend()
                    .loader_stats()
                    .parts_failed
                    .saturating_sub(w.parts_failed0);
                let degraded_delta = sched
                    .backend()
                    .metrics
                    .degraded_fallbacks
                    .saturating_sub(w.degraded0);
                let queue_t = pre_queue + f.queue_wait;
                let v = match f.outcome {
                    Err(e) => obj(vec![("error", s(&e))]),
                    Ok(toks) => {
                        worker_stats.served.fetch_add(1, Ordering::Relaxed);
                        worker_stats
                            .tokens
                            .fetch_add(toks.len() as u64, Ordering::Relaxed);
                        worker_stats.queue_ns.fetch_add(
                            queue_t.as_nanos() as u64,
                            Ordering::Relaxed,
                        );
                        obj(vec![
                            ("text", s(&tokenizer::decode(&toks))),
                            (
                                "tokens",
                                arr(toks
                                    .iter()
                                    .map(|&t| num(t as f64))
                                    .collect()),
                            ),
                            ("queue_ms", num(queue_t.as_secs_f64() * 1e3)),
                            (
                                "decode_ms",
                                num(f.decode.as_secs_f64() * 1e3),
                            ),
                            ("waves", num(f.waves as f64)),
                            ("truncated", Value::Bool(f.truncated)),
                            (
                                "status",
                                s(if f.timed_out { "timeout" } else { "ok" }),
                            ),
                            // per-request failure detail: preload parts
                            // that failed and degraded-mode fetches the
                            // engine absorbed while this request was live
                            (
                                "parts_failed_delta",
                                num(parts_failed_delta as f64),
                            ),
                            (
                                "degraded_fallbacks",
                                num(degraded_delta as f64),
                            ),
                            // causal attribution (span-context plumbed):
                            // engine-class flash stall time and on-demand
                            // rows charged to THIS sequence's steps — not
                            // a lifetime-overlap estimate
                            ("io_wait_us", num(f.io_wait_us as f64)),
                            (
                                "ondemand_rows",
                                num(f.ondemand_rows as f64),
                            ),
                            (
                                "toks_per_sec",
                                num(toks.len() as f64
                                    / f.decode.as_secs_f64().max(1e-9)),
                            ),
                            (
                                "cache_hit_rate",
                                num(sched.backend().cache_hit_rate()),
                            ),
                            // per-request inter-token latency (µs; the
                            // log2-bucket percentile is the bucket upper
                            // edge clamped to the observed max)
                            ("itl_p50_us", num(f.itl.p50() as f64)),
                            ("itl_p95_us", num(f.itl.p95() as f64)),
                            ("itl_p99_us", num(f.itl.p99() as f64)),
                        ])
                    }
                };
                let _ = resp.send(v);
            }

            // scripted pressure trace: fire due steps between waves,
            // through the same governor path a set_budget command takes
            let decoded = sched.backend().metrics.tokens;
            if let Some(trace) = pressure.as_mut() {
                if let Some(budget) = trace.due(decoded) {
                    // a failed step must not take down serving — log and
                    // keep the engine on its previous configuration, the
                    // same degradation a failed set_budget command gets
                    match gov.set_budget(sched.backend_mut(), budget,
                                         RebudgetTrigger::Schedule) {
                        Ok(d) => {
                            sched.set_max_active(d.max_seqs);
                            eprintln!(
                                "[server] pressure step -> {} ({}): \
                                 sp={:.2} N={} cache={} max_seqs={}",
                                budget, d.note, d.new_sp, d.new_group,
                                d.cache_target, d.max_seqs
                            );
                        }
                        Err(e) => eprintln!(
                            "[server] pressure step failed: {e:#}"
                        ),
                    }
                    worker_stats.publish_governor(sched.backend(), &gov);
                }
            }

            // OS memory-pressure source: poll the available-DRAM file
            // between waves (throttled) and feed a CHANGED figure to the
            // governor — the third trigger next to command/schedule
            if let Some(pf) = &pressure_file {
                if pressure_last_poll.elapsed() >= PRESSURE_POLL_EVERY {
                    pressure_last_poll = Instant::now();
                    match crate::governor::read_pressure_file(pf) {
                        Ok(bytes)
                            if pressure_last_bytes != Some(bytes) =>
                        {
                            pressure_last_bytes = Some(bytes);
                            pressure_err_logged = false;
                            match gov.set_budget(
                                sched.backend_mut(),
                                bytes,
                                RebudgetTrigger::Pressure,
                            ) {
                                Ok(d) => {
                                    sched.set_max_active(d.max_seqs);
                                    eprintln!(
                                        "[server] pressure file -> {} \
                                         ({}): sp={:.2} N={} cache={} \
                                         max_seqs={}",
                                        bytes, d.note, d.new_sp,
                                        d.new_group, d.cache_target,
                                        d.max_seqs
                                    );
                                }
                                Err(e) => eprintln!(
                                    "[server] pressure rebudget failed: \
                                     {e:#}"
                                ),
                            }
                            worker_stats
                                .publish_governor(sched.backend(), &gov);
                        }
                        Ok(_) => {} // unchanged — deduped
                        Err(e) => {
                            // an unreadable file must not spam stderr or
                            // take down serving
                            if !pressure_err_logged {
                                pressure_err_logged = true;
                                eprintln!(
                                    "[server] pressure file unreadable: \
                                     {e:#}"
                                );
                            }
                        }
                    }
                }
            }

            // refresh the stats mirror — `stats` connections never touch
            // the engine. The lock-free mirrors (engine counters, sched
            // atomics) refresh every wave; the mutex-guarded ones (pool
            // ledger takes the counted WeightCache lock, loader stats its
            // mutex) only when a sequence retired — per-request frequency,
            // like the old worker, not per-token lock traffic
            if any_finished {
                let parts_failed =
                    sched.backend().loader_stats().parts_failed;
                last_parts_failed = parts_failed;
                worker_stats.publish_governor(sched.backend(), &gov);
            }
            worker_stats
                .publish_hot(&sched.backend().metrics, last_parts_failed);
            worker_stats.publish_trace(sched.backend());
            // per-wave DRAM ledger sample: the governor's pool targets
            // plus the engine-owned KV/slab residency, into the bounded
            // sampler ring (Chrome counter tracks in the trace export)
            {
                let engine = sched.backend();
                let t = engine.trace_handle();
                if t.enabled() {
                    let pools = gov.current_pools();
                    let (kv_bytes, slab_bytes) = engine.ledger_probe();
                    t.record_ledger(LedgerSample {
                        t_us: t.now_us(),
                        cache_bytes: pools.cache_bytes,
                        preload_bytes: pools.preload_bytes,
                        compute_bytes: pools.compute_bytes,
                        kv_bytes,
                        slab_bytes,
                    });
                }
            }
            let (active, queued, max_active) =
                (sched.active(), sched.queued(), sched.max_active());
            worker_stats.publish_sched(
                &sched.stats(),
                active,
                queued,
                max_active,
            );
        }
        sched.shutdown();
        if let Some(path) = &trace_out {
            match write_trace(path, sched.backend().trace_handle()) {
                Ok(()) => eprintln!(
                    "[server] trace written to {}",
                    path.display()
                ),
                Err(e) => {
                    eprintln!("[server] trace write failed: {e:#}")
                }
            }
        }
        Ok(())
    });

    // ---- accept loop
    for conn in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let conn = match conn {
            Ok(c) => c,
            Err(_) => continue,
        };
        let job_tx = job_tx.clone();
        let stats = stats.clone();
        let stop2 = stop.clone();
        let trace_slot2 = trace_slot.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(
                conn,
                job_tx,
                stats,
                stop2,
                trace_slot2,
                telemetry_interval_ms,
            );
        });
        if stop.load(Ordering::Relaxed) {
            break;
        }
    }
    let _ = job_tx.send(Job::Stop);
    let _ = worker.join();
    Ok(stats.served.load(Ordering::Relaxed))
}

/// Apply a live re-budget at a wave boundary: governor search + engine
/// apply + scheduler ceiling (preempting past it), answering with the
/// decision.
fn apply_rebudget(
    sched: &mut Scheduler<SwapEngine>,
    gov: &mut DramGovernor,
    bytes: u64,
) -> Value {
    match gov.set_budget(sched.backend_mut(), bytes,
                         RebudgetTrigger::Command) {
        Err(e) => obj(vec![("error", s(&format!("{e:#}")))]),
        Ok(d) if d.note == "infeasible" => obj(vec![(
            "error",
            s("budget below minimum servable configuration"),
        )]),
        Ok(d) => {
            let preempted = if d.applied {
                sched.set_max_active(d.max_seqs)
            } else {
                0
            };
            obj(vec![
                ("applied", Value::Bool(d.applied)),
                ("note", s(d.note)),
                ("sparsity", num(d.new_sp)),
                ("group_size", num(d.new_group as f64)),
                ("cache_bytes", num(d.cache_target as f64)),
                ("slab_cap_bytes", num(d.slab_cap as f64)),
                ("max_seqs", num(d.max_seqs as f64)),
                (
                    // 0 = unthrottled (no finite ceiling planned yet)
                    "kv_pool_blocks",
                    num(if d.kv_pool_blocks == usize::MAX {
                        0.0
                    } else {
                        d.kv_pool_blocks as f64
                    }),
                ),
                ("seqs_preempted", num(preempted as f64)),
                ("evicted_rows", num(d.evicted_rows as f64)),
                ("settle_ms", num(d.settle.as_secs_f64() * 1e3)),
                (
                    "ledger_cache_bytes",
                    num(d.new_pools.cache_bytes as f64),
                ),
                (
                    "ledger_preload_bytes",
                    num(d.new_pools.preload_bytes as f64),
                ),
                (
                    "ledger_compute_bytes",
                    num(d.new_pools.compute_bytes as f64),
                ),
            ])
        }
    }
}

/// Export the flight-recorder ring as Chrome trace-event JSON
/// (Perfetto / `chrome://tracing` loadable; `scripts/check_trace.py`
/// validates the schema).
fn write_trace(
    path: &std::path::Path,
    h: &crate::trace::TraceHandle,
) -> Result<()> {
    let v = crate::trace::chrome_trace(h);
    std::fs::write(path, v.to_string())
        .with_context(|| format!("writing trace {}", path.display()))?;
    Ok(())
}

/// The full stats snapshot — one shape for both the `stats` command and
/// the per-frame `"stats"` field of `subscribe` streams (tooling parses
/// one schema, not two).
fn stats_json(stats: &ServerStats) -> Value {
    let served = stats.served.load(Ordering::Relaxed);
    let tokens = stats.tokens.load(Ordering::Relaxed);
    let dec_ns = stats.decode_ns.load(Ordering::Relaxed);
    let waves = stats.sched_waves.load(Ordering::Relaxed);
    let g = |a: &AtomicU64| num(a.load(Ordering::Relaxed) as f64);
    let client_p90 = {
        let p90s = stats.client_p90s.lock().unwrap();
        obj(p90s
            .iter()
            .map(|(c, p)| (c.as_str(), num(*p as f64)))
            .collect())
    };
    obj(vec![
        ("served", num(served as f64)),
        ("tokens", num(tokens as f64)),
        (
            "avg_queue_ms",
            num(stats.queue_ns.load(Ordering::Relaxed) as f64
                / 1e6
                / served.max(1) as f64),
        ),
        // aggregate generated-token throughput over wave wall time
        // (sequences overlap — per-request durations must not be summed)
        (
            "throughput_toks_per_sec",
            num(tokens as f64 / (dec_ns as f64 / 1e9).max(1e-9)),
        ),
        (
            "cache_hit_rate",
            num({
                let h = stats.cache_hits.load(Ordering::Relaxed) as f64;
                let mi =
                    stats.cache_misses.load(Ordering::Relaxed) as f64;
                if h + mi == 0.0 { 0.0 } else { h / (h + mi) }
            }),
        ),
        ("flash_bytes", g(&stats.flash_bytes)),
        ("dram_bytes", g(&stats.dram_bytes)),
        (
            "preload_precision",
            num({
                let h = stats.preload_hits.load(Ordering::Relaxed) as f64;
                let t =
                    stats.preload_total.load(Ordering::Relaxed) as f64;
                if t == 0.0 { 0.0 } else { h / t }
            }),
        ),
        ("cross_token_preloads", g(&stats.cross_token_preloads)),
        ("cache_lock_acquires", g(&stats.lock_acquires)),
        ("cache_locks_avoided", g(&stats.locks_avoided)),
        ("batched_inserts", g(&stats.batched_inserts)),
        ("ondemand_rows", g(&stats.ondemand_rows)),
        ("ondemand_coalesced_runs", g(&stats.ondemand_coalesced_runs)),
        ("slab_bytes_peak", g(&stats.slab_bytes_peak)),
        // kernel hot paths: bucketed attention window traffic and
        // block-kernel dequant throughput (PERF.md "Kernel hot paths")
        ("host_copy_bytes", g(&stats.host_copy_bytes)),
        ("attn_bucket_cap", g(&stats.attn_bucket_cap)),
        ("dequant_rows_vectorized", g(&stats.dequant_rows_vectorized)),
        ("subslab_waste_bytes", g(&stats.subslab_waste_bytes)),
        // async flash read path (PERF.md): io_wait_us is the legacy
        // total; the split tells preload reaping from on-demand stalls
        ("io_batches", g(&stats.io_batches)),
        ("io_inflight_peak", g(&stats.io_inflight_peak)),
        (
            "io_wait_us",
            num((stats.io_wait_loader_us.load(Ordering::Relaxed)
                + stats.io_wait_engine_us.load(Ordering::Relaxed))
                as f64),
        ),
        ("io_wait_loader_us", g(&stats.io_wait_loader_us)),
        ("io_wait_engine_us", g(&stats.io_wait_engine_us)),
        ("io_buffers_recycled", g(&stats.io_buffers_recycled)),
        ("parts_failed", g(&stats.parts_failed)),
        // fault injection & recovery ladder
        ("faults_injected", g(&stats.faults_injected)),
        ("io_retries", g(&stats.io_retries)),
        ("wedged_recoveries", g(&stats.wedged_recoveries)),
        ("fallback_rows", g(&stats.fallback_rows)),
        ("degraded_fallbacks", g(&stats.degraded_fallbacks)),
        ("seqs_timed_out", g(&stats.seqs_timed_out)),
        ("seqs_panicked", g(&stats.seqs_panicked)),
        // runtime DRAM governor: budget, pools, decisions
        ("budget_bytes", g(&stats.budget_bytes)),
        ("ledger_cache_bytes", g(&stats.ledger_cache_bytes)),
        ("ledger_preload_bytes", g(&stats.ledger_preload_bytes)),
        ("ledger_compute_bytes", g(&stats.ledger_compute_bytes)),
        ("rebudgets_applied", g(&stats.rebudgets_applied)),
        ("rebudgets_skipped", g(&stats.rebudgets_skipped)),
        ("rebudget_rows_evicted", g(&stats.rebudget_rows_evicted)),
        ("level_switches", g(&stats.level_switches)),
        ("last_settle_us", g(&stats.last_settle_us)),
        // continuous-batching scheduler
        ("seqs_active", g(&stats.seqs_active)),
        ("seqs_waiting", g(&stats.seqs_waiting)),
        ("seqs_admitted", g(&stats.seqs_admitted)),
        ("seqs_queued", g(&stats.seqs_queued)),
        ("seqs_rejected", g(&stats.seqs_rejected)),
        ("seqs_preempted", g(&stats.seqs_preempted)),
        ("seqs_completed", g(&stats.seqs_completed)),
        ("seqs_active_peak", g(&stats.seqs_active_peak)),
        ("sched_waves", g(&stats.sched_waves)),
        (
            "sched_wave_avg_us",
            num(stats.sched_wave_us.load(Ordering::Relaxed) as f64
                / waves.max(1) as f64),
        ),
        ("max_active_seqs", g(&stats.max_active_seqs)),
        ("kv_per_seq_bytes", g(&stats.kv_per_seq_bytes)),
        // per-client expected occupancy: p90 of ended-sequence lengths,
        // keyed by the request's `"client"` tag (see PERF.md)
        ("client_p90", client_p90),
        // paged KV pool (block-granular M_kv)
        ("kv_block_bytes", g(&stats.kv_block_bytes)),
        ("kv_blocks_total", g(&stats.kv_blocks_total)),
        ("kv_blocks_free", g(&stats.kv_blocks_free)),
        ("kv_blocks_peak", g(&stats.kv_blocks_peak)),
        ("kv_preemptions_oom", g(&stats.kv_preemptions_oom)),
        // latency percentiles (log2-bucket, µs) — see PERF.md
        // §Observability
        ("itl_p50_us", g(&stats.itl_p50_us)),
        ("itl_p95_us", g(&stats.itl_p95_us)),
        ("itl_p99_us", g(&stats.itl_p99_us)),
        ("wave_p50_us", g(&stats.wave_p50_us)),
        ("wave_p99_us", g(&stats.wave_p99_us)),
        ("ondemand_p99_us", g(&stats.ondemand_p99_us)),
        ("admission_wait_p99_us", g(&stats.admission_wait_p99_us)),
        ("io_wait_loader_p99_us", g(&stats.io_wait_loader_p99_us)),
        ("io_wait_engine_p50_us", g(&stats.io_wait_engine_p50_us)),
        ("io_wait_engine_p95_us", g(&stats.io_wait_engine_p95_us)),
        ("io_wait_engine_p99_us", g(&stats.io_wait_engine_p99_us)),
        // flight recorder ring health
        ("trace_enabled", g(&stats.trace_enabled)),
        ("trace_events", g(&stats.trace_events)),
        ("trace_capacity", g(&stats.trace_capacity)),
        ("trace_dropped", g(&stats.trace_dropped)),
        ("journal_entries", g(&stats.journal_entries)),
        ("journal_dropped", g(&stats.journal_dropped)),
        // live-telemetry plane
        ("subscribers", g(&stats.subscribers)),
        ("frames_sent", g(&stats.frames_sent)),
        ("frames_dropped", g(&stats.frames_dropped)),
    ])
}

/// Bounded per-subscriber frame queue: a slow reader drops frames (and
/// counts them) instead of backing pressure into the worker. 16 frames
/// of headroom absorbs scheduler jitter at any sane interval.
const SUB_QUEUE_CAP: usize = 16;

/// Frames queued for one subscriber, between the producer (frame
/// builder, paced at the subscribe interval) and the connection thread
/// (socket writer). `closed` is the single teardown signal for both
/// directions — writer death and producer shutdown.
struct SubQueue {
    frames: VecDeque<String>,
    closed: bool,
}

/// Drive one `subscribe` stream until the client disconnects (or the
/// server stops). A paced producer thread drains span deltas from the
/// flight-recorder ring and enqueues finished frames; the connection
/// thread pops and writes them. The queue is bounded: when the reader is
/// slower than the interval, whole frames drop and are counted — but the
/// frame sequence number still advances, so gaps are visible client-side
/// (`spans_missed` separately reports ring overwrites between drains).
/// Nothing here ever touches the decode worker: the producer takes only
/// the ring's own mutex and the queue's.
fn run_subscriber(
    writer: &mut TcpStream,
    h: TraceHandle,
    interval_ms: u64,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
) {
    let q = Arc::new((
        Mutex::new(SubQueue { frames: VecDeque::new(), closed: false }),
        Condvar::new(),
    ));
    let q_prod = q.clone();
    let stop_prod = stop.clone();
    let producer = std::thread::spawn(move || {
        let mut cursor = 0u64;
        let mut frame_no = 0u64;
        let mut dropped = 0u64;
        loop {
            std::thread::sleep(Duration::from_millis(interval_ms));
            if stop_prod.load(Ordering::Relaxed) {
                break;
            }
            let (spans, new_cursor, missed) = h.drain_since(cursor);
            cursor = new_cursor;
            frame_no += 1; // dropped frames leave visible gaps
            let spans_json: Vec<Value> = spans
                .iter()
                .map(|e| {
                    obj(vec![
                        ("kind", s(e.kind.name())),
                        ("t0_us", num(e.t0_us as f64)),
                        ("dur_us", num(e.dur_us as f64)),
                        ("tid", num(e.tid as f64)),
                        ("req", num(e.ctx.req as f64)),
                        ("seq", num(e.ctx.seq as f64)),
                        ("a", num(e.a as f64)),
                        ("b", num(e.b as f64)),
                    ])
                })
                .collect();
            let frame = obj(vec![
                ("frame", num(frame_no as f64)),
                ("t_us", num(h.now_us() as f64)),
                ("spans", arr(spans_json)),
                ("spans_missed", num(missed as f64)),
                ("stats", stats_json(&stats)),
                ("frames_dropped", num(dropped as f64)),
            ]);
            let mut line = frame.to_string();
            line.push('\n');
            let (lock, cv) = &*q_prod;
            let mut g = lock.lock().unwrap();
            if g.closed {
                break;
            }
            if g.frames.len() < SUB_QUEUE_CAP {
                g.frames.push_back(line);
                stats.frames_sent.fetch_add(1, Ordering::Relaxed);
            } else {
                dropped += 1;
                stats.frames_dropped.fetch_add(1, Ordering::Relaxed);
            }
            cv.notify_one();
        }
        let (lock, cv) = &*q_prod;
        lock.lock().unwrap().closed = true;
        cv.notify_all();
    });
    let (lock, cv) = &*q;
    loop {
        let frame = {
            let mut g = lock.lock().unwrap();
            loop {
                if let Some(f) = g.frames.pop_front() {
                    break Some(f);
                }
                if g.closed {
                    break None;
                }
                g = cv.wait(g).unwrap();
            }
        };
        let Some(frame) = frame else { break };
        if writer.write_all(frame.as_bytes()).is_err() {
            // client went away (or wedged past the OS socket buffer):
            // mark closed so the producer exits at its next tick
            break;
        }
    }
    {
        let mut g = lock.lock().unwrap();
        g.closed = true;
        cv.notify_all();
    }
    let _ = producer.join();
}

/// Request-id mint: one per decode request at connection accept — the
/// root of the request's span-context chain (`SpanCtx.req`). Starts at 1
/// so 0 stays the "no request attached" sentinel.
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(0);

/// Input hardening: a request line larger than this answers with an
/// error (and the rest of the line is drained in bounded chunks) instead
/// of buffering unbounded client input.
const MAX_LINE_BYTES: usize = 1 << 20;

fn handle_conn(
    conn: TcpStream,
    job_tx: Sender<Job>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    trace_slot: Arc<Mutex<Option<TraceHandle>>>,
    telemetry_interval_ms: u64,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let mut reader = BufReader::new(conn);
    loop {
        let mut line = String::new();
        // read at most MAX+1 bytes of the line: enough to detect the
        // overflow without storing an attacker-sized buffer
        match (&mut reader)
            .take(MAX_LINE_BYTES as u64 + 1)
            .read_line(&mut line)
        {
            Ok(0) => break, // EOF — client disconnected
            Ok(_) => {}
            Err(e) => {
                // invalid UTF-8 or a mid-line disconnect: this client is
                // done, but the failure stays on this connection thread
                respond(
                    &mut writer,
                    &obj(vec![("error", s(&format!("bad line: {e}")))]),
                )
                .ok();
                break;
            }
        }
        if line.len() > MAX_LINE_BYTES {
            // drain the rest of the oversized line in bounded chunks so
            // the NEXT line on this connection still parses (a line that
            // hit the cap but still ends in '\n' is already complete —
            // draining would eat the following request)
            while !line.ends_with('\n') {
                let buf = reader.fill_buf()?;
                if buf.is_empty() {
                    break;
                }
                match buf.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        reader.consume(i + 1);
                        break;
                    }
                    None => {
                        let n = buf.len();
                        reader.consume(n);
                    }
                }
            }
            respond(
                &mut writer,
                &obj(vec![("error", s("request line too long"))]),
            )?;
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let req = match json::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                respond(&mut writer,
                        &obj(vec![("error", s(&format!("bad json: {e}")))]))?;
                continue;
            }
        };
        match req.get("cmd").and_then(Value::as_str) {
            Some("stats") => {
                respond(&mut writer, &stats_json(&stats))?;
            }
            Some("stats_reset") => {
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::StatsReset { resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("trace") => {
                let enable = req.get("enable").and_then(Value::as_bool);
                let out = req
                    .get("out")
                    .and_then(Value::as_str)
                    .map(PathBuf::from);
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Trace { enable, out, resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("journal") => {
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Journal { resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("metrics") => {
                // Prometheus text exposition, rendered on the worker at
                // a wave boundary (consistent snapshot of engine + sched
                // counters), shipped back as one JSON string field
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Metrics { resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("subscribe") => {
                let handle = trace_slot.lock().unwrap().clone();
                let Some(h) = handle else {
                    respond(
                        &mut writer,
                        &obj(vec![("error", s("engine not ready"))]),
                    )?;
                    continue;
                };
                let interval_ms = req
                    .get("interval_ms")
                    .and_then(Value::as_f64)
                    .filter(|&v| v >= 1.0)
                    .map(|v| v as u64)
                    .unwrap_or(telemetry_interval_ms);
                respond(
                    &mut writer,
                    &obj(vec![
                        ("ok", Value::Bool(true)),
                        ("subscribed", Value::Bool(true)),
                        ("interval_ms", num(interval_ms as f64)),
                    ]),
                )?;
                stats.subscribers.fetch_add(1, Ordering::Relaxed);
                run_subscriber(
                    &mut writer,
                    h,
                    interval_ms,
                    stats.clone(),
                    stop.clone(),
                );
                stats.subscribers.fetch_sub(1, Ordering::Relaxed);
                // the connection is a one-way stream once upgraded —
                // tear it down rather than re-entering request parsing
                break;
            }
            Some("health") => {
                // recovery-ladder summary: is the engine absorbing
                // faults, and at what cost? `degraded` flips when any
                // rung of the ladder has fired — preload parts failed,
                // a worker was replaced, the engine served rows via
                // urgent fallback — or when the telemetry plane itself
                // is lossy: ring spans, journal entries, or subscriber
                // frames dropped. Lost observability is a health
                // condition, not a silent gap.
                let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
                let degraded = g(&stats.parts_failed) > 0
                    || g(&stats.wedged_recoveries) > 0
                    || g(&stats.degraded_fallbacks) > 0
                    || g(&stats.seqs_panicked) > 0
                    || g(&stats.trace_dropped) > 0
                    || g(&stats.journal_dropped) > 0
                    || g(&stats.frames_dropped) > 0;
                let n = |a: &AtomicU64| num(g(a) as f64);
                respond(
                    &mut writer,
                    &obj(vec![
                        ("ok", Value::Bool(true)),
                        ("degraded", Value::Bool(degraded)),
                        ("faults_injected", n(&stats.faults_injected)),
                        ("io_retries", n(&stats.io_retries)),
                        ("wedged_recoveries", n(&stats.wedged_recoveries)),
                        ("parts_failed", n(&stats.parts_failed)),
                        ("fallback_rows", n(&stats.fallback_rows)),
                        ("degraded_fallbacks", n(&stats.degraded_fallbacks)),
                        ("seqs_timed_out", n(&stats.seqs_timed_out)),
                        ("seqs_panicked", n(&stats.seqs_panicked)),
                        ("seqs_active", n(&stats.seqs_active)),
                        ("seqs_waiting", n(&stats.seqs_waiting)),
                        ("trace_dropped", n(&stats.trace_dropped)),
                        ("journal_dropped", n(&stats.journal_dropped)),
                        ("frames_dropped", n(&stats.frames_dropped)),
                    ]),
                )?;
            }
            Some("set_budget") => {
                // Elastic memory, live: the worker re-runs the §4.1
                // search under the new M_max and applies the result to
                // the running engine at the next wave boundary — mid-
                // generation, not after it.
                let bytes =
                    req.get("bytes").and_then(Value::as_f64).unwrap_or(0.0)
                        as u64;
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Rebudget { bytes, resp: tx });
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                respond(&mut writer, &obj(vec![("ok", Value::Bool(true))]))?;
                // poke the accept loop
                let _ = TcpStream::connect(
                    conn_addr(&writer).unwrap_or("127.0.0.1:0".into()),
                );
                break;
            }
            _ => {
                let prompt = tokenizer::encode(
                    req.get("prompt").and_then(Value::as_str).unwrap_or(" "),
                );
                let n_tokens = req
                    .get("n_tokens")
                    .and_then(Value::as_usize)
                    .unwrap_or(32);
                let temp = req
                    .get("temp")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0) as f32;
                let deadline_waves = req
                    .get("deadline_waves")
                    .and_then(Value::as_f64)
                    .filter(|&d| d >= 1.0)
                    .map(|d| d as u64);
                let req_id =
                    NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed) + 1;
                let client = req
                    .get("client")
                    .and_then(Value::as_str)
                    .map(str::to_string);
                let (tx, rx) = channel();
                let _ = job_tx.send(Job::Decode(Request {
                    prompt,
                    n_tokens,
                    temp,
                    deadline_waves,
                    req_id,
                    client,
                    enqueued: Instant::now(),
                    resp: tx,
                }));
                match rx.recv() {
                    Ok(v) => respond(&mut writer, &v)?,
                    Err(_) => respond(
                        &mut writer,
                        &obj(vec![("error", s("engine gone"))]),
                    )?,
                }
            }
        }
    }
    Ok(())
}

fn conn_addr(stream: &TcpStream) -> Option<String> {
    stream.local_addr().ok().map(|a| a.to_string())
}

fn respond(w: &mut TcpStream, v: &Value) -> Result<()> {
    let mut line = v.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    Ok(())
}

/// Client helper (examples + tests): send one request, read one response.
pub fn client_roundtrip(addr: &str, request: &Value) -> Result<Value> {
    let mut conn = TcpStream::connect(addr)?;
    let mut line = request.to_string();
    line.push('\n');
    conn.write_all(line.as_bytes())?;
    let mut reader = BufReader::new(conn);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    json::parse(resp.trim())
}

/// Energy summary helper reused by the CLI.
pub fn energy_summary(
    dev: &crate::device::DeviceProfile,
    m: &crate::metrics::DecodeMetrics,
) -> metrics::EnergyReport {
    metrics::energy(dev, m)
}
