//! Prometheus text exposition of the counter registry.
//!
//! `{"cmd":"metrics"}` renders every counter pallas-lint's counters pass
//! audits — [`DecodeMetrics`], [`SchedStats`], [`IoSnapshot`] — plus the
//! log2-bucket latency histograms, in the Prometheus text format
//! (`# TYPE` lines, cumulative `le` buckets, `_sum`/`_count`). All series
//! carry a `pallas_` name prefix; the lint-visible key is the bare name
//! inside each `("key", value)` tuple below, so the counters pass can
//! prove every registered counter reaches this exposition (aliases in
//! `lint.toml [counters].exposition_aliases` cover renames).
//!
//! The log2 histograms convert losslessly: bucket `i` of [`Histo`] counts
//! values in `(2^(i-1)-1, 2^i-1]`, so the Prometheus bucket boundary is
//! the inclusive upper edge and cumulation is a running sum — the same
//! conservative quantile semantics `Histo::percentile` reports.

use std::fmt::Write as _;

use crate::flash::IoSnapshot;
use crate::metrics::DecodeMetrics;
use crate::sched::SchedStats;
use crate::trace::Histo;

/// One `counter`-typed series: `# TYPE` line + sample.
fn counter(out: &mut String, kv: (&str, u64)) {
    let (name, v) = kv;
    let _ = writeln!(out, "# TYPE pallas_{name} counter");
    let _ = writeln!(out, "pallas_{name} {v}");
}

/// One `gauge`-typed series (peaks and high-water marks are not
/// monotone counters).
fn gauge(out: &mut String, kv: (&str, u64)) {
    let (name, v) = kv;
    let _ = writeln!(out, "# TYPE pallas_{name} gauge");
    let _ = writeln!(out, "pallas_{name} {v}");
}

/// One `histogram`-typed series: the log2 buckets become cumulative
/// `le` buckets (upper-edge boundaries), closed by `+Inf`, `_sum`, and
/// `_count`. Empty trailing buckets are elided — `+Inf` carries the
/// total — to keep the exposition proportional to observed spread.
fn histogram(out: &mut String, kh: (&str, &Histo)) {
    let (name, h) = kh;
    let _ = writeln!(out, "# TYPE pallas_{name} histogram");
    let mut cum = 0u64;
    let hi = (0..64).rev().find(|&i| h.bucket_count(i) > 0);
    if let Some(hi) = hi {
        for i in 0..=hi.min(62) {
            cum += h.bucket_count(i);
            let _ = writeln!(
                out,
                "pallas_{name}_bucket{{le=\"{}\"}} {cum}",
                Histo::bucket_upper_edge(i)
            );
        }
    }
    let _ = writeln!(
        out,
        "pallas_{name}_bucket{{le=\"+Inf\"}} {}",
        h.count()
    );
    let _ = writeln!(out, "pallas_{name}_sum {}", h.sum());
    let _ = writeln!(out, "pallas_{name}_count {}", h.count());
}

/// Render the full registry. `h_loader`/`h_engine` are the shared
/// read-queue wait distributions ([`crate::engine::SwapEngine::
/// io_wait_histos`]).
pub fn render(
    m: &DecodeMetrics,
    sc: &SchedStats,
    io: &IoSnapshot,
    h_loader: &Histo,
    h_engine: &Histo,
) -> String {
    let us = |d: std::time::Duration| d.as_micros() as u64;
    let mut out = String::with_capacity(8 * 1024);

    // ---- decode engine (DecodeMetrics)
    counter(&mut out, ("tokens", m.tokens));
    counter(&mut out, ("wall_us", us(m.wall)));
    counter(&mut out, ("compute_busy_us", us(m.compute_busy)));
    counter(&mut out, ("flash_busy_us", us(m.flash_busy)));
    counter(&mut out, ("flash_bytes", m.flash_bytes));
    counter(&mut out, ("cache_bytes", m.cache_bytes));
    counter(&mut out, ("dram_bytes", m.dram_bytes));
    counter(&mut out, ("cache_hits", m.cache_hits));
    counter(&mut out, ("cache_misses", m.cache_misses));
    counter(&mut out, ("preload_hits", m.preload_hits));
    counter(&mut out, ("preload_total", m.preload_total));
    counter(&mut out, ("cache_lock_acquires", m.cache_lock_acquires));
    counter(&mut out, ("cache_locks_avoided", m.cache_locks_avoided));
    counter(&mut out, ("batched_inserts", m.batched_inserts));
    counter(&mut out, ("ondemand_rows", m.ondemand_rows));
    counter(
        &mut out,
        ("ondemand_coalesced_runs", m.ondemand_coalesced_runs),
    );
    gauge(&mut out, ("slab_bytes_peak", m.slab_bytes_peak));
    // kernel hot paths (PERF.md "Kernel hot paths")
    counter(&mut out, ("host_copy_bytes", m.host_copy_bytes));
    gauge(&mut out, ("attn_bucket_cap", m.attn_bucket_cap));
    counter(
        &mut out,
        ("dequant_rows_vectorized", m.dequant_rows_vectorized),
    );
    counter(&mut out, ("subslab_waste_bytes", m.subslab_waste_bytes));
    counter(&mut out, ("cross_token_preloads", m.cross_token_preloads));
    counter(&mut out, ("fallback_rows", m.fallback_rows));
    counter(&mut out, ("degraded_fallbacks", m.degraded_fallbacks));

    // ---- shared read queue (IoSnapshot; io_-prefixed registry)
    counter(&mut out, ("io_submitted", io.submitted));
    counter(&mut out, ("io_batches", io.batches));
    gauge(&mut out, ("io_inflight_peak", io.inflight_peak));
    counter(&mut out, ("io_wait_us", io.wait_ns / 1_000));
    counter(&mut out, ("io_buffers_recycled", io.buffers_recycled));
    counter(&mut out, ("io_retries", io.retries));
    counter(&mut out, ("faults_injected", io.faults_injected));
    counter(&mut out, ("wedged_recoveries", io.wedged_recoveries));

    // ---- governor
    counter(&mut out, ("rebudgets_applied", m.rebudgets_applied));
    counter(&mut out, ("rebudgets_skipped", m.rebudgets_skipped));
    counter(
        &mut out,
        ("rebudget_rows_evicted", m.rebudget_rows_evicted),
    );
    counter(&mut out, ("level_switches", m.level_switches));
    counter(&mut out, ("rebudget_settle_us", us(m.rebudget_settle)));

    // ---- continuous-batching scheduler (SchedStats + mirrors)
    counter(&mut out, ("sched_waves", m.sched_waves));
    counter(&mut out, ("sched_wave_time_us", us(m.sched_wave_time)));
    counter(&mut out, ("wave_time_us", us(sc.wave_time)));
    counter(&mut out, ("tokens_out", sc.tokens_out));
    counter(&mut out, ("seqs_admitted", sc.seqs_admitted));
    counter(&mut out, ("seqs_queued", sc.seqs_queued));
    counter(&mut out, ("seqs_rejected", sc.seqs_rejected));
    counter(&mut out, ("seqs_preempted", sc.seqs_preempted));
    counter(&mut out, ("seqs_completed", sc.seqs_completed));
    counter(&mut out, ("seqs_timed_out", sc.seqs_timed_out));
    counter(&mut out, ("seqs_panicked", sc.seqs_panicked));
    counter(&mut out, ("kv_preemptions_oom", sc.kv_preempted_oom));
    gauge(&mut out, ("peak_active", sc.peak_active));
    gauge(&mut out, ("kv_blocks_peak", m.kv_blocks_peak));

    // ---- log2 latency histograms (cumulative le buckets)
    histogram(&mut out, ("itl_us", &m.h_itl_us));
    histogram(&mut out, ("wave_us", &m.h_wave_us));
    histogram(&mut out, ("admission_wait_us", &m.h_admission_wait_us));
    histogram(&mut out, ("ondemand_us", &m.h_ondemand_us));
    histogram(&mut out, ("io_wait_loader_us", h_loader));
    histogram(&mut out, ("io_wait_engine_us", h_engine));

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_cumulative_and_closed_by_inf() {
        let mut h = Histo::new();
        for v in [0, 1, 1, 5, 300] {
            h.record(v);
        }
        let mut out = String::new();
        histogram(&mut out, ("t_us", &h));
        // bucket 0 (le="0") holds the one zero; le="1" adds the two 1s
        assert!(out.contains("pallas_t_us_bucket{le=\"0\"} 1\n"), "{out}");
        assert!(out.contains("pallas_t_us_bucket{le=\"1\"} 3\n"), "{out}");
        // 5 lands in (3, 7]; 300 in (255, 511]
        assert!(out.contains("pallas_t_us_bucket{le=\"7\"} 4\n"), "{out}");
        assert!(
            out.contains("pallas_t_us_bucket{le=\"511\"} 5\n"),
            "{out}"
        );
        assert!(
            out.contains("pallas_t_us_bucket{le=\"+Inf\"} 5\n"),
            "{out}"
        );
        assert!(out.contains("pallas_t_us_sum 307\n"), "{out}");
        assert!(out.contains("pallas_t_us_count 5\n"), "{out}");
        // monotone: each bucket line's value never decreases
        let mut last = 0u64;
        for line in out.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 =
                line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-cumulative: {line}");
            last = v;
        }
    }

    #[test]
    fn empty_histogram_renders_inf_only() {
        let h = Histo::new();
        let mut out = String::new();
        histogram(&mut out, ("empty_us", &h));
        assert!(
            out.contains("pallas_empty_us_bucket{le=\"+Inf\"} 0\n"),
            "{out}"
        );
        assert!(out.contains("pallas_empty_us_count 0\n"), "{out}");
        assert!(!out.contains("le=\"0\""), "{out}");
    }

    #[test]
    fn render_covers_registry_counters() {
        let m = DecodeMetrics::default();
        let sc = SchedStats::default();
        let io = IoSnapshot::default();
        let text =
            render(&m, &sc, &io, &Histo::new(), &Histo::new());
        for name in [
            "pallas_tokens ",
            "pallas_io_submitted ",
            "pallas_tokens_out ",
            "pallas_kv_preemptions_oom ",
            "pallas_host_copy_bytes ",
            "pallas_attn_bucket_cap ",
            "pallas_dequant_rows_vectorized ",
            "pallas_subslab_waste_bytes ",
            "pallas_itl_us_count ",
            "pallas_io_wait_engine_us_count ",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // every sample line is `name value` with the pallas_ prefix
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.starts_with("pallas_"), "bad line: {line}");
            assert_eq!(line.split(' ').count(), 2, "bad line: {line}");
        }
    }
}
